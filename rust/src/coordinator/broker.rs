//! Broker data plane: persistent job records + the persistent work queue.
//!
//! Job record = one cache line in the submitting thread's **home pool**:
//! `[state][len][payload x 6]` — state ∈ {PENDING=1, DONE=2} (0 means the
//! slot was never written; records are created PENDING and persisted
//! before their handle is enqueued). Payloads up to 48 bytes inline (the
//! broker is a control-plane component; bulk data would live elsewhere).
//!
//! ## Multi-pool topology
//!
//! The broker addresses memory through [`crate::pmem::Topology`]: each
//! producer's job records and submission log live on its home socket's
//! pool (socket-local persistence on the submit path), and handles are
//! pool-qualified [`GAddr`]s packed into the queue's `u64` items. On a
//! single-pool topology every handle packs to the bare arena offset —
//! bit-identical to the pre-topology layout. Recovery reconciliation
//! therefore walks **all** pools: every thread's submission log (on its
//! home pool) against the recovered work queue, whichever pools its
//! shards live on.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs::{self, ObsSite};
use crate::pmem::{GAddr, PAddr, PmemPool, Topology, WORDS_PER_LINE};
use crate::queues::asyncq::{AsyncCfg, AsyncQueue, DeqFuture, EnqFuture, ExecFuture};
use crate::queues::perlcrq::PerLcrq;
use crate::queues::sharded::ShardedQueue;
use crate::queues::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError};

/// Max payload bytes per job (6 words inline).
pub const MAX_PAYLOAD: usize = 48;

const ST_PENDING: u64 = 1;
const ST_DONE: u64 = 2;

/// A durable job handle: the record's pool-qualified address.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobId(pub GAddr);

/// Decoded job state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Unwritten,
    Pending,
    Done,
}

/// The persistent broker. The work queue is any [`PersistentQueue`] —
/// PerLCRQ by default ([`Broker::new`] / [`Broker::new_on`]) or the
/// sharded/batched layer ([`Broker::new_sharded`]) for contention-heavy
/// deployments.
pub struct Broker {
    topo: Topology,
    queue: Arc<dyn PersistentQueue>,
    /// Typed handle on the sharded work queue (when built with
    /// [`Broker::new_sharded`]) — the async completion layer needs the
    /// concrete type for its batch-log plumbing.
    sharded: Option<Arc<ShardedQueue<PerLcrq>>>,
    /// Persistent per-thread submission logs (each on its thread's home
    /// pool) so audits and recovery reconciliation survive crashes.
    submit_log: SubmitLog,
    nthreads: usize,
    /// Per-job lease on in-flight (taken-but-not-completed) jobs, in
    /// milliseconds; 0 disables leasing. Volatile by design: leases guard
    /// against *worker death without a crash* — a full crash already
    /// redelivers via recovery, so nothing here needs to persist.
    /// `Arc`-shared so the async layer's resolution hook (which starts
    /// leases inside the combiner) can read it without borrowing `self`.
    lease_ms: Arc<AtomicU64>,
    /// Outstanding leases: handle → when the job was taken. Behind an
    /// `Arc` so the async ack closure (which may outlive the borrow) can
    /// clear the lease at execution time.
    leases: Arc<Mutex<HashMap<u64, Instant>>>,
}

/// Persistent per-thread submission logs: each thread `t` owns a
/// line-aligned region `[count][handles...]` on its home pool; `count` is
/// persisted after each appended handle (handles are packed [`GAddr`]s).
///
/// The owning pool and bare in-pool address are resolved **once** at
/// allocation: the append hot path issues pool-direct primitives instead
/// of re-unpacking `pools[g.pool]` behind every [`Topology`] accessor
/// (previously ~7 qualified round-trips per submit: one load, two
/// stores, two pwbs, the psync dispatch — each indexing the pool table
/// anew).
struct SubmitLog {
    slots: Vec<LogSlot>,
    cap: usize,
}

/// One thread's log: its home pool and the log's base word within it.
struct LogSlot {
    pool: Arc<PmemPool>,
    base: PAddr,
}

impl SubmitLog {
    fn alloc(topo: &Topology, nthreads: usize, cap: usize) -> Self {
        let slots: Vec<LogSlot> = (0..nthreads)
            .map(|t| {
                let pool = topo.home_pool(t);
                let b = topo.alloc_on(
                    pool,
                    (cap + WORDS_PER_LINE).next_multiple_of(WORDS_PER_LINE),
                    WORDS_PER_LINE,
                );
                // Each log is written by exactly one thread (SWSR).
                topo.set_hot(b, cap + WORDS_PER_LINE, crate::pmem::Hotness::Private);
                LogSlot { pool: Arc::clone(topo.pool(pool)), base: b.addr }
            })
            .collect();
        Self { slots, cap }
    }

    fn append(&self, tid: usize, job: JobId) {
        let LogSlot { pool, base: b } = &self.slots[tid];
        let b = *b;
        let n = pool.load(tid, b);
        assert!((n as usize) < self.cap, "submission log full; raise capacity");
        pool.store(tid, b.add(1 + n as usize), job.0.to_u64());
        pool.store(tid, b, n + 1);
        // One line flush covers count+early entries; entry line may differ.
        pool.pwb(tid, b.add(1 + n as usize));
        pool.pwb(tid, b);
        pool.psync(tid);
    }

    fn entries(&self, tid: usize) -> Vec<JobId> {
        let LogSlot { pool, base: b } = &self.slots[tid];
        let b = *b;
        let n = pool.load(tid, b) as usize;
        (0..n)
            .map(|i| JobId(GAddr::from_u64(pool.load(tid, b.add(1 + i)))))
            .collect()
    }
}

/// Result of a post-crash audit (per-state counts over the submission
/// logs of every pool).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BrokerAudit {
    pub submitted: usize,
    pub done: usize,
    pub pending: usize,
    /// Jobs whose record was never durably written (submission incomplete
    /// at crash — allowed to vanish).
    pub unwritten: usize,
}

/// SubmitLog ↔ work-queue reconciliation dump (`persiq audit`): what is
/// durably recorded vs what the queue would actually deliver. After
/// [`Broker::recover`] every mismatch count must be zero — the audit
/// verifies the reconciliation invariants instead of trusting them.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReconcileReport {
    /// Per-state counts from the submission logs.
    pub audit: BrokerAudit,
    /// Handles found on the work queue (including duplicates).
    pub queued: usize,
    /// Queued handles whose job record is PENDING (the healthy case).
    pub queued_pending: usize,
    /// Mismatch: queued handles pointing at DONE records (a completed
    /// job would be redelivered; `take` filters these but they should
    /// not survive recovery).
    pub queued_done: usize,
    /// Mismatch: queued handles pointing at unwritten records.
    pub queued_unwritten: usize,
    /// Mismatch: the same handle queued more than once.
    pub queued_duplicates: usize,
    /// Mismatch: PENDING jobs in the submission logs with **no** queued
    /// handle — stranded forever without intervention.
    pub stranded_pending: usize,
    /// Submitted-job counts per pool (socket) of the record's home.
    pub per_pool_submitted: Vec<usize>,
    /// Shard-plan state of a sharded work queue: `(active epoch, active
    /// shard count)`; `(0, 0)` for non-sharded queues.
    pub plan: (u64, usize),
    /// Mid-transition: `(frozen epoch, frozen shard count, residue)` of
    /// a plan still draining after a `resize`; `None` when the queue has
    /// exactly one plan (always the case post-recovery). The residue is
    /// a `len_hint` sum over the frozen stripes — an **upper bound** on
    /// the undrained items (it may overcount in-flight consumption, and
    /// never undercounts to 0 while an item remains), so reports must
    /// label it `residue <= N`, not an exact occupancy.
    pub draining_plan: Option<(u64, usize, u64)>,
    /// Cumulative resize counters of the work queue (zeroes when
    /// non-sharded).
    pub resize: crate::queues::sharded::ResizeStats,
}

impl ReconcileReport {
    /// Total queue↔log mismatches (0 = the reconciliation invariants
    /// hold).
    pub fn mismatches(&self) -> usize {
        self.queued_done
            + self.queued_unwritten
            + self.queued_duplicates
            + self.stranded_pending
    }
}

impl Broker {
    /// Create a broker on a standalone pool (single-pool compatibility
    /// entry point) for `nthreads` workers+producers, able to hold
    /// `max_jobs` job records.
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, max_jobs: usize, ring: usize) -> Broker {
        Self::new_on(&Topology::from_pool(pool), nthreads, max_jobs, ring)
    }

    /// Create a broker on a topology with a single PerLCRQ work queue
    /// (on the primary pool; job records still spread over the
    /// producers' home pools).
    pub fn new_on(topo: &Topology, nthreads: usize, max_jobs: usize, ring: usize) -> Broker {
        let cfg = QueueConfig { ring_size: ring, ..Default::default() };
        Broker {
            queue: Arc::new(PerLcrq::new(topo.primary(), nthreads, cfg)),
            sharded: None,
            submit_log: SubmitLog::alloc(topo, nthreads, max_jobs),
            topo: topo.clone(),
            nthreads,
            lease_ms: Arc::new(AtomicU64::new(0)),
            leases: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    /// Create a broker running on the sharded (optionally batched) work
    /// queue — `cfg.shards` / `cfg.batch` / `cfg.batch_deq` select the
    /// striping and group-commit parameters, `cfg.placement` maps shards
    /// onto the topology's pools. With `batch_deq > 1` the **ack path
    /// rides the work queue's dequeue log**: every handle a worker takes
    /// is recorded in a per-thread persistent dequeue log and
    /// group-committed once per `batch_deq` takes, so [`Broker::recover`]'s
    /// queue↔SubmitLog reconciliation stays exact — a durably-logged take
    /// is never redelivered (its position is retired at recovery), an
    /// unlogged take is redelivered and filtered by the DONE-state check
    /// in [`Broker::take`], and a logged take whose job never completed is
    /// re-enqueued from the SubmitLog. Fails with
    /// [`QueueError::BadConfig`] on an invalid configuration.
    pub fn new_sharded(
        topo: &Topology,
        nthreads: usize,
        max_jobs: usize,
        cfg: QueueConfig,
    ) -> Result<Broker, QueueError> {
        let sharded = Arc::new(ShardedQueue::new_perlcrq(topo, nthreads, cfg)?);
        Ok(Broker {
            queue: Arc::clone(&sharded) as Arc<dyn PersistentQueue>,
            sharded: Some(sharded),
            submit_log: SubmitLog::alloc(topo, nthreads, max_jobs),
            topo: topo.clone(),
            nthreads,
            lease_ms: Arc::new(AtomicU64::new(0)),
            leases: Arc::new(Mutex::new(HashMap::new())),
        })
    }

    /// Durably write a job record + submission-log entry (the synchronous
    /// prefix of both submit paths). On return the *record* survives any
    /// crash; whether its queue handle does depends on the enqueue path
    /// that follows.
    fn write_record(&self, tid: usize, payload: &[u8]) -> Result<JobId> {
        anyhow::ensure!(payload.len() <= MAX_PAYLOAD, "payload too large");
        let t = &self.topo;
        let rec = t.alloc_lines_on(t.home_pool(tid), 1);
        t.store(tid, rec.add(1), payload.len() as u64);
        for (i, chunk) in payload.chunks(8).enumerate() {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            t.store(tid, rec.add(2 + i), u64::from_le_bytes(w));
        }
        t.store(tid, rec.add(0), ST_PENDING);
        // Record durable before it becomes reachable.
        t.pwb(tid, rec);
        t.psync_pool(tid, rec.pool as usize);
        self.submit_log.append(tid, JobId(rec));
        // Record + submit-log entry are durable (append just psynced):
        // certified flight event, write-after-psync.
        obs::flight::record_sealed(
            self.topo.pool(self.topo.home_pool(tid)),
            tid,
            obs::flight::FlightKind::BrokerSubmit,
            rec.to_u64(),
        );
        Ok(JobId(rec))
    }

    /// Submit a job: durably write the record (on the submitter's home
    /// pool), log it, enqueue its handle. On return the job is guaranteed
    /// to survive any crash.
    pub fn submit(&self, tid: usize, payload: &[u8]) -> Result<JobId> {
        let job = self.write_record(tid, payload)?;
        self.queue.enqueue(tid, job.0.to_u64())?;
        Ok(job)
    }

    /// Async submit: the record + submission log are written durably on
    /// the caller's tid (as in [`Broker::submit`]), but the handle
    /// enqueue rides the async layer's combiner — the returned future
    /// resolves only once the handle is durably queued (its batch flush
    /// retired). Until then a crash leaves the job in the
    /// stranded-PENDING window that [`Broker::recover`] re-enqueues from
    /// the submission log, so an unresolved future never means a lost
    /// job — only an unacknowledged one.
    pub fn submit_async(
        &self,
        tid: usize,
        payload: &[u8],
        aq: &AsyncQueue<PerLcrq>,
    ) -> Result<(JobId, EnqFuture)> {
        let job = self.write_record(tid, payload)?;
        Ok((job, aq.enqueue_async(job.0.to_u64())))
    }

    /// Decode a job record's payload.
    fn read_payload(&self, tid: usize, rec: GAddr) -> Vec<u8> {
        let t = &self.topo;
        let len = t.load(tid, rec.add(1)) as usize;
        let mut payload = vec![0u8; len.min(MAX_PAYLOAD)];
        for (i, chunk) in payload.chunks_mut(8).enumerate() {
            let w = t.load(tid, rec.add(2 + i)).to_le_bytes();
            chunk.copy_from_slice(&w[..chunk.len()]);
        }
        payload
    }

    /// Start a lease for a just-delivered handle (no-op when leasing is
    /// off).
    fn note_taken(&self, handle: u64) {
        if self.lease_ms.load(Ordering::Relaxed) > 0 {
            self.leases.lock().unwrap().insert(handle, Instant::now());
        }
    }

    /// Take the next job (its payload), or `None` when the queue is empty.
    /// The job stays PENDING until [`Broker::complete`] — a crash between
    /// take and complete re-delivers it after recovery (at-least-once on
    /// *processing*, exactly-once on *completion*).
    pub fn take(&self, tid: usize) -> Result<Option<(JobId, Vec<u8>)>> {
        loop {
            let Some(handle) = self.queue.dequeue(tid)? else {
                return Ok(None);
            };
            if let Some(hit) = self.resolve_take(tid, handle) {
                return Ok(Some(hit));
            }
            // DONE or unwritten record — skip and keep dequeuing.
        }
    }

    /// Async take: dequeue a handle through the combiner. The future
    /// resolves with `Some(handle)` only once the consumption is durably
    /// logged (it will not be redelivered after a crash); pass the handle
    /// to [`Broker::resolve_take`] to filter stale deliveries and decode
    /// the payload — a `None` from `resolve_take` means "redelivered
    /// already-done job, take again".
    ///
    /// **Lease-at-resolution:** on a layer built by
    /// [`Broker::async_layer`] the combiner starts the job's lease at
    /// the durability point, strictly before this future resolves — a
    /// worker dying between the await and `resolve_take` therefore
    /// leaves a *leased* PENDING job that [`Broker::reap_expired`]
    /// redelivers, not a stranded one. (`resolve_take` merely refreshes
    /// that lease on the async path.)
    pub fn take_async(&self, aq: &AsyncQueue<PerLcrq>) -> DeqFuture {
        aq.dequeue_async()
    }

    /// Classify a dequeued handle: `Some((job, payload))` for a live
    /// PENDING job (starting its lease, when enabled), `None` for a
    /// handle whose record is DONE (completed in a previous epoch but
    /// re-delivered by a recovered queue) or unwritten (submission never
    /// returned) — skip those.
    pub fn resolve_take(&self, tid: usize, handle: u64) -> Option<(JobId, Vec<u8>)> {
        let rec = GAddr::from_u64(handle);
        match self.topo.load(tid, rec.add(0)) {
            ST_PENDING => {
                self.note_taken(handle);
                Some((JobId(rec), self.read_payload(tid, rec)))
            }
            _ => None,
        }
    }

    /// Durably mark a job done (exactly-once: a CAS guards the state
    /// transition; the flush makes it crash-proof).
    pub fn complete(&self, tid: usize, job: JobId) -> Result<bool> {
        let t = &self.topo;
        let won = t.cas(tid, job.0.add(0), ST_PENDING, ST_DONE);
        if won {
            // The DONE flush is acknowledgement traffic, not op cost.
            let _site = obs::enter_site(ObsSite::BrokerAck);
            t.pwb(tid, job.0);
            t.psync_pool(tid, job.0.pool as usize);
            // DONE is durable: certified flight event on the job's pool.
            // (`ack_async` records nothing — its DONE pwb rides a later
            // group flush, so there is no completed psync to seal on.)
            obs::flight::record_sealed(
                self.topo.pool(job.0.pool as usize),
                tid,
                obs::flight::FlightKind::BrokerAck,
                job.0.to_u64(),
            );
        }
        if self.lease_ms.load(Ordering::Relaxed) > 0 {
            self.leases.lock().unwrap().remove(&job.0.to_u64());
        }
        Ok(won)
    }

    /// Async ack: the DONE transition executes on the combiner's thread
    /// slot and its `psync` rides the next group flush — acks amortize to
    /// the same 1/K drain as the dequeue log instead of paying a private
    /// psync each. The future resolves `1` once the DONE mark is durable,
    /// `0` if the CAS lost (someone else completed it). Until resolution
    /// a crash may roll the ack back: the job is then PENDING again and
    /// recovery redelivers it — the same at-least-once contract as a
    /// crash between [`Broker::take`] and [`Broker::complete`].
    pub fn ack_async(&self, job: JobId, aq: &AsyncQueue<PerLcrq>) -> ExecFuture {
        let rec = job.0;
        // The lease is dropped INSIDE the combiner closure, i.e. only
        // once the ack actually executes: if the layer is sealed before
        // the op runs (future fails Closed/Crashed), the lease survives
        // and `reap_expired` can still redeliver — dropping it eagerly
        // here would strand a durably-taken, never-acked job until the
        // next crash recovery.
        let leases = if self.lease_ms.load(Ordering::Relaxed) > 0 {
            Some(Arc::clone(&self.leases))
        } else {
            None
        };
        aq.exec_async(move |topo, tid, _plan_epoch| {
            let won = topo.cas(tid, rec.add(0), ST_PENDING, ST_DONE);
            if let Some(leases) = &leases {
                // Executed (won or lost the CAS): the job is no longer
                // "in flight with a silent worker".
                leases.lock().unwrap().remove(&rec.to_u64());
            }
            if won {
                topo.pwb(tid, rec);
                (1, 1u64 << rec.pool)
            } else {
                (0, 0)
            }
        })
    }

    /// Build the async completion layer over this broker's work queue.
    /// Requires a sharded broker ([`Broker::new_sharded`]); spawn the
    /// flusher with [`AsyncQueue::spawn_flusher`] on thread slots disjoint
    /// from the producers'/workers'.
    ///
    /// The layer is wired for **lease-at-resolution**: when a
    /// `take_async` future's consumption becomes durable, the combiner
    /// starts the job's lease *before* the future resolves — a worker
    /// dying between the await and [`Broker::resolve_take`] leaves a
    /// leased, [`Broker::reap_expired`]-recoverable PENDING job instead
    /// of a stranded one (the window the sync-lease design left open).
    pub fn async_layer(&self, cfg: AsyncCfg) -> Result<AsyncQueue<PerLcrq>, QueueError> {
        let Some(sharded) = &self.sharded else {
            return Err(QueueError::BadConfig(
                "async broker paths need the sharded work queue (--queue sharded)",
            ));
        };
        let aq = AsyncQueue::new(Arc::clone(sharded), cfg)?;
        let lease_ms = Arc::clone(&self.lease_ms);
        let leases = Arc::clone(&self.leases);
        aq.set_deq_resolved_hook(Arc::new(move |handle: u64| {
            if lease_ms.load(Ordering::Relaxed) > 0 {
                leases.lock().unwrap().insert(handle, Instant::now());
            }
        }));
        Ok(aq)
    }

    /// Re-shard the work queue **online** to `new_k` stripes (see
    /// [`ShardedQueue::resize`]): an admin operation safe under live
    /// producers, workers and flushers. `tid` must be the caller's
    /// exclusive thread slot. Requires a sharded broker.
    ///
    /// Progress: with epoch-pinned plan access the transition never
    /// blocks an in-flight operation — submits, takes and combiner
    /// flushes keep running through the flip; only this call waits (for
    /// the flip's bounded grace period). The CLI surfaces it as
    /// `persiq resize` and `persiq serve --resize K`, both unchanged.
    pub fn resize(&self, tid: usize, new_k: usize) -> Result<u64, QueueError> {
        let Some(sharded) = &self.sharded else {
            return Err(QueueError::BadConfig(
                "resize needs the sharded work queue (--queue sharded)",
            ));
        };
        sharded.resize(tid, new_k)
    }

    /// Enable (or disable, with 0) per-job leases: a job taken but
    /// neither completed nor acked within `ms` milliseconds is considered
    /// abandoned — its worker died *without* a crash — and
    /// [`Broker::reap_expired`] will re-enqueue it.
    pub fn set_lease_ms(&self, ms: u64) {
        self.lease_ms.store(ms, Ordering::Relaxed);
        if ms == 0 {
            // Disabling drops existing entries too: the removal paths in
            // complete()/ack_async are gated on lease_ms for hot-path
            // cheapness, so entries inserted while leasing was on would
            // otherwise linger and resurface as phantom expired leases
            // if leasing is ever re-enabled.
            self.leases.lock().unwrap().clear();
        }
    }

    /// Re-enqueue every leased job whose lease expired and whose record
    /// is still PENDING (worker death without a crash: nothing else would
    /// ever redeliver it). Returns the number of jobs requeued.
    /// Processing stays at-least-once — if the original worker is merely
    /// slow, both it and the new assignee race [`Broker::complete`]'s CAS
    /// and exactly one wins.
    pub fn reap_expired(&self, tid: usize) -> usize {
        let ms = self.lease_ms.load(Ordering::Relaxed);
        if ms == 0 {
            return 0;
        }
        let now = Instant::now();
        let expired: Vec<u64> = {
            let leases = self.leases.lock().unwrap();
            leases
                .iter()
                .filter(|(_, taken)| now.duration_since(**taken) >= Duration::from_millis(ms))
                .map(|(&h, _)| h)
                .collect()
        };
        let mut requeued = 0;
        for h in expired {
            // Drop the lease first: if the job is re-taken it gets a
            // fresh lease; if it completed meanwhile the entry is stale.
            self.leases.lock().unwrap().remove(&h);
            let rec = GAddr::from_u64(h);
            if self.topo.load(tid, rec.add(0)) == ST_PENDING {
                match self.queue.enqueue(tid, h) {
                    Ok(()) => requeued += 1,
                    Err(_) => {
                        // Queue rejected the re-enqueue (e.g. capacity):
                        // restore the lease so a later reap retries —
                        // dropping it here would strand the job until a
                        // crash recovery.
                        self.leases.lock().unwrap().insert(h, Instant::now());
                    }
                }
            }
        }
        if requeued > 0 {
            // Flush the re-enqueues if the work queue batches (detach is
            // the worker-safe flush entry point).
            self.queue.detach(tid);
            obs::registry()
                .counter(
                    "persiq_broker_leases_reaped_total",
                    "Expired leases whose PENDING job was re-enqueued",
                )
                .add(tid, requeued as u64);
            obs::trace::event(
                tid,
                self.topo.vtime(tid),
                "lease_reap",
                format_args!("\"requeued\":{requeued}"),
            );
        }
        requeued
    }

    /// Outstanding (unexpired or expired, not yet reaped) leases.
    pub fn leases_outstanding(&self) -> usize {
        self.leases.lock().unwrap().len()
    }

    /// Read a job's durable state.
    pub fn state(&self, tid: usize, job: JobId) -> JobState {
        match self.topo.load(tid, job.0.add(0)) {
            ST_PENDING => JobState::Pending,
            ST_DONE => JobState::Done,
            _ => JobState::Unwritten,
        }
    }

    /// Post-crash recovery. Job records need no repair (states are
    /// monotone and persisted at every transition), but the *queue ↔ log*
    /// relation does: a crash inside `submit` — after the durable log
    /// append but before the handle enqueue persisted — or inside a
    /// batched work queue's unflushed enqueue batch can leave a PENDING
    /// job with no queued handle, stranding it forever; symmetrically, a
    /// batched-dequeue work queue whose take was durably logged retires
    /// the handle at queue recovery even when the job never completed.
    /// Recovery therefore reconciles exactly (single-threaded): recover
    /// the queue (which replays its own batch logs across every pool),
    /// drain the recovered handles, re-enqueue the live ones in order,
    /// and re-insert every logged PENDING job whose handle was missing —
    /// walking each thread's submission log on its home pool.
    pub fn recover(&self) {
        // Every psync below — queue recovery, the drain, the re-enqueue
        // backlog and its flushes — is Recovery traffic in the site
        // ledger (batched flushes defer to this ambient scope).
        let _site = obs::enter_site(ObsSite::Recovery);
        let t0 = self.topo.vtime(0);
        // Leases are volatile crash-free-failure state: after a real
        // crash every in-flight job is redelivered by the reconciliation
        // below, so stale leases must not additionally re-enqueue them.
        self.leases.lock().unwrap().clear();
        self.queue.recover(self.topo.primary());
        let tid = 0;
        let mut queued: Vec<u64> = Vec::new();
        while let Ok(Some(h)) = self.queue.dequeue(tid) {
            queued.push(h);
        }
        // Re-enqueue each handle as a thread *homed on the handle's pool*
        // so placement-aware work queues keep recovered jobs socket-local
        // (re-inserting everything as tid 0 would pile the whole backlog
        // onto socket 0's shards under colocate). Recovery is
        // single-threaded and quiescent, so acting as each tid in turn is
        // the same contract as `flush_all`.
        let rep: Vec<usize> = (0..self.topo.len())
            .map(|p| (0..self.nthreads).find(|&t| self.topo.home_pool(t) == p).unwrap_or(0))
            .collect();
        let tid_for = |h: u64| rep[GAddr::from_u64(h).pool as usize % rep.len()];
        let present: std::collections::HashSet<u64> = queued.iter().copied().collect();
        let mut seen = std::collections::HashSet::new();
        for &h in &queued {
            // Drop duplicate handles (earlier at-least-once redeliveries)
            // and handles of already-completed jobs (re-delivered by the
            // recovered queue because the consuming dequeue's persistence
            // raced the crash); take() would skip the latter anyway.
            if seen.insert(h)
                && self.state(tid, JobId(GAddr::from_u64(h))) == JobState::Pending
            {
                let _ = self.queue.enqueue(tid_for(h), h);
            }
        }
        for t in 0..self.nthreads {
            for job in self.submit_log.entries(t) {
                if self.state(tid, job) == JobState::Pending
                    && !present.contains(&job.0.to_u64())
                {
                    let h = job.0.to_u64();
                    let _ = self.queue.enqueue(tid_for(h), h);
                }
            }
        }
        // Flush batched re-enqueues on every slot used (no-op for per-op
        // queues).
        self.queue.quiesce();
        // Broker-level recovery span end (the work queue's own recover
        // emitted the inner span): the re-enqueue flushes above retired.
        obs::flight::record_sealed(
            self.topo.primary(),
            0,
            obs::flight::FlightKind::RecoverEnd,
            self.topo.primary().epoch(),
        );
        obs::trace::span(
            0,
            t0,
            self.topo.vtime(0),
            "broker_recover",
            format_args!("\"drained\":{}", queued.len()),
        );
    }

    /// Flush any thread-buffered queue state (batched handle enqueues).
    /// Quiescent contexts only — see [`PersistentQueue::quiesce`].
    pub fn quiesce(&self) {
        self.queue.quiesce();
    }

    /// A producer/worker thread is about to operate as `tid`: reclaim any
    /// queue state a dead predecessor stranded in the slot (see
    /// [`PersistentQueue::attach`] — on a sharded work queue this flushes
    /// orphaned group-commit batches and reseeds the shard ticket).
    pub fn attach_worker(&self, tid: usize) {
        self.queue.attach(tid);
    }

    /// The thread operating as `tid` is exiting normally: flush its
    /// buffered work-queue batches so nothing it produced or consumed
    /// stays volatile. Safe to call from the worker itself.
    pub fn detach_worker(&self, tid: usize) {
        self.queue.detach(tid);
    }

    /// Audit all jobs found in the persistent submission logs (across
    /// every pool's logs).
    pub fn audit(&self, tid: usize) -> BrokerAudit {
        let mut a = BrokerAudit::default();
        for t in 0..self.nthreads {
            for job in self.submit_log.entries(t) {
                a.submitted += 1;
                match self.state(tid, job) {
                    JobState::Done => a.done += 1,
                    JobState::Pending => a.pending += 1,
                    JobState::Unwritten => a.unwritten += 1,
                }
            }
        }
        a
    }

    /// Dump the SubmitLog ↔ queue reconciliation (`persiq audit`):
    /// drains the work queue, classifies every handle against the job
    /// records, cross-checks the submission logs of every pool for
    /// stranded PENDING jobs, then restores the queue (unique live
    /// handles re-enqueued in drain order). **Quiescent contexts only**
    /// — the drain/re-enqueue is single-threaded, like recovery.
    pub fn reconcile_report(&self, tid: usize) -> ReconcileReport {
        let mut rep = ReconcileReport {
            per_pool_submitted: vec![0; self.topo.len()],
            ..Default::default()
        };
        if let Some(sharded) = &self.sharded {
            rep.plan = (sharded.plan_epoch(), sharded.shard_count());
            rep.draining_plan = sharded.draining_info(tid);
            rep.resize = sharded.resize_stats();
        }
        let mut queued: Vec<u64> = Vec::new();
        while let Ok(Some(h)) = self.queue.dequeue(tid) {
            queued.push(h);
        }
        rep.queued = queued.len();
        let mut seen = std::collections::HashSet::new();
        for &h in &queued {
            let job = JobId(GAddr::from_u64(h));
            if !seen.insert(h) {
                rep.queued_duplicates += 1;
                continue;
            }
            match self.state(tid, job) {
                JobState::Pending => {
                    rep.queued_pending += 1;
                    let _ = self.queue.enqueue(tid, h); // restore
                }
                JobState::Done => rep.queued_done += 1,
                JobState::Unwritten => rep.queued_unwritten += 1,
            }
        }
        self.queue.quiesce();
        // One pass over every pool's submission logs computes the audit
        // counts, the per-pool distribution and the stranded set together
        // (each log entry is read, and each record's state loaded, once).
        // The pool id comes from an append-validated GAddr — entries are
        // single persistent words, so a torn log yields 0 (pool 0,
        // unwritten), never an out-of-range pool.
        for t in 0..self.nthreads {
            for job in self.submit_log.entries(t) {
                rep.audit.submitted += 1;
                rep.per_pool_submitted[job.0.pool as usize] += 1;
                match self.state(tid, job) {
                    JobState::Done => rep.audit.done += 1,
                    JobState::Unwritten => rep.audit.unwritten += 1,
                    JobState::Pending => {
                        rep.audit.pending += 1;
                        if !seen.contains(&job.0.to_u64()) {
                            rep.stranded_pending += 1;
                        }
                    }
                }
            }
        }
        rep
    }

    /// Registry-style metric families: per-state job counts from the
    /// durable submission logs, lease occupancy, and — on a sharded work
    /// queue — a queue-depth estimate plus the queue's own resize/plan
    /// families. Collector-priced (walks the submission logs); call from
    /// exposition paths, not per-op.
    pub fn metric_families(&self, tid: usize) -> Vec<obs::Family> {
        use obs::{Family, Kind, Sample};
        let a = self.audit(tid);
        let state_sample = |s: &str, v: usize| Sample::labelled("state", s, v as f64);
        let mut out = vec![
            Family::scalar(
                "persiq_broker_jobs",
                "Durably submitted jobs by record state",
                Kind::Gauge,
                vec![
                    state_sample("done", a.done),
                    state_sample("pending", a.pending),
                    state_sample("unwritten", a.unwritten),
                ],
            ),
            Family::scalar(
                "persiq_broker_submitted_total",
                "Jobs appended to the submission logs",
                Kind::Counter,
                vec![Sample::plain(a.submitted as f64)],
            ),
            Family::scalar(
                "persiq_broker_leases_outstanding",
                "Taken-but-unresolved jobs currently under lease",
                Kind::Gauge,
                vec![Sample::plain(self.leases_outstanding() as f64)],
            ),
        ];
        if let Some(sharded) = &self.sharded {
            out.push(Family::scalar(
                "persiq_broker_queue_depth",
                "Handles on the work queue (len-hint upper bound, incl. draining residue; \
                 may overcount, never undercounts to 0 while occupied)",
                Kind::Gauge,
                vec![Sample::plain(sharded.depth_hint(tid) as f64)],
            ));
            out.extend(sharded.metric_families(tid));
        }
        out
    }

    /// The underlying queue (observability).
    pub fn queue(&self) -> &dyn PersistentQueue {
        self.queue.as_ref()
    }

    /// The topology this broker addresses (observability).
    pub fn topology(&self) -> &Topology {
        &self.topo
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn pmem_cfg() -> PmemConfig {
        PmemConfig {
            capacity_words: 1 << 21,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 3,
        }
    }

    fn mk() -> (Arc<PmemPool>, Broker) {
        let pool = Arc::new(PmemPool::new(pmem_cfg()));
        let b = Broker::new(&pool, 4, 4096, 256);
        (pool, b)
    }

    #[test]
    fn submit_take_complete_roundtrip() {
        let (_p, b) = mk();
        let id = b.submit(0, b"hello world").unwrap();
        assert_eq!(b.state(0, id), JobState::Pending);
        let (jid, payload) = b.take(1).unwrap().unwrap();
        assert_eq!(jid, id);
        assert_eq!(&payload, b"hello world");
        assert!(b.complete(1, jid).unwrap());
        assert_eq!(b.state(0, id), JobState::Done);
        assert!(b.take(1).unwrap().is_none());
    }

    #[test]
    fn complete_is_exactly_once() {
        let (_p, b) = mk();
        let id = b.submit(0, b"x").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert!(b.complete(1, jid).unwrap());
        assert!(!b.complete(2, id).unwrap(), "second completion must lose the CAS");
    }

    #[test]
    fn fifo_delivery() {
        let (_p, b) = mk();
        for i in 0..20u8 {
            b.submit(0, &[i]).unwrap();
        }
        for i in 0..20u8 {
            let (_, payload) = b.take(1).unwrap().unwrap();
            assert_eq!(payload, vec![i]);
        }
    }

    #[test]
    fn submitted_jobs_survive_crash() {
        let (p, b) = mk();
        let mut ids = Vec::new();
        for i in 0..10u8 {
            ids.push(b.submit(0, &[i, i, i]).unwrap());
        }
        // Consume + complete a few.
        for _ in 0..4 {
            let (jid, _) = b.take(1).unwrap().unwrap();
            b.complete(1, jid).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        b.recover();
        let audit = b.audit(0);
        assert_eq!(audit.submitted, 10);
        assert_eq!(audit.done, 4);
        assert_eq!(audit.pending, 6);
        // Remaining jobs are still deliverable, in order.
        let mut remaining = Vec::new();
        while let Some((jid, payload)) = b.take(0).unwrap() {
            remaining.push(payload[0]);
            b.complete(0, jid).unwrap();
        }
        assert_eq!(remaining, vec![4, 5, 6, 7, 8, 9]);
        assert_eq!(b.audit(0).done, 10);
    }

    #[test]
    fn done_jobs_not_redelivered_after_crash() {
        // Crash AFTER completion but potentially before the dequeue's head
        // persist: the handle may be re-delivered by the recovered queue,
        // but take() must skip DONE records.
        let (p, b) = mk();
        let id = b.submit(0, b"once").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert_eq!(jid, id);
        b.complete(1, jid).unwrap();
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        b.recover();
        assert!(b.take(0).unwrap().is_none(), "DONE job must not be re-delivered");
        assert_eq!(b.audit(0).done, 1);
    }

    #[test]
    fn payload_too_large_rejected() {
        let (_p, b) = mk();
        assert!(b.submit(0, &[0u8; MAX_PAYLOAD + 1]).is_err());
    }

    #[test]
    fn multi_pool_records_live_on_home_pools() {
        let topo = Topology::new(pmem_cfg(), 2);
        let b = Broker::new_sharded(
            &topo,
            4,
            4096,
            QueueConfig { shards: 2, ring_size: 256, ..Default::default() },
        )
        .unwrap();
        // Producer 0 homes on pool 0, producer 1 on pool 1.
        let id0 = b.submit(0, b"zero").unwrap();
        let id1 = b.submit(1, b"one").unwrap();
        assert_eq!(id0.0.pool, 0);
        assert_eq!(id1.0.pool, 1);
        // Handles round-trip through the queue's u64 items.
        let mut got = Vec::new();
        while let Some((jid, payload)) = b.take(2).unwrap() {
            got.push((jid, payload));
            b.complete(2, jid).unwrap();
        }
        got.sort_by_key(|(jid, _)| jid.0.pool);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].1, b"zero");
        assert_eq!(got[1].1, b"one");
    }

    #[test]
    fn multi_pool_crash_recovery_walks_all_pools() {
        let topo = Topology::new(
            PmemConfig {
                capacity_words: 1 << 21,
                cost: CostModel::zero(),
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 8,
            },
            2,
        );
        let b = Broker::new_sharded(
            &topo,
            4,
            4096,
            QueueConfig { shards: 2, batch: 4, ring_size: 256, ..Default::default() },
        )
        .unwrap();
        // Submissions from both home pools, some with unflushed handle
        // batches (batch = 4: the handles sit in an unsealed batch, but
        // the submit logs are durable — recovery must re-enqueue from
        // the logs of BOTH pools).
        for i in 0..6u8 {
            b.submit(0, &[i]).unwrap();
            b.submit(1, &[100 + i]).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(4);
        topo.crash(&mut rng);
        b.recover();
        let audit = b.audit(0);
        assert_eq!(audit.submitted, 12);
        assert_eq!(audit.pending, 12);
        let mut got = Vec::new();
        while let Some((jid, payload)) = b.take(0).unwrap() {
            got.push(payload[0]);
            b.complete(0, jid).unwrap();
        }
        got.sort_unstable();
        assert_eq!(
            got,
            vec![0, 1, 2, 3, 4, 5, 100, 101, 102, 103, 104, 105],
            "recovery must restore every durably submitted job from both pools"
        );
        let rep = b.reconcile_report(0);
        assert_eq!(rep.mismatches(), 0);
        assert_eq!(rep.audit.done, 12);
    }

    #[test]
    fn lease_expiry_requeues_abandoned_job() {
        let (_p, b) = mk();
        b.set_lease_ms(1);
        let id = b.submit(0, b"leased").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert_eq!(jid, id);
        assert_eq!(b.leases_outstanding(), 1);
        // Worker 1 "dies" silently (no crash, no complete): the queue is
        // empty and nothing but the lease can ever redeliver the job.
        assert!(b.take(2).unwrap().is_none());
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(b.reap_expired(3), 1, "expired lease must requeue the job");
        let (jid2, payload) = b.take(2).unwrap().unwrap();
        assert_eq!(jid2, id);
        assert_eq!(&payload, b"leased");
        assert!(b.complete(2, jid2).unwrap());
        assert_eq!(b.reap_expired(3), 0, "completed job must not be reaped");
        assert_eq!(b.leases_outstanding(), 0);
    }

    #[test]
    fn unexpired_lease_is_left_alone() {
        let (_p, b) = mk();
        b.set_lease_ms(60_000);
        b.submit(0, b"slow").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert_eq!(b.reap_expired(2), 0, "live lease must not redeliver");
        assert!(b.take(2).unwrap().is_none());
        assert!(b.complete(1, jid).unwrap());
    }

    #[test]
    fn async_submit_take_ack_roundtrip() {
        use crate::queues::asyncq::AsyncCfg;
        let topo = Topology::new(pmem_cfg(), 2);
        let b = Broker::new_sharded(
            &topo,
            6,
            4096,
            QueueConfig { shards: 2, batch: 4, batch_deq: 2, ring_size: 256, ..Default::default() },
        )
        .unwrap();
        let aq = b
            .async_layer(AsyncCfg { flush_us: 500, depth: 8, flushers: 1 })
            .unwrap();
        let fl = aq.spawn_flusher(4); // producers/workers use tids 0..4
        let mut futs = Vec::new();
        for i in 0..6u8 {
            let (id, f) = b.submit_async(0, &[i], &aq).unwrap();
            futs.push((id, f));
        }
        for (_, f) in futs {
            f.wait().unwrap();
        }
        let mut acks = Vec::new();
        while acks.len() < 6 {
            match b.take_async(&aq).wait().unwrap() {
                Some(h) => {
                    let (jid, payload) =
                        b.resolve_take(1, h).expect("no stale handles in this run");
                    assert_eq!(payload.len(), 1);
                    acks.push(b.ack_async(jid, &aq));
                }
                None => std::thread::yield_now(),
            }
        }
        for a in acks {
            assert_eq!(a.wait(), Ok(1), "ack must win its CAS exactly once");
        }
        fl.stop();
        assert_eq!(b.audit(0).done, 6);
        assert!(b.take(1).unwrap().is_none());
        assert_eq!(b.reconcile_report(0).mismatches(), 0);
    }

    #[test]
    fn async_layer_requires_sharded_queue() {
        use crate::queues::asyncq::AsyncCfg;
        let (_p, b) = mk(); // plain PerLCRQ broker
        assert!(matches!(
            b.async_layer(AsyncCfg::default()),
            Err(QueueError::BadConfig(_))
        ));
    }

    #[test]
    fn reconcile_report_counts_and_restores() {
        let (p, b) = mk();
        for i in 0..5u8 {
            b.submit(0, &[i]).unwrap();
        }
        let (jid, _) = b.take(1).unwrap().unwrap();
        b.complete(1, jid).unwrap();
        let rep = b.reconcile_report(0);
        assert_eq!(rep.audit.submitted, 5);
        assert_eq!(rep.audit.done, 1);
        assert_eq!(rep.audit.pending, 4);
        assert_eq!(rep.queued, 4);
        assert_eq!(rep.queued_pending, 4);
        assert_eq!(rep.mismatches(), 0);
        assert_eq!(rep.per_pool_submitted, vec![5]);
        // The report must not consume the queue: all 4 still deliverable.
        let mut n = 0;
        while let Some((jid, _)) = b.take(0).unwrap() {
            b.complete(0, jid).unwrap();
            n += 1;
        }
        assert_eq!(n, 4, "reconcile_report must restore the queue");
        // And post-crash, post-recovery the invariants hold too.
        let mut rng = Xoshiro256::seed_from(6);
        p.crash(&mut rng);
        b.recover();
        assert_eq!(b.reconcile_report(0).mismatches(), 0);
    }
}
