//! Broker data plane: persistent job records + the PerLCRQ work queue.
//!
//! Job record = one cache line in the pool:
//! `[state][len][payload x 6]` — state ∈ {PENDING=1, DONE=2} (0 means the
//! slot was never written; records are created PENDING and persisted
//! before their handle is enqueued). Payloads up to 48 bytes inline (the
//! broker is a control-plane component; bulk data would live elsewhere).

use std::sync::Arc;

use anyhow::Result;

use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};
use crate::queues::perlcrq::PerLcrq;
use crate::queues::sharded::ShardedQueue;
use crate::queues::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError};

/// Max payload bytes per job (6 words inline).
pub const MAX_PAYLOAD: usize = 48;

const ST_PENDING: u64 = 1;
const ST_DONE: u64 = 2;

/// A durable job handle (the record's pool address).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct JobId(pub PAddr);

/// Decoded job state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Unwritten,
    Pending,
    Done,
}

/// The persistent broker. The work queue is any [`PersistentQueue`] —
/// PerLCRQ by default ([`Broker::new`]) or the sharded/batched layer
/// ([`Broker::new_sharded`]) for contention-heavy deployments.
pub struct Broker {
    pool: Arc<PmemPool>,
    queue: Arc<dyn PersistentQueue>,
    /// All records ever allocated (audit; order = submission order per
    /// thread). Volatile — rebuilt by audits via the submission log below.
    submit_log: SubmitLog,
    nthreads: usize,
}

/// Persistent per-thread submission logs so audits survive crashes:
/// each thread `t` owns a line-aligned region `[count][jobs...]`; `count`
/// is persisted after each appended handle.
struct SubmitLog {
    base: Vec<PAddr>,
    cap: usize,
}

impl SubmitLog {
    fn alloc(pool: &PmemPool, nthreads: usize, cap: usize) -> Self {
        let base: Vec<PAddr> = (0..nthreads)
            .map(|_| {
                pool.alloc(
                    (cap + WORDS_PER_LINE).next_multiple_of(WORDS_PER_LINE),
                    WORDS_PER_LINE,
                )
            })
            .collect();
        // Each log is written by exactly one thread (SWSR).
        for &b in &base {
            pool.set_hot(b, cap + WORDS_PER_LINE, crate::pmem::Hotness::Private);
        }
        Self { base, cap }
    }

    fn append(&self, pool: &PmemPool, tid: usize, job: JobId) {
        let b = self.base[tid];
        let n = pool.load(tid, b);
        assert!((n as usize) < self.cap, "submission log full; raise capacity");
        pool.store(tid, b.add(1 + n as usize), job.0.to_u64());
        pool.store(tid, b, n + 1);
        // One line flush covers count+early entries; entry line may differ.
        pool.pwb(tid, b.add(1 + n as usize));
        pool.pwb(tid, b);
        pool.psync(tid);
    }

    fn entries(&self, pool: &PmemPool, tid: usize) -> Vec<JobId> {
        let b = self.base[tid];
        let n = pool.load(tid, b) as usize;
        (0..n).map(|i| JobId(PAddr::from_u64(pool.load(tid, b.add(1 + i))))).collect()
    }
}

/// Result of a post-crash audit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BrokerAudit {
    pub submitted: usize,
    pub done: usize,
    pub pending: usize,
    /// Jobs whose record was never durably written (submission incomplete
    /// at crash — allowed to vanish).
    pub unwritten: usize,
}

impl Broker {
    /// Create a broker for `nthreads` workers+producers, able to hold
    /// `max_jobs` job records.
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, max_jobs: usize, ring: usize) -> Broker {
        let cfg = QueueConfig { ring_size: ring, ..Default::default() };
        Broker {
            queue: Arc::new(PerLcrq::new(pool, nthreads, cfg)),
            submit_log: SubmitLog::alloc(pool, nthreads, max_jobs),
            pool: Arc::clone(pool),
            nthreads,
        }
    }

    /// Create a broker running on the sharded (optionally batched) work
    /// queue — `cfg.shards` / `cfg.batch` / `cfg.batch_deq` select the
    /// striping and group-commit parameters. With `batch_deq > 1` the
    /// **ack path rides the work queue's dequeue log**: every handle a
    /// worker takes is recorded in a per-thread persistent dequeue log
    /// and group-committed once per `batch_deq` takes, so
    /// [`Broker::recover`]'s queue↔SubmitLog reconciliation stays exact —
    /// a durably-logged take is never redelivered (its position is
    /// retired at recovery), an unlogged take is redelivered and filtered
    /// by the DONE-state check in [`Broker::take`], and a logged take
    /// whose job never completed is re-enqueued from the SubmitLog.
    /// Fails with [`QueueError::BadConfig`] on an invalid configuration.
    pub fn new_sharded(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        max_jobs: usize,
        cfg: QueueConfig,
    ) -> Result<Broker, QueueError> {
        Ok(Broker {
            queue: Arc::new(ShardedQueue::new_perlcrq(pool, nthreads, cfg)?),
            submit_log: SubmitLog::alloc(pool, nthreads, max_jobs),
            pool: Arc::clone(pool),
            nthreads,
        })
    }

    /// Submit a job: durably write the record, log it, enqueue its handle.
    /// On return the job is guaranteed to survive any crash.
    pub fn submit(&self, tid: usize, payload: &[u8]) -> Result<JobId> {
        anyhow::ensure!(payload.len() <= MAX_PAYLOAD, "payload too large");
        let p = &self.pool;
        let rec = p.alloc_lines(1);
        p.store(tid, rec.add(1), payload.len() as u64);
        for (i, chunk) in payload.chunks(8).enumerate() {
            let mut w = [0u8; 8];
            w[..chunk.len()].copy_from_slice(chunk);
            p.store(tid, rec.add(2 + i), u64::from_le_bytes(w));
        }
        p.store(tid, rec.add(0), ST_PENDING);
        // Record durable before it becomes reachable.
        p.pwb(tid, rec);
        p.psync(tid);
        self.submit_log.append(p, tid, JobId(rec));
        self.queue.enqueue(tid, rec.to_u64())?;
        Ok(JobId(rec))
    }

    /// Take the next job (its payload), or `None` when the queue is empty.
    /// The job stays PENDING until [`Broker::complete`] — a crash between
    /// take and complete re-delivers it after recovery (at-least-once on
    /// *processing*, exactly-once on *completion*).
    pub fn take(&self, tid: usize) -> Result<Option<(JobId, Vec<u8>)>> {
        loop {
            let Some(handle) = self.queue.dequeue(tid)? else {
                return Ok(None);
            };
            let rec = PAddr::from_u64(handle);
            let p = &self.pool;
            match p.load(tid, rec.add(0)) {
                ST_PENDING => {
                    let len = p.load(tid, rec.add(1)) as usize;
                    let mut payload = vec![0u8; len.min(MAX_PAYLOAD)];
                    for (i, chunk) in payload.chunks_mut(8).enumerate() {
                        let w = p.load(tid, rec.add(2 + i)).to_le_bytes();
                        chunk.copy_from_slice(&w[..chunk.len()]);
                    }
                    return Ok(Some((JobId(rec), payload)));
                }
                // DONE: completed in a previous epoch but re-delivered by a
                // recovered queue (the dequeue that removed it never
                // persisted) — skip.
                ST_DONE => continue,
                // Unwritten record: handle enqueued but record lost — can
                // only happen for submissions that never returned; skip.
                _ => continue,
            }
        }
    }

    /// Durably mark a job done (exactly-once: a CAS guards the state
    /// transition; the flush makes it crash-proof).
    pub fn complete(&self, tid: usize, job: JobId) -> Result<bool> {
        let p = &self.pool;
        let won = p.cas(tid, job.0.add(0), ST_PENDING, ST_DONE);
        if won {
            p.pwb(tid, job.0);
            p.psync(tid);
        }
        Ok(won)
    }

    /// Read a job's durable state.
    pub fn state(&self, tid: usize, job: JobId) -> JobState {
        match self.pool.load(tid, job.0.add(0)) {
            ST_PENDING => JobState::Pending,
            ST_DONE => JobState::Done,
            _ => JobState::Unwritten,
        }
    }

    /// Post-crash recovery. Job records need no repair (states are
    /// monotone and persisted at every transition), but the *queue ↔ log*
    /// relation does: a crash inside `submit` — after the durable log
    /// append but before the handle enqueue persisted — or inside a
    /// batched work queue's unflushed enqueue batch can leave a PENDING
    /// job with no queued handle, stranding it forever; symmetrically, a
    /// batched-dequeue work queue whose take was durably logged retires
    /// the handle at queue recovery even when the job never completed.
    /// Recovery therefore reconciles exactly (single-threaded): recover
    /// the queue (which replays its own batch logs), drain the recovered
    /// handles, re-enqueue the live ones in order, and re-insert every
    /// logged PENDING job whose handle was missing.
    pub fn recover(&self) {
        self.queue.recover(&self.pool);
        let tid = 0;
        let mut queued: Vec<u64> = Vec::new();
        while let Ok(Some(h)) = self.queue.dequeue(tid) {
            queued.push(h);
        }
        let present: std::collections::HashSet<u64> = queued.iter().copied().collect();
        let mut seen = std::collections::HashSet::new();
        for &h in &queued {
            // Drop duplicate handles (earlier at-least-once redeliveries)
            // and handles of already-completed jobs (re-delivered by the
            // recovered queue because the consuming dequeue's persistence
            // raced the crash); take() would skip the latter anyway.
            if seen.insert(h)
                && self.state(tid, JobId(PAddr::from_u64(h))) == JobState::Pending
            {
                let _ = self.queue.enqueue(tid, h);
            }
        }
        for t in 0..self.nthreads {
            for job in self.submit_log.entries(&self.pool, t) {
                if self.state(tid, job) == JobState::Pending
                    && !present.contains(&job.0.to_u64())
                {
                    let _ = self.queue.enqueue(tid, job.0.to_u64());
                }
            }
        }
        // Flush batched re-enqueues (no-op for per-op queues).
        self.queue.quiesce();
    }

    /// Flush any thread-buffered queue state (batched handle enqueues).
    /// Quiescent contexts only — see [`PersistentQueue::quiesce`].
    pub fn quiesce(&self) {
        self.queue.quiesce();
    }

    /// A producer/worker thread is about to operate as `tid`: reclaim any
    /// queue state a dead predecessor stranded in the slot (see
    /// [`PersistentQueue::attach`] — on a sharded work queue this flushes
    /// orphaned group-commit batches and reseeds the shard ticket).
    pub fn attach_worker(&self, tid: usize) {
        self.queue.attach(tid);
    }

    /// The thread operating as `tid` is exiting normally: flush its
    /// buffered work-queue batches so nothing it produced or consumed
    /// stays volatile. Safe to call from the worker itself.
    pub fn detach_worker(&self, tid: usize) {
        self.queue.detach(tid);
    }

    /// Audit all jobs found in the persistent submission logs.
    pub fn audit(&self, tid: usize) -> BrokerAudit {
        let mut a = BrokerAudit::default();
        for t in 0..self.nthreads {
            for job in self.submit_log.entries(&self.pool, t) {
                a.submitted += 1;
                match self.state(tid, job) {
                    JobState::Done => a.done += 1,
                    JobState::Pending => a.pending += 1,
                    JobState::Unwritten => a.unwritten += 1,
                }
            }
        }
        a
    }

    /// The underlying queue (observability).
    pub fn queue(&self) -> &dyn PersistentQueue {
        self.queue.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk() -> (Arc<PmemPool>, Broker) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 21,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 3,
        }));
        let b = Broker::new(&pool, 4, 4096, 256);
        (pool, b)
    }

    #[test]
    fn submit_take_complete_roundtrip() {
        let (_p, b) = mk();
        let id = b.submit(0, b"hello world").unwrap();
        assert_eq!(b.state(0, id), JobState::Pending);
        let (jid, payload) = b.take(1).unwrap().unwrap();
        assert_eq!(jid, id);
        assert_eq!(&payload, b"hello world");
        assert!(b.complete(1, jid).unwrap());
        assert_eq!(b.state(0, id), JobState::Done);
        assert!(b.take(1).unwrap().is_none());
    }

    #[test]
    fn complete_is_exactly_once() {
        let (_p, b) = mk();
        let id = b.submit(0, b"x").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert!(b.complete(1, jid).unwrap());
        assert!(!b.complete(2, id).unwrap(), "second completion must lose the CAS");
    }

    #[test]
    fn fifo_delivery() {
        let (_p, b) = mk();
        for i in 0..20u8 {
            b.submit(0, &[i]).unwrap();
        }
        for i in 0..20u8 {
            let (_, payload) = b.take(1).unwrap().unwrap();
            assert_eq!(payload, vec![i]);
        }
    }

    #[test]
    fn submitted_jobs_survive_crash() {
        let (p, b) = mk();
        let mut ids = Vec::new();
        for i in 0..10u8 {
            ids.push(b.submit(0, &[i, i, i]).unwrap());
        }
        // Consume + complete a few.
        for _ in 0..4 {
            let (jid, _) = b.take(1).unwrap().unwrap();
            b.complete(1, jid).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        b.recover();
        let audit = b.audit(0);
        assert_eq!(audit.submitted, 10);
        assert_eq!(audit.done, 4);
        assert_eq!(audit.pending, 6);
        // Remaining jobs are still deliverable, in order.
        let mut remaining = Vec::new();
        while let Some((jid, payload)) = b.take(0).unwrap() {
            remaining.push(payload[0]);
            b.complete(0, jid).unwrap();
        }
        assert_eq!(remaining, vec![4, 5, 6, 7, 8, 9]);
        assert_eq!(b.audit(0).done, 10);
    }

    #[test]
    fn done_jobs_not_redelivered_after_crash() {
        // Crash AFTER completion but potentially before the dequeue's head
        // persist: the handle may be re-delivered by the recovered queue,
        // but take() must skip DONE records.
        let (p, b) = mk();
        let id = b.submit(0, b"once").unwrap();
        let (jid, _) = b.take(1).unwrap().unwrap();
        assert_eq!(jid, id);
        b.complete(1, jid).unwrap();
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        b.recover();
        assert!(b.take(0).unwrap().is_none(), "DONE job must not be re-delivered");
        assert_eq!(b.audit(0).done, 1);
    }

    #[test]
    fn payload_too_large_rejected() {
        let (_p, b) = mk();
        assert!(b.submit(0, &[0u8; MAX_PAYLOAD + 1]).is_err());
    }
}
