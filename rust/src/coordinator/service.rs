//! Broker service orchestration: producer/worker pools, crash cycles, and
//! the end-to-end report (`examples/task_broker` and `persiq serve`).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::obs;
use crate::pmem::{run_guarded, Topology};
use crate::queues::asyncq::{AsyncCfg, AsyncQueue, ExecFuture};
use crate::util::rng::Xoshiro256;
use crate::util::time::Stopwatch;

use super::broker::Broker;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub producers: usize,
    pub workers: usize,
    /// Jobs each producer submits per epoch.
    pub jobs_per_producer: usize,
    /// Crash/recovery cycles to run (0 = single run, no crash).
    pub crash_cycles: usize,
    /// pmem-primitive steps before each crash.
    pub crash_steps: u64,
    pub seed: u64,
    /// Serve through the async completion layer: producers hold windows
    /// of `submit_async` futures, workers `take_async`/`ack_async`, and
    /// all queue persistence rides the flusher's group commit. Requires
    /// a sharded broker.
    pub use_async: bool,
    /// Async-layer knobs (`--flush-us` / `--async-depth` / `--flushers`);
    /// only read when `use_async`.
    pub acfg: AsyncCfg,
    /// Per-job lease in ms (0 = off): jobs taken by a worker that dies
    /// silently are re-enqueued by a reap pass (see
    /// [`Broker::reap_expired`]).
    pub lease_ms: u64,
    /// Online re-shard target (0 = off): during the FIRST cycle an admin
    /// thread resizes the work queue to this stripe count while
    /// producers/workers (and flushers, in async mode) are live —
    /// `persiq serve --resize` / `persiq resize`. Requires a sharded
    /// broker and one extra thread slot ([`ServiceConfig::admin_tid`]).
    pub resize_to: usize,
    /// The admin thread's exclusive queue tid (used only when
    /// `resize_to > 0`); callers must size the broker's `nthreads` past
    /// it.
    pub admin_tid: usize,
    /// Print a Prometheus-text metrics dump every N cycles (0 = off):
    /// `persiq serve --metrics-every N`. Emission happens at cycle
    /// boundaries, after every worker joined, so the durable-record
    /// reads race nothing.
    pub metrics_every: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            producers: 2,
            workers: 2,
            jobs_per_producer: 500,
            crash_cycles: 0,
            crash_steps: 50_000,
            seed: 0xB40C,
            use_async: false,
            acfg: AsyncCfg::default(),
            lease_ms: 0,
            resize_to: 0,
            admin_tid: 0,
            metrics_every: 0,
        }
    }
}

/// One Prometheus-text dump of every metrics surface the service stack
/// exposes — global registry, pmem topology, broker (+ its sharded
/// queue), the async layer when live, and the psync-by-site ledger.
fn emit_metrics(topo: &Topology, broker: &Broker, aq: Option<&AsyncQueue>, cycle: usize) {
    let mut fams = obs::registry().families();
    fams.extend(topo.metric_families());
    fams.extend(broker.metric_families(0));
    if let Some(aq) = aq {
        fams.extend(aq.metric_families());
    }
    fams.extend(obs::ledger_families(&topo.site_ledger()));
    println!("# persiq serve metrics, cycle {cycle}");
    print!("{}", obs::render(&fams));
}

/// Spawn the one-shot resize admin thread (first cycle only): waits a
/// beat so real traffic is in flight, then re-shards online on its own
/// exclusive tid. Best-effort — a crash unwinds it (recovery converges
/// the plan), and a still-draining transition is retried briefly.
fn spawn_resizer(
    broker: &Arc<Broker>,
    cfg: &ServiceConfig,
) -> Option<std::thread::JoinHandle<()>> {
    if cfg.resize_to == 0 {
        return None;
    }
    let broker = Arc::clone(broker);
    let (tid, new_k) = (cfg.admin_tid, cfg.resize_to);
    Some(std::thread::spawn(move || {
        let _ = run_guarded(|| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            for attempt in 0..50 {
                match broker.resize(tid, new_k) {
                    Ok(_) => break,
                    // Only a still-draining previous transition is worth
                    // retrying; anything else (bad k, non-sharded queue)
                    // is permanent and must be surfaced, not swallowed.
                    Err(e) => {
                        let retryable = e.to_string().contains("draining");
                        if !retryable || attempt == 49 {
                            crate::log_warn!("serve: online resize to {new_k} failed: {e}");
                            if !retryable {
                                break;
                            }
                        }
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
            }
        });
    }))
}

/// End-to-end service report.
#[derive(Clone, Debug, Default)]
pub struct ServiceReport {
    pub submitted: usize,
    pub processed: u64,
    pub done: usize,
    pub pending_after: usize,
    pub crashes: usize,
    pub wall_secs: f64,
    /// Per-job processing latency samples (simulated ns; for the metrics
    /// pipeline).
    pub latency_samples: Vec<f64>,
}

/// Run the broker service end-to-end: per cycle, producers submit and
/// workers drain; a crash interrupts mid-flight; recovery resumes; after
/// the last cycle workers drain everything left. The final audit must show
/// every submitted job done exactly once.
pub fn run_service(
    topo: &Topology,
    broker: &Arc<Broker>,
    cfg: &ServiceConfig,
) -> Result<ServiceReport> {
    if cfg.lease_ms > 0 {
        broker.set_lease_ms(cfg.lease_ms);
    }
    if cfg.use_async {
        return run_service_async(topo, broker, cfg);
    }
    let sw = Stopwatch::start();
    let mut rng = Xoshiro256::seed_from(cfg.seed);
    let processed = Arc::new(AtomicU64::new(0));
    let samples = Arc::new(std::sync::Mutex::new(Vec::new()));
    let cycles = cfg.crash_cycles.max(1);
    let mut crashes = 0;

    for cycle in 0..cycles {
        let crashing = cfg.crash_cycles > 0;
        if crashing {
            topo.arm_crash_after(cfg.crash_steps);
        }
        let mut handles = Vec::new();
        // Producers: tids [0, producers).
        for ptid in 0..cfg.producers {
            let broker = Arc::clone(broker);
            let jobs = cfg.jobs_per_producer;
            handles.push(std::thread::spawn(move || {
                let _ = run_guarded(|| {
                    broker.attach_worker(ptid);
                    for i in 0..jobs {
                        let payload =
                            format!("job:c{cycle}:p{ptid}:{i}").into_bytes();
                        broker.submit(ptid, &payload[..payload.len().min(48)]).unwrap();
                    }
                    // Normal exit: flush buffered handle enqueues. (A
                    // crash unwinds past this; recovery reconciles.)
                    broker.detach_worker(ptid);
                });
            }));
        }
        // Workers: tids [producers, producers+workers). The exit target
        // is cumulative across cycles (`processed` never resets), so
        // later cycles keep their workers draining instead of exiting on
        // the first empty poll.
        let total_target = cfg.producers * cfg.jobs_per_producer * (cycle + 1);
        for w in 0..cfg.workers {
            let broker = Arc::clone(broker);
            let topo = topo.clone();
            let processed = Arc::clone(&processed);
            let samples = Arc::clone(&samples);
            let wtid = cfg.producers + w;
            handles.push(std::thread::spawn(move || {
                let mut my_samples = Vec::new();
                let _ = run_guarded(|| {
                    broker.attach_worker(wtid);
                    let mut idle = 0u32;
                    // Drain until the queue stays empty (producers done)
                    // or the epoch target is safely exceeded.
                    while idle < 2_000 {
                        let t0 = topo.vtime(wtid);
                        match broker.take(wtid).unwrap() {
                            Some((jid, _payload)) => {
                                idle = 0;
                                // "Process": the completion transition is
                                // the work product.
                                if broker.complete(wtid, jid).unwrap() {
                                    processed.fetch_add(1, Ordering::Relaxed);
                                    my_samples.push((topo.vtime(wtid) - t0) as f64);
                                }
                            }
                            None => {
                                idle += 1;
                                if processed.load(Ordering::Relaxed)
                                    >= total_target as u64
                                {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    // Normal exit: flush this worker's buffered dequeue
                    // log. (A crash unwinds past this; recovery
                    // reconciles.)
                    broker.detach_worker(wtid);
                });
                samples.lock().unwrap().extend(my_samples);
            }));
        }
        if cycle == 0 {
            if let Some(h) = spawn_resizer(broker, cfg) {
                handles.push(h);
            }
        }
        for h in handles {
            h.join().expect("service thread panicked");
        }
        if cfg.metrics_every > 0 && (cycle + 1) % cfg.metrics_every == 0 {
            emit_metrics(topo, broker, None, cycle);
        }
        if crashing {
            topo.crash(&mut rng);
            broker.recover();
            crashes += 1;
        }
    }

    let latency_samples = std::mem::take(&mut *samples.lock().unwrap());
    finish_service(broker, &processed, crashes, &sw, latency_samples)
}

/// The shared tail of both serve paths: reap expired leases (no-op when
/// leasing is off) so jobs abandoned by a silently-dead worker are
/// requeued, flush any thread-buffered handle enqueues (batched work
/// queues), drain + complete whatever survived, and assemble the report
/// from the final audit.
fn finish_service(
    broker: &Arc<Broker>,
    processed: &AtomicU64,
    crashes: usize,
    sw: &Stopwatch,
    latency_samples: Vec<f64>,
) -> Result<ServiceReport> {
    broker.reap_expired(0);
    broker.quiesce();
    while let Some((jid, _)) = broker.take(0)? {
        if broker.complete(0, jid)? {
            processed.fetch_add(1, Ordering::Relaxed);
        }
    }
    let audit = broker.audit(0);
    Ok(ServiceReport {
        submitted: audit.submitted,
        processed: processed.load(Ordering::Relaxed),
        done: audit.done,
        pending_after: audit.pending,
        crashes,
        wall_secs: sw.elapsed_secs(),
        latency_samples,
    })
}

/// The async serve path: producers hold a window of `submit_async`
/// futures (job records are still written durably on their own tids),
/// workers pipeline `take_async` deliveries into `ack_async` windows, and
/// every queue/ack psync is group-committed by the flusher workers on
/// thread slots `producers + workers ..`. Durability-gated completion
/// means a resolved submit future is a crash-proof job and a resolved
/// ack is a crash-proof completion — the exactly-once audit at the end
/// is identical to the sync path's.
fn run_service_async(
    topo: &Topology,
    broker: &Arc<Broker>,
    cfg: &ServiceConfig,
) -> Result<ServiceReport> {
    let sw = Stopwatch::start();
    let mut rng = Xoshiro256::seed_from(cfg.seed);
    let processed = Arc::new(AtomicU64::new(0));
    let cycles = cfg.crash_cycles.max(1);
    let mut crashes = 0;
    // Window per producer/worker: deep enough to overlap a few group
    // commits, small enough to bound in-flight state.
    let window = cfg.acfg.depth.clamp(4, 256);

    for cycle in 0..cycles {
        let crashing = cfg.crash_cycles > 0;
        if crashing {
            topo.arm_crash_after(cfg.crash_steps);
        }
        // A fresh async layer per cycle: a crash seals the previous one.
        let aq = broker.async_layer(cfg.acfg.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
        let flusher = aq.spawn_flusher(cfg.producers + cfg.workers);
        let mut handles = Vec::new();
        // Producers: tids [0, producers).
        for ptid in 0..cfg.producers {
            let broker = Arc::clone(broker);
            let aq = aq.clone();
            let jobs = cfg.jobs_per_producer;
            handles.push(std::thread::spawn(move || {
                let _ = run_guarded(|| {
                    let mut pending = VecDeque::with_capacity(window + 1);
                    for i in 0..jobs {
                        if aq.is_closed() {
                            break;
                        }
                        let payload = format!("job:c{cycle}:p{ptid}:{i}").into_bytes();
                        let (_id, fut) = broker
                            .submit_async(ptid, &payload[..payload.len().min(48)], &aq)
                            .unwrap();
                        pending.push_back(fut);
                        if pending.len() >= window {
                            // Await the oldest; a crash error ends the
                            // epoch (recovery re-enqueues from the logs).
                            if pending.pop_front().unwrap().wait().is_err() {
                                break;
                            }
                        }
                    }
                    while let Some(f) = pending.pop_front() {
                        let _ = f.wait();
                    }
                });
            }));
        }
        // Workers: tids [producers, producers+workers). Cumulative target
        // (see the sync path): later cycles must keep draining the
        // recovered backlog through the async take/ack path.
        let total_target = cfg.producers * cfg.jobs_per_producer * (cycle + 1);
        for w in 0..cfg.workers {
            let broker = Arc::clone(broker);
            let aq = aq.clone();
            let processed = Arc::clone(&processed);
            let wtid = cfg.producers + w;
            handles.push(std::thread::spawn(move || {
                let _ = run_guarded(|| {
                    let mut acks: VecDeque<ExecFuture> = VecDeque::with_capacity(window + 1);
                    // Pop resolved acks from the front (and, when the
                    // window is full, block on the oldest) — pipelined
                    // completion instead of a per-job psync wait.
                    let settle = |acks: &mut VecDeque<ExecFuture>, blocking: usize| {
                        while acks.len() > blocking
                            || acks.front().is_some_and(|a| a.is_resolved())
                        {
                            match acks.pop_front() {
                                Some(a) => {
                                    if let Ok(1) = a.wait() {
                                        processed.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                None => break,
                            }
                        }
                    };
                    let mut idle = 0u32;
                    while idle < 2_000 {
                        match broker.take_async(&aq).wait() {
                            Ok(Some(h)) => {
                                idle = 0;
                                if let Some((jid, _payload)) = broker.resolve_take(wtid, h) {
                                    acks.push_back(broker.ack_async(jid, &aq));
                                    settle(&mut acks, window - 1);
                                }
                                // else: stale DONE handle — take again.
                            }
                            Ok(None) => {
                                idle += 1;
                                settle(&mut acks, usize::MAX);
                                if processed.load(Ordering::Relaxed) >= total_target as u64 {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                            Err(_) => break, // crash/closed
                        }
                    }
                    settle(&mut acks, 0);
                });
            }));
        }
        if cycle == 0 {
            if let Some(h) = spawn_resizer(broker, cfg) {
                handles.push(h);
            }
        }
        for h in handles {
            h.join().expect("service thread panicked");
        }
        // Stop (and on crash: observe) the flusher before cutting the
        // topology — crash() requires all pmem-touching threads unwound.
        flusher.stop();
        if cfg.metrics_every > 0 && (cycle + 1) % cfg.metrics_every == 0 {
            emit_metrics(topo, broker, Some(&aq), cycle);
        }
        if crashing {
            topo.crash(&mut rng);
            broker.recover();
            crashes += 1;
        }
    }

    // Per-job latency sampling is a sync-path feature: async job time is
    // dominated by the completion window, not per-op cost — no samples.
    finish_service(broker, &processed, crashes, &sw, Vec::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::install_quiet_crash_hook;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(cap: usize) -> (Topology, Arc<Broker>) {
        let topo = Topology::single(PmemConfig {
            capacity_words: cap,
            cost: CostModel::zero(),
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 9,
        });
        let broker = Arc::new(Broker::new_on(&topo, 8, 1 << 16, 1 << 10));
        (topo, broker)
    }

    #[test]
    fn clean_run_processes_everything() {
        let (topo, broker) = mk(1 << 22);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 200,
            crash_cycles: 0,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.submitted, 400);
        assert_eq!(rep.done, 400);
        assert_eq!(rep.pending_after, 0);
        assert!(rep.latency_samples.len() > 0);
    }

    fn mk_sharded(cap: usize, nthreads: usize) -> (Topology, Arc<Broker>) {
        let topo = Topology::single(PmemConfig {
            capacity_words: cap,
            cost: CostModel::zero(),
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 11,
        });
        let broker = Arc::new(
            Broker::new_sharded(
                &topo,
                nthreads,
                1 << 16,
                crate::queues::QueueConfig {
                    shards: 4,
                    batch: 4,
                    batch_deq: 4,
                    ring_size: 1 << 10,
                    ..Default::default()
                },
            )
            .unwrap(),
        );
        (topo, broker)
    }

    #[test]
    fn async_serve_clean_run_completes_everything() {
        let (topo, broker) = mk_sharded(1 << 22, 2 + 2 + 1);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 200,
            crash_cycles: 0,
            use_async: true,
            acfg: AsyncCfg { flush_us: 200, depth: 8, flushers: 1 },
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.submitted, 400);
        assert_eq!(rep.done, 400, "async serve must complete every job");
        assert_eq!(rep.pending_after, 0);
    }

    #[test]
    fn async_serve_crash_cycles_lose_nothing() {
        install_quiet_crash_hook();
        let (topo, broker) = mk_sharded(1 << 23, 2 + 2 + 2);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 250,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 2,
            use_async: true,
            acfg: AsyncCfg { flush_us: 100, depth: 8, flushers: 2 },
            lease_ms: 0,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.crashes, 3);
        assert_eq!(
            rep.done, rep.submitted,
            "async crash cycles must still complete every durably submitted job \
             exactly once (submitted={}, done={}, pending={})",
            rep.submitted, rep.done, rep.pending_after
        );
        assert_eq!(rep.pending_after, 0);
    }

    #[test]
    fn serve_with_online_resize_completes_everything() {
        // Sync path: an admin thread grows the work queue 4 -> 8 stripes
        // while producers/workers are live; every job still completes
        // exactly once and the broker converges to one plan.
        let (topo, broker) = mk_sharded(1 << 22, 2 + 2 + 1);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 0,
            resize_to: 8,
            admin_tid: 4,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.submitted, 600);
        assert_eq!(rep.done, 600, "online resize must not lose or duplicate jobs");
        assert_eq!(rep.pending_after, 0);
        let rec = broker.reconcile_report(0);
        assert_eq!(rec.mismatches(), 0);
        assert_eq!(rec.plan, (2, 8), "the grown plan must be active");
        assert!(rec.draining_plan.is_none(), "the old plan must have retired");
    }

    #[test]
    fn async_serve_with_resize_and_crashes_loses_nothing() {
        install_quiet_crash_hook();
        let (topo, broker) = mk_sharded(1 << 23, 2 + 2 + 2 + 1);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 250,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 7,
            use_async: true,
            acfg: AsyncCfg { flush_us: 100, depth: 8, flushers: 2 },
            resize_to: 8,
            admin_tid: 6,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.crashes, 3);
        assert_eq!(
            rep.done, rep.submitted,
            "resize + async + crash cycles must keep exactly-once completion \
             (submitted={}, done={}, pending={})",
            rep.submitted, rep.done, rep.pending_after
        );
        assert_eq!(rep.pending_after, 0);
        assert!(
            broker.reconcile_report(0).draining_plan.is_none(),
            "recovery must have converged the plan"
        );
    }

    #[test]
    fn crash_cycles_lose_nothing_complete_once() {
        install_quiet_crash_hook();
        let (topo, broker) = mk(1 << 23);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 1,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.crashes, 3);
        assert_eq!(
            rep.done, rep.submitted,
            "every durably submitted job must be completed exactly once \
             (submitted={}, done={}, pending={})",
            rep.submitted, rep.done, rep.pending_after
        );
        assert_eq!(rep.pending_after, 0);
    }
}
