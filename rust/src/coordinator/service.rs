//! Broker service orchestration: producer/worker pools, crash cycles, and
//! the end-to-end report (`examples/task_broker` and `persiq serve`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::pmem::{run_guarded, Topology};
use crate::util::rng::Xoshiro256;
use crate::util::time::Stopwatch;

use super::broker::Broker;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub producers: usize,
    pub workers: usize,
    /// Jobs each producer submits per epoch.
    pub jobs_per_producer: usize,
    /// Crash/recovery cycles to run (0 = single run, no crash).
    pub crash_cycles: usize,
    /// pmem-primitive steps before each crash.
    pub crash_steps: u64,
    pub seed: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            producers: 2,
            workers: 2,
            jobs_per_producer: 500,
            crash_cycles: 0,
            crash_steps: 50_000,
            seed: 0xB40C,
        }
    }
}

/// End-to-end service report.
#[derive(Clone, Debug, Default)]
pub struct ServiceReport {
    pub submitted: usize,
    pub processed: u64,
    pub done: usize,
    pub pending_after: usize,
    pub crashes: usize,
    pub wall_secs: f64,
    /// Per-job processing latency samples (simulated ns; for the metrics
    /// pipeline).
    pub latency_samples: Vec<f64>,
}

/// Run the broker service end-to-end: per cycle, producers submit and
/// workers drain; a crash interrupts mid-flight; recovery resumes; after
/// the last cycle workers drain everything left. The final audit must show
/// every submitted job done exactly once.
pub fn run_service(
    topo: &Topology,
    broker: &Arc<Broker>,
    cfg: &ServiceConfig,
) -> Result<ServiceReport> {
    let sw = Stopwatch::start();
    let mut rng = Xoshiro256::seed_from(cfg.seed);
    let processed = Arc::new(AtomicU64::new(0));
    let samples = Arc::new(std::sync::Mutex::new(Vec::new()));
    let cycles = cfg.crash_cycles.max(1);
    let mut crashes = 0;

    for cycle in 0..cycles {
        let crashing = cfg.crash_cycles > 0;
        if crashing {
            topo.arm_crash_after(cfg.crash_steps);
        }
        let mut handles = Vec::new();
        // Producers: tids [0, producers).
        for ptid in 0..cfg.producers {
            let broker = Arc::clone(broker);
            let jobs = cfg.jobs_per_producer;
            handles.push(std::thread::spawn(move || {
                let _ = run_guarded(|| {
                    broker.attach_worker(ptid);
                    for i in 0..jobs {
                        let payload =
                            format!("job:c{cycle}:p{ptid}:{i}").into_bytes();
                        broker.submit(ptid, &payload[..payload.len().min(48)]).unwrap();
                    }
                    // Normal exit: flush buffered handle enqueues. (A
                    // crash unwinds past this; recovery reconciles.)
                    broker.detach_worker(ptid);
                });
            }));
        }
        // Workers: tids [producers, producers+workers).
        let total_target = cfg.producers * cfg.jobs_per_producer;
        for w in 0..cfg.workers {
            let broker = Arc::clone(broker);
            let topo = topo.clone();
            let processed = Arc::clone(&processed);
            let samples = Arc::clone(&samples);
            let wtid = cfg.producers + w;
            handles.push(std::thread::spawn(move || {
                let mut my_samples = Vec::new();
                let _ = run_guarded(|| {
                    broker.attach_worker(wtid);
                    let mut idle = 0u32;
                    // Drain until the queue stays empty (producers done)
                    // or the epoch target is safely exceeded.
                    while idle < 2_000 {
                        let t0 = topo.vtime(wtid);
                        match broker.take(wtid).unwrap() {
                            Some((jid, _payload)) => {
                                idle = 0;
                                // "Process": the completion transition is
                                // the work product.
                                if broker.complete(wtid, jid).unwrap() {
                                    processed.fetch_add(1, Ordering::Relaxed);
                                    my_samples.push((topo.vtime(wtid) - t0) as f64);
                                }
                            }
                            None => {
                                idle += 1;
                                if processed.load(Ordering::Relaxed)
                                    >= total_target as u64
                                {
                                    break;
                                }
                                std::thread::yield_now();
                            }
                        }
                    }
                    // Normal exit: flush this worker's buffered dequeue
                    // log. (A crash unwinds past this; recovery
                    // reconciles.)
                    broker.detach_worker(wtid);
                });
                samples.lock().unwrap().extend(my_samples);
            }));
        }
        for h in handles {
            h.join().expect("service thread panicked");
        }
        if crashing {
            topo.crash(&mut rng);
            broker.recover();
            crashes += 1;
        }
    }

    // Final drain: finish whatever survived the last crash. Flush any
    // thread-buffered handle enqueues first (batched work queues) so no
    // submitted job stays invisible.
    broker.quiesce();
    while let Some((jid, _)) = broker.take(0)? {
        if broker.complete(0, jid)? {
            processed.fetch_add(1, Ordering::Relaxed);
        }
    }

    let audit = broker.audit(0);
    let latency_samples = std::mem::take(&mut *samples.lock().unwrap());
    Ok(ServiceReport {
        submitted: audit.submitted,
        processed: processed.load(Ordering::Relaxed),
        done: audit.done,
        pending_after: audit.pending,
        crashes,
        wall_secs: sw.elapsed_secs(),
        latency_samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::install_quiet_crash_hook;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(cap: usize) -> (Topology, Arc<Broker>) {
        let topo = Topology::single(PmemConfig {
            capacity_words: cap,
            cost: CostModel::zero(),
            evict_prob: 0.25,
            pending_flush_prob: 0.5,
            seed: 9,
        });
        let broker = Arc::new(Broker::new_on(&topo, 8, 1 << 16, 1 << 10));
        (topo, broker)
    }

    #[test]
    fn clean_run_processes_everything() {
        let (topo, broker) = mk(1 << 22);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 200,
            crash_cycles: 0,
            ..Default::default()
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.submitted, 400);
        assert_eq!(rep.done, 400);
        assert_eq!(rep.pending_after, 0);
        assert!(rep.latency_samples.len() > 0);
    }

    #[test]
    fn crash_cycles_lose_nothing_complete_once() {
        install_quiet_crash_hook();
        let (topo, broker) = mk(1 << 23);
        let cfg = ServiceConfig {
            producers: 2,
            workers: 2,
            jobs_per_producer: 300,
            crash_cycles: 3,
            crash_steps: 30_000,
            seed: 1,
        };
        let rep = run_service(&topo, &broker, &cfg).unwrap();
        assert_eq!(rep.crashes, 3);
        assert_eq!(
            rep.done, rep.submitted,
            "every durably submitted job must be completed exactly once \
             (submitted={}, done={}, pending={})",
            rep.submitted, rep.done, rep.pending_after
        );
        assert_eq!(rep.pending_after, 0);
    }
}
