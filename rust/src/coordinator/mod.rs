//! The coordinator: a persistent **task-broker service** built on PerLCRQ —
//! the end-to-end application of the framework (DESIGN.md S16).
//!
//! Producers submit jobs (payload bytes); the broker persists the payload
//! in the NVM pool, enqueues a handle on a PerLCRQ work queue, and workers
//! consume, process and durably mark jobs done. A full-system crash at any
//! point loses no *submitted* job and double-executes none: the work queue
//! is durably linearizable (the paper's contribution) and job state
//! transitions are CAS-guarded and persisted.
//!
//! * [`broker`] — the data plane: job records, submit/take/complete,
//!   recovery, audit.
//! * [`service`] — the orchestration loop: producer/worker thread pools,
//!   crash cycles, end-to-end statistics (the `examples/task_broker`
//!   driver and `persiq serve` both run this).

pub mod broker;
pub mod service;

pub use broker::{Broker, BrokerAudit, JobId, JobState};
pub use service::{run_service, ServiceConfig, ServiceReport};
