//! The coordinator: a persistent **task-broker service** built on PerLCRQ —
//! the end-to-end application of the framework (DESIGN.md S16).
//!
//! Producers submit jobs (payload bytes); the broker persists the payload
//! in the NVM pool, enqueues a handle on a PerLCRQ work queue, and workers
//! consume, process and durably mark jobs done. A full-system crash at any
//! point loses no *submitted* job and double-executes none: the work queue
//! is durably linearizable (the paper's contribution) and job state
//! transitions are CAS-guarded and persisted.
//!
//! * [`broker`] — the data plane: job records, submit/take/complete,
//!   recovery, audit; the async variants (`submit_async` / `take_async` /
//!   `ack_async`) ride the [`crate::queues::asyncq`] completion layer, so
//!   handle enqueues, consumptions and DONE marks group-commit on the
//!   flusher's psync; per-job leases + [`broker::Broker::reap_expired`]
//!   redeliver jobs whose worker died *without* a crash.
//! * [`service`] — the orchestration loop: producer/worker thread pools,
//!   crash cycles, end-to-end statistics (the `examples/task_broker`
//!   driver and `persiq serve` both run this); `ServiceConfig::use_async`
//!   switches it onto the async paths end to end.

pub mod broker;
pub mod service;

pub use broker::{Broker, BrokerAudit, JobId, JobState, ReconcileReport};
pub use service::{run_service, ServiceConfig, ServiceReport};
