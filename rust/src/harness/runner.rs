//! Multi-thread workload runner with virtual-time metering.
//!
//! Throughput reporting follows DESIGN.md §1: real OS threads provide real
//! interleavings (correctness), while per-thread **virtual clocks** (see
//! [`crate::pmem`]) provide the scaling signal the paper measures on its
//! 96-thread testbed. Simulated throughput = `ops / max_vtime`; wall-clock
//! throughput is also reported (meaningful only up to the physical core
//! count of this machine).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::pmem::{run_guarded, Topology};
use crate::queues::ConcurrentQueue;
use crate::util::rng::Xoshiro256;
use crate::util::time::Stopwatch;
use crate::verify::{Event, EventKind, Recorder};

use super::workload::{value_for, Workload};

/// A one-shot callback thread 0 runs mid-workload, between two of its
/// operations, on its own tid — the online re-sharding trigger
/// (`--resharding-schedule`). Runs inside the crash guard: a simulated
/// crash can land anywhere inside it.
#[derive(Clone)]
pub struct MidHook(pub Arc<dyn Fn(usize) + Send + Sync>);

impl std::fmt::Debug for MidHook {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("MidHook(..)")
    }
}

/// Runner configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub nthreads: usize,
    /// Total operations across all threads (each runs `total/n`).
    pub total_ops: u64,
    pub workload: Workload,
    pub seed: u64,
    /// Value salt (vary across crash cycles for global uniqueness).
    pub salt: u64,
    /// Record verify/ events (adds overhead; off for throughput runs).
    pub record: bool,
    /// Keep every `k`-th op's simulated latency as a sample (0 = none).
    pub sample_every: u64,
    /// Inject random yields to diversify interleavings on few cores.
    pub yield_prob: f64,
    /// Run [`RunConfig::mid_hook`] once thread 0 has completed this many
    /// of its own ops (0 = never) — while every other thread keeps
    /// operating, so the hook runs genuinely online.
    pub hook_after: u64,
    /// The one-shot mid-run hook (receives thread 0's tid).
    pub mid_hook: Option<MidHook>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            nthreads: 4,
            total_ops: 100_000,
            workload: Workload::Pairs,
            seed: 42,
            salt: 0,
            record: false,
            sample_every: 0,
            yield_prob: 0.0,
            hook_after: 0,
            mid_hook: None,
        }
    }
}

/// Result of one workload run.
#[derive(Clone, Debug, Default)]
pub struct RunResult {
    pub ops_done: u64,
    pub enqueues: u64,
    pub dequeues: u64,
    pub empties: u64,
    /// Wall-clock seconds.
    pub wall_secs: f64,
    /// Simulated makespan: max over threads of virtual ns spent.
    pub sim_ns: u64,
    /// Crashed mid-run? (set when a crash was armed).
    pub crashed: bool,
    /// Per-thread event logs (when `record`).
    pub logs: Vec<Vec<Event>>,
    /// Simulated per-op latency samples in ns, per thread (when
    /// `sample_every > 0`) — input to the L2 metrics pipeline.
    pub latency_samples: Vec<Vec<f64>>,
    /// Ops per simulated second.
    pub sim_mops: f64,
    /// Ops per wall second.
    pub wall_mops: f64,
}

impl RunResult {
    fn finalize(&mut self) {
        self.sim_mops = if self.sim_ns > 0 {
            self.ops_done as f64 / (self.sim_ns as f64 / 1e9) / 1e6
        } else {
            0.0
        };
        self.wall_mops = if self.wall_secs > 0.0 {
            self.ops_done as f64 / self.wall_secs / 1e6
        } else {
            0.0
        };
    }
}

/// Run `cfg.workload` over `queue`. Resets the topology meter first so
/// `sim_ns` reflects only this run. If a crash is armed on the topology
/// the run may end early with `crashed = true` (the caller then drives
/// crash/recovery — see [`super::failure`]).
pub fn run_workload(
    topo: &Topology,
    queue: &Arc<dyn ConcurrentQueue>,
    cfg: &RunConfig,
) -> RunResult {
    topo.reset_meter();
    topo.set_active_threads(cfg.nthreads);
    let recorder = Recorder::new();
    let ops_per_thread = (cfg.total_ops / cfg.nthreads as u64).max(1);
    let done = Arc::new(AtomicU64::new(0));
    let enq_ct = Arc::new(AtomicU64::new(0));
    let deq_ct = Arc::new(AtomicU64::new(0));
    let empty_ct = Arc::new(AtomicU64::new(0));
    let crashed = Arc::new(AtomicU64::new(0));

    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    for tid in 0..cfg.nthreads {
        let topo = topo.clone();
        let queue = Arc::clone(queue);
        let recorder = Arc::clone(&recorder);
        let (done, enq_ct, deq_ct, empty_ct, crashed) = (
            Arc::clone(&done),
            Arc::clone(&enq_ct),
            Arc::clone(&deq_ct),
            Arc::clone(&empty_ct),
            Arc::clone(&crashed),
        );
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::split(cfg.seed, tid as u64);
            let mut log: Vec<Event> = Vec::new();
            let mut samples: Vec<f64> = Vec::new();
            let mut counter: u64 = 0;
            let mut my_done = 0u64;
            let mut my_enq = 0u64;
            let mut my_deq = 0u64;
            let mut my_empty = 0u64;
            let out = run_guarded(|| {
                for k in 0..ops_per_thread {
                    if tid == 0 && cfg.hook_after > 0 && k == cfg.hook_after {
                        if let Some(hook) = &cfg.mid_hook {
                            hook.0(tid);
                        }
                    }
                    if cfg.yield_prob > 0.0 && rng.chance(cfg.yield_prob) {
                        std::thread::yield_now();
                    }
                    let t0 = if cfg.sample_every > 0 { topo.vtime(tid) } else { 0 };
                    if cfg.workload.is_enqueue(k, &mut rng) {
                        let v = value_for(cfg.salt, tid, counter);
                        counter += 1;
                        if cfg.record {
                            recorder.record(
                                &mut log,
                                tid,
                                topo.epoch(),
                                EventKind::EnqInvoke { value: v },
                            );
                        }
                        queue.enqueue(tid, v).expect("enqueue failed: size the pool/capacity");
                        if cfg.record {
                            recorder.record(
                                &mut log,
                                tid,
                                topo.epoch(),
                                EventKind::EnqOk { value: v },
                            );
                        }
                        my_enq += 1;
                    } else {
                        if cfg.record {
                            recorder.record(&mut log, tid, topo.epoch(), EventKind::DeqInvoke);
                        }
                        match queue.dequeue(tid).expect("dequeue failed") {
                            Some(v) => {
                                if cfg.record {
                                    recorder.record(
                                        &mut log,
                                        tid,
                                        topo.epoch(),
                                        EventKind::DeqOk { value: v },
                                    );
                                }
                                my_deq += 1;
                            }
                            None => {
                                if cfg.record {
                                    recorder.record(
                                        &mut log,
                                        tid,
                                        topo.epoch(),
                                        EventKind::DeqEmpty,
                                    );
                                }
                                my_empty += 1;
                            }
                        }
                    }
                    my_done += 1;
                    if cfg.sample_every > 0 && k % cfg.sample_every == 0 {
                        samples.push((topo.vtime(tid) - t0) as f64);
                    }
                }
            });
            if out.crashed() {
                crashed.fetch_add(1, Ordering::Relaxed);
            }
            done.fetch_add(my_done, Ordering::Relaxed);
            enq_ct.fetch_add(my_enq, Ordering::Relaxed);
            deq_ct.fetch_add(my_deq, Ordering::Relaxed);
            empty_ct.fetch_add(my_empty, Ordering::Relaxed);
            (log, samples)
        }));
    }

    let mut logs = Vec::new();
    let mut latency_samples = Vec::new();
    for h in handles {
        let (log, samples) = h.join().expect("worker panicked (non-crash)");
        logs.push(log);
        latency_samples.push(samples);
    }

    let mut res = RunResult {
        ops_done: done.load(Ordering::Relaxed),
        enqueues: enq_ct.load(Ordering::Relaxed),
        dequeues: deq_ct.load(Ordering::Relaxed),
        empties: empty_ct.load(Ordering::Relaxed),
        wall_secs: sw.elapsed_secs(),
        sim_ns: topo.max_vtime(),
        crashed: crashed.load(Ordering::Relaxed) > 0,
        logs,
        latency_samples,
        ..Default::default()
    };
    res.finalize();
    res
}

/// Exhaustively drain a queue (single-threaded), returning the values —
/// the verifier's final-state probe.
pub fn drain_all(queue: &Arc<dyn ConcurrentQueue>, tid: usize) -> Vec<u64> {
    let mut out = Vec::new();
    while let Ok(Some(v)) = queue.dequeue(tid) {
        out.push(v);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::queues::{by_name, QueueConfig, QueueCtx};
    use crate::verify::{check, History};

    fn ctx(cap: usize) -> QueueCtx {
        QueueCtx::single(
            PmemConfig {
                capacity_words: cap,
                cost: CostModel::default(),
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 7,
            },
            4,
            QueueConfig::default(),
        )
    }

    #[test]
    fn pairs_workload_runs_and_meters() {
        let c = ctx(1 << 21);
        let q = by_name("perlcrq").unwrap()(&c);
        let cfg = RunConfig { nthreads: 4, total_ops: 8_000, ..Default::default() };
        let r = run_workload(&c.topo, &q, &cfg);
        assert_eq!(r.ops_done, 8_000);
        assert!(r.sim_ns > 0, "virtual time must advance");
        assert!(r.sim_mops > 0.0);
        assert!(!r.crashed);
        assert_eq!(r.enqueues, 4_000);
        assert_eq!(r.dequeues + r.empties, 4_000);
    }

    #[test]
    fn recorded_history_verifies() {
        let c = ctx(1 << 21);
        let q = by_name("perlcrq").unwrap()(&c);
        let cfg = RunConfig {
            nthreads: 4,
            total_ops: 4_000,
            record: true,
            ..Default::default()
        };
        let r = run_workload(&c.topo, &q, &cfg);
        let drain = drain_all(&q, 0);
        let h = History::from_logs(r.logs, drain);
        let rep = check(&h, 5);
        assert!(rep.ok(), "verifier found: {:?}", rep.violations);
        assert!(rep.enq_completed > 0);
    }

    #[test]
    fn sampling_collects_latencies() {
        let c = ctx(1 << 21);
        let q = by_name("periq").unwrap()(&c);
        let cfg = RunConfig {
            nthreads: 2,
            total_ops: 2_000,
            sample_every: 10,
            ..Default::default()
        };
        let r = run_workload(&c.topo, &q, &cfg);
        let n: usize = r.latency_samples.iter().map(|s| s.len()).sum();
        assert!(n >= 190, "expected ~200 samples, got {n}");
        assert!(r.latency_samples.iter().flatten().all(|&x| x >= 0.0));
    }

    #[test]
    fn sim_time_reflects_contention_costs() {
        // Same ops, 1 vs 4 threads on the SAME algorithm: per-op simulated
        // cost should rise with threads (FAI contention), so sim throughput
        // does not scale linearly.
        let c1 = ctx(1 << 21);
        let q1 = by_name("perlcrq").unwrap()(&c1);
        let r1 = run_workload(
            &c1.topo,
            &q1,
            &RunConfig { nthreads: 1, total_ops: 4_000, ..Default::default() },
        );
        let c4 = ctx(1 << 21);
        let q4 = by_name("perlcrq").unwrap()(&c4);
        let r4 = run_workload(
            &c4.topo,
            &q4,
            &RunConfig { nthreads: 4, total_ops: 4_000, ..Default::default() },
        );
        assert!(
            r4.sim_mops < r1.sim_mops * 4.0,
            "4 threads must not be 4x of 1 thread under contention \
             (1t={:.2} 4t={:.2})",
            r1.sim_mops,
            r4.sim_mops
        );
    }
}
