//! Workload definitions (paper §5 methodology).

use crate::util::rng::Xoshiro256;

/// The operation mix each worker thread executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Alternating enqueue/dequeue pairs starting from an empty queue —
    /// the paper's standard workload ("avoids performing unsuccessful and
    /// thus cheap operations").
    Pairs,
    /// Uniform random 50% enqueue / 50% dequeue (paper: "did not
    /// illustrate significantly different performance trends").
    Random5050,
    /// 80% enqueue / 20% dequeue (grows the queue; recovery-size benches).
    EnqHeavy,
    /// 20% enqueue / 80% dequeue.
    DeqHeavy,
    /// Enqueue-only (fills the queue to a target size).
    EnqOnly,
}

impl Workload {
    /// Parse from CLI/config name.
    pub fn parse(s: &str) -> Option<Workload> {
        Some(match s {
            "pairs" => Workload::Pairs,
            "random" | "random5050" | "50-50" => Workload::Random5050,
            "enq-heavy" => Workload::EnqHeavy,
            "deq-heavy" => Workload::DeqHeavy,
            "enq-only" => Workload::EnqOnly,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Workload::Pairs => "pairs",
            Workload::Random5050 => "random5050",
            Workload::EnqHeavy => "enq-heavy",
            Workload::DeqHeavy => "deq-heavy",
            Workload::EnqOnly => "enq-only",
        }
    }

    /// Decide whether the `k`-th operation of a thread is an enqueue.
    #[inline]
    pub fn is_enqueue(&self, k: u64, rng: &mut Xoshiro256) -> bool {
        match self {
            Workload::Pairs => k % 2 == 0,
            Workload::Random5050 => rng.next_bool(),
            Workload::EnqHeavy => rng.next_below(10) < 8,
            Workload::DeqHeavy => rng.next_below(10) < 2,
            Workload::EnqOnly => true,
        }
    }
}

/// Build the globally unique value for thread `tid`'s `k`-th enqueue.
/// Layout: `salt (12 bits) | tid (10 bits) | counter (40 bits)` — always
/// `< MAX_ITEM` and unique across crash cycles when `salt` differs.
#[inline]
pub fn value_for(salt: u64, tid: usize, counter: u64) -> u64 {
    debug_assert!(salt < (1 << 12));
    debug_assert!(tid < (1 << 10));
    debug_assert!(counter < (1 << 40));
    (salt << 50) | ((tid as u64) << 40) | counter
}

/// Decompose a value produced by [`value_for`].
pub fn split_value(v: u64) -> (u64, usize, u64) {
    ((v >> 50) & 0xFFF, ((v >> 40) & 0x3FF) as usize, v & ((1 << 40) - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::queues::MAX_ITEM;

    #[test]
    fn parse_roundtrip() {
        for w in [
            Workload::Pairs,
            Workload::Random5050,
            Workload::EnqHeavy,
            Workload::DeqHeavy,
            Workload::EnqOnly,
        ] {
            assert_eq!(Workload::parse(w.name()), Some(w));
        }
        assert_eq!(Workload::parse("nope"), None);
    }

    #[test]
    fn pairs_alternate() {
        let mut rng = Xoshiro256::seed_from(1);
        assert!(Workload::Pairs.is_enqueue(0, &mut rng));
        assert!(!Workload::Pairs.is_enqueue(1, &mut rng));
        assert!(Workload::Pairs.is_enqueue(2, &mut rng));
    }

    #[test]
    fn mixes_are_biased() {
        let mut rng = Xoshiro256::seed_from(2);
        let count = |w: Workload, rng: &mut Xoshiro256| {
            (0..1000).filter(|&k| w.is_enqueue(k, rng)).count()
        };
        let eh = count(Workload::EnqHeavy, &mut rng);
        let dh = count(Workload::DeqHeavy, &mut rng);
        assert!(eh > 700, "enq-heavy should be ~80% enqueues, got {eh}");
        assert!(dh < 300, "deq-heavy should be ~20% enqueues, got {dh}");
        assert_eq!(count(Workload::EnqOnly, &mut rng), 1000);
    }

    #[test]
    fn values_unique_and_in_range() {
        let a = value_for(1, 5, 100);
        let b = value_for(1, 5, 101);
        let c = value_for(1, 6, 100);
        let d = value_for(2, 5, 100);
        let all = [a, b, c, d];
        let mut s = all.to_vec();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 4);
        for v in all {
            assert!(v < MAX_ITEM);
        }
        assert_eq!(split_value(a), (1, 5, 100));
    }
}
