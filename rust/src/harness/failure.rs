//! The §5 failure framework.
//!
//! > "The framework provides a shared variable called `recovery_steps`.
//! > All threads monitor this variable and each operation periodically
//! > lowers the value by 1 step. When it reaches 0, any thread running
//! > will cease, effectively simulating a crash of all threads.
//! > Afterwards, the recovery function is launched by some thread. [...]
//! > The above procedure [...] is called a *cycle*. Each evaluation test
//! > has 10 cycles and we measure only the third part of each cycle, which
//! > corresponds to the recovery cost."
//!
//! Our `recovery_steps` counts **pmem primitives** rather than whole
//! operations, so crashes land *inside* operations (in every window the
//! §4 proofs reason about). The recovery cost is measured in wall-clock
//! time, simulated time, and NVM reads (scan length).

use std::sync::Arc;

use crate::pmem::Topology;
use crate::queues::PersistentQueue;
use crate::util::rng::Xoshiro256;
use crate::util::time::Stopwatch;

use super::runner::{run_workload, RunConfig, RunResult};

/// Crash-cycle configuration.
#[derive(Clone, Debug)]
pub struct CycleConfig {
    /// Number of cycles (paper: 10).
    pub cycles: usize,
    /// pmem-primitive steps before the crash fires (per cycle); jittered
    /// by ±25% per cycle.
    pub steps: u64,
    /// Workload config for the normal-execution part.
    pub run: RunConfig,
    /// RNG seed for crash nondeterminism.
    pub seed: u64,
}

impl Default for CycleConfig {
    fn default() -> Self {
        Self { cycles: 10, steps: 50_000, run: RunConfig::default(), seed: 0xC4A5 }
    }
}

/// Result of one cycle.
#[derive(Clone, Debug, Default)]
pub struct CycleResult {
    /// Operations completed before the crash.
    pub ops_before_crash: u64,
    /// Recovery wall-clock seconds (the paper's measured quantity).
    pub recovery_wall_secs: f64,
    /// Recovery simulated ns (virtual clock of the recovering thread).
    pub recovery_sim_ns: u64,
    /// NVM words read during recovery (scan length).
    pub recovery_loads: u64,
    /// NVM words written during recovery.
    pub recovery_stores: u64,
    /// The run portion (normal execution) of the cycle.
    pub run: RunResult,
}

/// Run `cfg.cycles` crash/recovery cycles. Per cycle: run the workload
/// with the step countdown armed → threads cease mid-operation → commit
/// the crash → run the recovery function, measured. Returns per-cycle
/// results (callers average the recovery cost, as in Figures 4–5).
pub fn run_cycles(
    topo: &Topology,
    queue: &Arc<dyn PersistentQueue>,
    cfg: &CycleConfig,
) -> Vec<CycleResult> {
    let mut rng = Xoshiro256::seed_from(cfg.seed);
    let mut out = Vec::with_capacity(cfg.cycles);
    let as_conc: Arc<dyn crate::queues::ConcurrentQueue> = Arc::clone(queue) as _;
    for cycle in 0..cfg.cycles {
        // --- Part 1: normal execution with the countdown armed ---
        let jitter = cfg.steps / 4;
        let steps = cfg.steps - jitter + rng.next_below(2 * jitter + 1);
        topo.arm_crash_after(steps);
        let mut run_cfg = cfg.run.clone();
        run_cfg.salt = (cycle as u64 + 1) & 0xFFF; // unique values per cycle
        run_cfg.seed = cfg.run.seed ^ (cycle as u64) << 32;
        let run = run_workload(topo, &as_conc, &run_cfg);

        // --- Part 2: the crash (one cut across every pool) ---
        topo.crash(&mut rng);

        // --- Part 3: recovery (the measured part) ---
        topo.reset_meter();
        let before = topo.stats_total();
        let sw = Stopwatch::start();
        queue.recover(topo.primary());
        let wall = sw.elapsed_secs();
        let after = topo.stats_total();
        out.push(CycleResult {
            ops_before_crash: run.ops_done,
            recovery_wall_secs: wall,
            recovery_sim_ns: topo.vtime(0),
            recovery_loads: after.loads - before.loads,
            recovery_stores: after.stores - before.stores,
            run,
        });
    }
    out
}

/// Average recovery wall seconds over cycles.
pub fn mean_recovery_secs(results: &[CycleResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|c| c.recovery_wall_secs).sum::<f64>() / results.len() as f64
}

/// Average recovery simulated ns over cycles.
pub fn mean_recovery_sim_ns(results: &[CycleResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|c| c.recovery_sim_ns as f64).sum::<f64>() / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::install_quiet_crash_hook;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::queues::{persistent_by_name, QueueConfig, QueueCtx};

    fn ctx() -> QueueCtx {
        QueueCtx::single(
            PmemConfig {
                capacity_words: 1 << 22,
                cost: CostModel::default(),
                evict_prob: 0.25,
                pending_flush_prob: 0.5,
                seed: 17,
            },
            4,
            QueueConfig::default(),
        )
    }

    #[test]
    fn cycles_crash_and_recover() {
        install_quiet_crash_hook();
        let c = ctx();
        let q = persistent_by_name("perlcrq").unwrap()(&c);
        let cfg = CycleConfig {
            cycles: 3,
            steps: 20_000,
            run: RunConfig { nthreads: 4, total_ops: 1_000_000, ..Default::default() },
            seed: 5,
        };
        let res = run_cycles(&c.topo, &q, &cfg);
        assert_eq!(res.len(), 3);
        for r in &res {
            assert!(r.run.crashed, "the countdown must interrupt the run");
            assert!(r.recovery_loads > 0, "recovery must read NVM");
        }
        assert_eq!(c.topo.epoch(), 3);
        // The queue is alive after the last recovery.
        q.enqueue(0, 12345).unwrap();
        assert!(q.dequeue(1).unwrap().is_some());
    }

    #[test]
    fn recovery_metrics_nonzero_for_periq() {
        install_quiet_crash_hook();
        let c = ctx();
        let q = persistent_by_name("periq").unwrap()(&c);
        let cfg = CycleConfig {
            cycles: 2,
            steps: 10_000,
            run: RunConfig { nthreads: 4, total_ops: 1_000_000, ..Default::default() },
            seed: 6,
        };
        let res = run_cycles(&c.topo, &q, &cfg);
        assert!(mean_recovery_secs(&res) >= 0.0);
        assert!(mean_recovery_sim_ns(&res) > 0.0);
    }
}
