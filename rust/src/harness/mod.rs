//! Benchmark & crash-test harness.
//!
//! * [`workload`] — the paper's §5 workloads: enqueue/dequeue pairs
//!   (default, "avoids performing unsuccessful and thus cheap operations"),
//!   50/50 random, and enqueue-/dequeue-heavy mixes.
//! * [`runner`] — multi-thread execution with virtual-time metering:
//!   simulated throughput = ops / max-thread-virtual-time (see pmem docs),
//!   plus wall-clock numbers and per-op latency samples for the L2 metrics
//!   pipeline.
//! * [`failure`] — the §5 failure framework: `recovery_steps` countdown, a
//!   *cycle* = normal run → crash when steps hit 0 → recovery; recovery
//!   cost is measured over 10 cycles by default.
//! * [`async_run`] — the async-API twin of [`runner`]: producers submit
//!   through [`crate::queues::asyncq`] and hold windows of futures,
//!   overlapping persistence latency instead of blocking per batch.
//! * [`mod@bench`] — a small criterion-style measurement core (warmup +
//!   repeated timed runs + mean/σ) used by all `cargo bench` targets.

pub mod async_run;
pub mod bench;
pub mod failure;
pub mod runner;
pub mod workload;

pub use async_run::{run_async_workload, AsyncRunConfig, AsyncRunResult};
pub use failure::{run_cycles, CycleConfig, CycleResult};
pub use runner::{run_workload, MidHook, RunConfig, RunResult};
pub use workload::Workload;
