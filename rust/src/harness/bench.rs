//! Criterion-style measurement core (criterion is unavailable offline).
//!
//! Each `cargo bench` target builds a [`Suite`], registers measurements,
//! and gets: warmup, repeated timed runs, mean ± σ, an aligned table on
//! stdout, a CSV under `results/`, and a machine-readable
//! `results/BENCH_<name>.json` artifact (schema `persiq-bench-v1`)
//! carrying the run configuration, every series' statistics, and each
//! paper claim's pass/fail verdict — what CI greps instead of scraping
//! stdout.

use std::path::PathBuf;

use crate::util::report::{fnum, Csv, Json};
use crate::util::time::{stats, Stats};

/// One measured series point.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Series name (e.g. algorithm).
    pub series: String,
    /// X value (e.g. thread count).
    pub x: f64,
    /// Y samples across repeats (e.g. simulated Mops/s).
    pub ys: Vec<f64>,
    /// Optional extra columns (e.g. pwbs/op).
    pub extra: Vec<(String, f64)>,
}

impl Measurement {
    pub fn stats(&self) -> Stats {
        stats(&self.ys)
    }
}

/// One paper-claim verdict carried in the `BENCH_<name>.json` artifact:
/// the claim as stated (e.g. "sharded throughput scales with K"), whether
/// this run supports it, and the measured evidence.
#[derive(Clone, Debug)]
pub struct Claim {
    /// Stable id CI can grep, e.g. "fig7-scaling".
    pub id: String,
    /// The paper's claim in one sentence.
    pub statement: String,
    pub pass: bool,
    /// Measured evidence, e.g. "K=8: 1.92 Mops vs K=1: 0.61 Mops".
    pub detail: String,
}

/// A bench suite: collects measurements, prints the figure's table,
/// saves CSV plus the `BENCH_<name>.json` artifact.
pub struct Suite {
    /// Bench id, e.g. "fig2_throughput".
    pub name: &'static str,
    /// What the paper's figure shows (printed as the header).
    pub title: &'static str,
    pub measurements: Vec<Measurement>,
    /// Repeats per point.
    pub repeats: usize,
    /// Run configuration echoed into the JSON artifact (threads, ops,
    /// shards, ... — whatever the figure sweeps or pins).
    pub config: Vec<(String, String)>,
    /// Paper-claim verdicts (register before [`Suite::finish`]).
    pub claims: Vec<Claim>,
}

impl Suite {
    pub fn new(name: &'static str, title: &'static str) -> Self {
        // Honor `cargo bench -- --quick` style knobs via env.
        let repeats = std::env::var("PERSIQ_BENCH_REPEATS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2);
        Self { name, title, measurements: Vec::new(), repeats, config: Vec::new(), claims: Vec::new() }
    }

    /// Record one configuration knob for the JSON artifact.
    pub fn config<V: std::fmt::Display>(&mut self, key: &str, val: V) {
        self.config.push((key.to_string(), val.to_string()));
    }

    /// Register a paper-claim verdict. Call before [`Suite::finish`] so
    /// the verdict lands in `BENCH_<name>.json`; the caller still decides
    /// whether a failed claim fails the process (gates `ensure!`, shape
    /// checks usually just record).
    pub fn claim(&mut self, id: &str, statement: &str, pass: bool, detail: String) {
        self.claims.push(Claim {
            id: id.to_string(),
            statement: statement.to_string(),
            pass,
            detail,
        });
    }

    /// True when every registered claim passed (vacuously true with none).
    pub fn claims_pass(&self) -> bool {
        self.claims.iter().all(|c| c.pass)
    }

    /// Measure `f` (returning one y sample per call) `repeats` times.
    pub fn measure<F: FnMut() -> f64>(&mut self, series: &str, x: f64, mut f: F) {
        let mut ys = Vec::with_capacity(self.repeats);
        for _ in 0..self.repeats {
            ys.push(f());
        }
        self.measurements.push(Measurement { series: series.to_string(), x, ys, extra: vec![] });
    }

    /// Measure with extra columns: `f` returns (y, extras).
    pub fn measure_extra<F: FnMut() -> (f64, Vec<(String, f64)>)>(
        &mut self,
        series: &str,
        x: f64,
        mut f: F,
    ) {
        let mut ys = Vec::with_capacity(self.repeats);
        let mut extra = Vec::new();
        for _ in 0..self.repeats {
            let (y, e) = f();
            ys.push(y);
            extra = e; // last repeat's extras
        }
        self.measurements.push(Measurement { series: series.to_string(), x, ys, extra });
    }

    /// Print the figure table and save `results/<name>.csv`.
    pub fn finish(&self) -> anyhow::Result<()> {
        println!("\n=== {} — {} ===", self.name, self.title);
        let has_extra = self.measurements.iter().any(|m| !m.extra.is_empty());
        let mut header = vec!["series".to_string(), "x".to_string(), "mean".to_string(),
            "std".to_string(), "min".to_string(), "max".to_string()];
        if has_extra {
            // Union of extra column names, stable order of first appearance.
            let mut cols: Vec<String> = Vec::new();
            for m in &self.measurements {
                for (k, _) in &m.extra {
                    if !cols.contains(k) {
                        cols.push(k.clone());
                    }
                }
            }
            header.extend(cols.clone());
            let mut csv = Csv::new(header);
            for m in &self.measurements {
                let s = m.stats();
                let mut row = vec![
                    m.series.clone(),
                    format!("{}", m.x),
                    fnum(s.mean),
                    fnum(s.std),
                    fnum(s.min),
                    fnum(s.max),
                ];
                for c in &cols {
                    let v = m.extra.iter().find(|(k, _)| k == c).map(|(_, v)| *v);
                    row.push(v.map(fnum).unwrap_or_default());
                }
                csv.row(row);
            }
            print!("{}", csv.to_table());
            csv.save(&self.csv_path())?;
        } else {
            let mut csv = Csv::new(header);
            for m in &self.measurements {
                let s = m.stats();
                csv.row(vec![
                    m.series.clone(),
                    format!("{}", m.x),
                    fnum(s.mean),
                    fnum(s.std),
                    fnum(s.min),
                    fnum(s.max),
                ]);
            }
            print!("{}", csv.to_table());
            csv.save(&self.csv_path())?;
        }
        println!("[saved {}]", self.csv_path().display());
        for c in &self.claims {
            println!(
                "claim {:<24} {}  {} ({})",
                c.id,
                if c.pass { "PASS" } else { "FAIL" },
                c.statement,
                c.detail
            );
        }
        self.to_json().save(&self.json_path())?;
        println!("[saved {}]", self.json_path().display());
        Ok(())
    }

    /// The `persiq-bench-v1` artifact: configuration, per-series stats
    /// (with raw samples and extra columns), and claim verdicts.
    pub fn to_json(&self) -> Json {
        let mut cfg = Json::obj();
        for (k, v) in &self.config {
            cfg = cfg.push(k, Json::Str(v.clone()));
        }
        let series = Json::Arr(
            self.measurements
                .iter()
                .map(|m| {
                    let s = m.stats();
                    let mut extra = Json::obj();
                    for (k, v) in &m.extra {
                        extra = extra.push(k, Json::Num(*v));
                    }
                    Json::obj()
                        .push("series", Json::Str(m.series.clone()))
                        .push("x", Json::Num(m.x))
                        .push("n", Json::Num(s.n as f64))
                        .push("mean", Json::Num(s.mean))
                        .push("std", Json::Num(s.std))
                        .push("min", Json::Num(s.min))
                        .push("max", Json::Num(s.max))
                        .push("samples", Json::Arr(m.ys.iter().map(|y| Json::Num(*y)).collect()))
                        .push("extra", extra)
                })
                .collect(),
        );
        let claims = Json::Arr(
            self.claims
                .iter()
                .map(|c| {
                    Json::obj()
                        .push("id", Json::Str(c.id.clone()))
                        .push("statement", Json::Str(c.statement.clone()))
                        .push("pass", Json::Bool(c.pass))
                        .push("detail", Json::Str(c.detail.clone()))
                })
                .collect(),
        );
        Json::obj()
            .push("schema", Json::Str("persiq-bench-v1".into()))
            .push("name", Json::Str(self.name.into()))
            .push("title", Json::Str(self.title.into()))
            .push("repeats", Json::Num(self.repeats as f64))
            .push("config", cfg)
            .push("series", series)
            .push("claims", claims)
            .push("pass", Json::Bool(self.claims_pass()))
    }

    fn csv_path(&self) -> PathBuf {
        PathBuf::from("results").join(format!("{}.csv", self.name))
    }

    fn json_path(&self) -> PathBuf {
        PathBuf::from("results").join(format!("BENCH_{}.json", self.name))
    }

    /// Summarize a series: mean y at the given x (for shape assertions in
    /// EXPERIMENTS.md and smoke checks).
    pub fn mean_at(&self, series: &str, x: f64) -> Option<f64> {
        self.measurements
            .iter()
            .find(|m| m.series == series && (m.x - x).abs() < 1e-9)
            .map(|m| m.stats().mean)
    }
}

/// Standard simulated thread counts for scaling figures (the paper sweeps
/// 1..96 on 48 cores / 96 hyperthreads). Override with PERSIQ_THREADS.
pub fn thread_sweep() -> Vec<usize> {
    if let Ok(s) = std::env::var("PERSIQ_THREADS") {
        return s
            .split(',')
            .filter_map(|p| p.trim().parse().ok())
            .collect();
    }
    vec![1, 2, 4, 8, 16, 32, 48, 64, 96]
}

/// Default ops per bench point (scaled from the paper's 10^7 for the
/// 1-core testbed). Override with PERSIQ_OPS.
pub fn bench_ops() -> u64 {
    std::env::var("PERSIQ_OPS").ok().and_then(|s| s.parse().ok()).unwrap_or(60_000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_and_stats() {
        let mut s = Suite::new("test_suite", "test");
        s.repeats = 4;
        let mut i = 0.0;
        s.measure("algo", 1.0, || {
            i += 1.0;
            i
        });
        let m = &s.measurements[0];
        assert_eq!(m.ys.len(), 4);
        assert!((m.stats().mean - 2.5).abs() < 1e-12);
        assert_eq!(s.mean_at("algo", 1.0), Some(2.5));
        assert_eq!(s.mean_at("algo", 2.0), None);
    }

    #[test]
    fn thread_sweep_env_override() {
        // Don't mutate the real env in parallel tests; just test the
        // default path shape.
        let v = thread_sweep();
        assert!(!v.is_empty());
        assert!(v[0] >= 1);
    }

    #[test]
    fn json_artifact_shape() {
        let mut s = Suite::new("test_json", "t");
        s.repeats = 2;
        s.config("threads", 4);
        s.measure("a", 1.0, || 5.0);
        s.claim("c1", "five is five", true, "5.0 == 5.0".into());
        let j = s.to_json().render();
        assert!(j.contains("\"schema\":\"persiq-bench-v1\""));
        assert!(j.contains("\"name\":\"test_json\""));
        assert!(j.contains("\"threads\":\"4\""));
        assert!(j.contains("\"series\":\"a\""));
        assert!(j.contains("\"id\":\"c1\""));
        assert!(j.ends_with("\"pass\":true}"));
        s.claim("c2", "never holds", false, String::new());
        assert!(!s.claims_pass());
        assert!(s.to_json().render().ends_with("\"pass\":false}"));
    }

    #[test]
    fn finish_writes_csv() {
        let mut s = Suite::new("test_suite_csv", "t");
        s.repeats = 1;
        s.measure("a", 1.0, || 5.0);
        // Write into a temp cwd-independent location by temporarily
        // changing into a temp dir is risky in parallel tests; instead
        // just exercise the table rendering path.
        let m = &s.measurements[0];
        assert_eq!(m.stats().n, 1);
    }
}
