//! Async workload runner: producers submit operations through the
//! [`crate::queues::asyncq`] completion layer and overlap persistence
//! latency by holding a *window* of outstanding futures, awaiting the
//! oldest only when the window fills — the service pattern the async
//! API exists for.
//!
//! Producers touch no persistent memory themselves (their virtual clocks
//! stay at zero); all queue work runs on the flusher workers' thread
//! slots, so `sim_ns = max_vtime` measures the persistence pipeline and
//! `sim_mops` compares directly against [`super::runner::run_workload`]
//! numbers for the sync API (same meter, same workloads).
//!
//! With `record = true` the producers log checker events at the **async
//! boundaries**: `EnqInvoke`/`DeqInvoke` at submission, `EnqOk`/`DeqOk`
//! at future resolution. Because resolution is durability-gated, a
//! history recorded this way needs *zero* trailing-loss/redelivery
//! allowance from the checker — `tests/prop_async_durability.rs` gates
//! on exactly that.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::pmem::Topology;
use crate::queues::asyncq::{AsyncCfg, AsyncQueue, AsyncStats, DeqFuture, EnqFuture};
use crate::queues::sharded::{Shardable, ShardedQueue};
use crate::util::rng::Xoshiro256;
use crate::util::time::Stopwatch;
use crate::verify::{Event, EventKind, Recorder};

use super::workload::{value_for, Workload};

/// Configuration for one async workload run.
#[derive(Clone, Debug)]
pub struct AsyncRunConfig {
    /// Producer (submitting) threads — tids `0..producers`; the flusher
    /// workers take tids `producers..producers + acfg.flushers`.
    pub producers: usize,
    /// Total operations across all producers.
    pub total_ops: u64,
    pub workload: Workload,
    pub seed: u64,
    /// Value salt (vary across crash cycles for global uniqueness).
    pub salt: u64,
    /// Record checker events at the async boundaries.
    pub record: bool,
    /// Outstanding futures a producer holds before awaiting the oldest.
    pub window: usize,
    pub acfg: AsyncCfg,
}

impl Default for AsyncRunConfig {
    fn default() -> Self {
        Self {
            producers: 4,
            total_ops: 100_000,
            workload: Workload::Pairs,
            seed: 42,
            salt: 0,
            record: false,
            window: 32,
            acfg: AsyncCfg::default(),
        }
    }
}

/// Result of one async workload run.
#[derive(Clone, Debug, Default)]
pub struct AsyncRunResult {
    /// Successfully resolved operations (enq ok + deq ok + empties).
    pub ops_done: u64,
    pub enq_ok: u64,
    pub deq_ok: u64,
    pub empties: u64,
    /// Futures that resolved with an error (crash/close/queue).
    pub failed: u64,
    /// Error-resolved enqueue futures (their items may or may not have
    /// landed — the crash-unknown window).
    pub failed_enq: u64,
    /// Error-resolved dequeue futures. Each may have durably consumed at
    /// most one value without returning it (the in-flight-dequeue budget
    /// the checker's `pending_deqs` models).
    pub failed_deq: u64,
    /// A flusher observed a simulated crash mid-run.
    pub crashed: bool,
    pub wall_secs: f64,
    /// Simulated makespan (max thread virtual time — the flusher tids).
    pub sim_ns: u64,
    pub sim_mops: f64,
    pub wall_mops: f64,
    /// Per-producer event logs (when `record`).
    pub logs: Vec<Vec<Event>>,
    /// Values whose `EnqFuture` resolved `Ok` — durably enqueued.
    pub enq_resolved: Vec<u64>,
    /// Values returned by `DeqFuture`s that resolved — durably consumed.
    pub deq_resolved: Vec<u64>,
    /// Async-layer counters at the end of the run.
    pub stats: AsyncStats,
}

enum Pending {
    E(u64, EnqFuture),
    D(DeqFuture),
}

/// Run an async workload over `queue`. Resets the topology meter first.
/// If a crash is armed the flusher workers unwind, every unflushed future
/// fails with `Crashed`, and the run ends early with `crashed = true`
/// (the caller then drives crash/recovery, as with the sync runner).
pub fn run_async_workload<Q: Shardable + 'static>(
    topo: &Topology,
    queue: &Arc<ShardedQueue<Q>>,
    cfg: &AsyncRunConfig,
) -> AsyncRunResult {
    topo.reset_meter();
    topo.set_active_threads(cfg.producers + cfg.acfg.flushers);
    let aq = AsyncQueue::new(Arc::clone(queue), cfg.acfg.clone())
        .expect("invalid async config (call AsyncCfg::validate first)");
    let recorder = Recorder::new();
    // Recording runs attach the executed-hook BEFORE spawning flushers:
    // the combiner stamps a `DeqExecuted` marker (attributed to the
    // submitting tid via the op tag) the moment a dequeue runs against
    // the queue, so the checker's V2 loss budget counts exactly the
    // crash-in-flight dequeues instead of the whole future window.
    let exec_log: Arc<std::sync::Mutex<Vec<Event>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    if cfg.record {
        let rec = Arc::clone(&recorder);
        let topo2 = topo.clone();
        let el = Arc::clone(&exec_log);
        aq.set_deq_executed_hook(Arc::new(move |tag: u64, _value: u64| {
            let mut log = el.lock().unwrap();
            rec.record(&mut log, tag as usize, topo2.epoch(), EventKind::DeqExecuted);
        }));
    }
    let flusher = aq.spawn_flusher(cfg.producers);
    let ops_per_thread = (cfg.total_ops / cfg.producers.max(1) as u64).max(1);

    let sw = Stopwatch::start();
    let mut handles = Vec::new();
    for tid in 0..cfg.producers {
        let aq = aq.clone();
        let topo = topo.clone();
        let recorder = Arc::clone(&recorder);
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::split(cfg.seed, tid as u64);
            let mut log: Vec<Event> = Vec::new();
            let mut window: VecDeque<Pending> = VecDeque::with_capacity(cfg.window + 1);
            let mut out = ProducerOut::default();
            let mut counter = 0u64;
            let epoch = topo.epoch();
            for k in 0..ops_per_thread {
                if aq.is_closed() {
                    break;
                }
                if cfg.workload.is_enqueue(k, &mut rng) {
                    let v = value_for(cfg.salt, tid, counter);
                    counter += 1;
                    if cfg.record {
                        recorder.record(&mut log, tid, epoch, EventKind::EnqInvoke { value: v });
                    }
                    window.push_back(Pending::E(v, aq.enqueue_async(v)));
                } else {
                    if cfg.record {
                        recorder.record(&mut log, tid, epoch, EventKind::DeqInvoke);
                    }
                    window.push_back(Pending::D(aq.dequeue_async_tagged(tid as u64)));
                }
                if window.len() >= cfg.window.max(1) {
                    let p = window.pop_front().expect("window nonempty");
                    resolve(p, &recorder, &mut log, tid, epoch, cfg.record, &mut out);
                }
            }
            while let Some(p) = window.pop_front() {
                resolve(p, &recorder, &mut log, tid, epoch, cfg.record, &mut out);
            }
            (log, out)
        }));
    }

    let mut res = AsyncRunResult::default();
    for h in handles {
        let (log, out) = h.join().expect("producer panicked");
        res.logs.push(log);
        res.enq_ok += out.enq_ok;
        res.deq_ok += out.deq_ok;
        res.empties += out.empties;
        res.failed += out.failed_enq + out.failed_deq;
        res.failed_enq += out.failed_enq;
        res.failed_deq += out.failed_deq;
        res.enq_resolved.extend(out.enq_resolved);
        res.deq_resolved.extend(out.deq_resolved);
    }
    res.crashed = flusher.stop() || aq.crashed();
    // Harvest the combiner-recorded executed markers only after the
    // flusher workers joined (no more writers).
    res.logs.push(std::mem::take(&mut *exec_log.lock().unwrap()));
    res.stats = aq.stats();
    res.ops_done = res.enq_ok + res.deq_ok + res.empties;
    res.wall_secs = sw.elapsed_secs();
    res.sim_ns = topo.max_vtime();
    res.sim_mops = if res.sim_ns > 0 {
        res.ops_done as f64 / (res.sim_ns as f64 / 1e9) / 1e6
    } else {
        0.0
    };
    res.wall_mops = if res.wall_secs > 0.0 {
        res.ops_done as f64 / res.wall_secs / 1e6
    } else {
        0.0
    };
    res
}

#[derive(Default)]
struct ProducerOut {
    enq_ok: u64,
    deq_ok: u64,
    empties: u64,
    failed_enq: u64,
    failed_deq: u64,
    enq_resolved: Vec<u64>,
    deq_resolved: Vec<u64>,
}

fn resolve(
    p: Pending,
    recorder: &Recorder,
    log: &mut Vec<Event>,
    tid: usize,
    epoch: u64,
    record: bool,
    out: &mut ProducerOut,
) {
    match p {
        Pending::E(v, f) => match f.wait() {
            Ok(()) => {
                out.enq_ok += 1;
                out.enq_resolved.push(v);
                if record {
                    recorder.record(log, tid, epoch, EventKind::EnqOk { value: v });
                }
            }
            Err(_) => out.failed_enq += 1,
        },
        Pending::D(f) => match f.wait() {
            Ok(Some(v)) => {
                out.deq_ok += 1;
                out.deq_resolved.push(v);
                if record {
                    recorder.record(log, tid, epoch, EventKind::DeqOk { value: v });
                }
            }
            Ok(None) => {
                out.empties += 1;
                if record {
                    recorder.record(log, tid, epoch, EventKind::DeqEmpty);
                }
            }
            Err(_) => out.failed_deq += 1,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::queues::{ConcurrentQueue, QueueConfig};

    fn mk(
        shards: usize,
        batch: usize,
        batch_deq: usize,
        flushers: usize,
    ) -> (Topology, Arc<ShardedQueue>) {
        let topo = Topology::single(PmemConfig {
            capacity_words: 1 << 22,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 3,
        });
        let cfg = QueueConfig { shards, batch, batch_deq, ring_size: 256, ..Default::default() };
        let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4 + flushers, cfg).unwrap());
        (topo, q)
    }

    #[test]
    fn clean_async_run_resolves_everything() {
        let (topo, q) = mk(4, 4, 4, 2);
        let cfg = AsyncRunConfig {
            producers: 4,
            total_ops: 8_000,
            window: 16,
            acfg: AsyncCfg { flushers: 2, depth: 16, flush_us: 200, ..Default::default() },
            ..Default::default()
        };
        let r = run_async_workload(&topo, &q, &cfg);
        assert!(!r.crashed);
        assert_eq!(r.failed, 0, "clean run must fail nothing");
        assert_eq!(r.ops_done, 8_000);
        assert_eq!(r.enq_ok, r.enq_resolved.len() as u64);
        // Conservation: every resolved dequeue's value was a resolved (or
        // at least submitted) enqueue; with pairs + drain they balance.
        let drained = {
            let mut d = Vec::new();
            while let Some(v) = q.dequeue(0).unwrap() {
                d.push(v);
            }
            d
        };
        let mut all = r.deq_resolved.clone();
        all.extend(drained);
        all.sort_unstable();
        all.dedup();
        let mut enq = r.enq_resolved.clone();
        enq.sort_unstable();
        assert_eq!(all, enq, "resolved enqueues = resolved dequeues + drained, no dups");
    }

    #[test]
    fn async_run_records_checkable_history() {
        use crate::verify::{check_with, CheckOptions, History};
        let (topo, q) = mk(4, 4, 4, 1);
        let cfg = AsyncRunConfig {
            producers: 4,
            total_ops: 4_000,
            record: true,
            window: 8,
            acfg: AsyncCfg { flushers: 1, depth: 8, flush_us: 200, ..Default::default() },
            ..Default::default()
        };
        let r = run_async_workload(&topo, &q, &cfg);
        let drained = {
            let mut d = Vec::new();
            while let Some(v) = q.dequeue(0).unwrap() {
                d.push(v);
            }
            d
        };
        let h = History::from_logs(r.logs, drained);
        let rep = check_with(
            &h,
            &CheckOptions {
                relaxation: crate::verify::relaxation_for(
                    "sharded-perlcrq",
                    5,
                    &QueueConfig { shards: 4, batch: 4, batch_deq: 4, ..Default::default() },
                ),
                check_empty: false,
                ..Default::default()
            },
        );
        assert!(rep.ok(), "async history must verify: {:?}", rep.violations);
        assert!(rep.enq_completed > 0);
    }
}
