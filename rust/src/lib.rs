//! # persiq — Highly-Efficient Persistent FIFO Queues
//!
//! A reproduction framework for *"Highly-Efficient Persistent FIFO Queues"*
//! (Fatourou, Giachoudis, Mallis — 2024): persistent (durably linearizable)
//! concurrent FIFO queues built on Fetch&Increment to avoid contended hot
//! spots, executing **one `pwb` + `psync` pair per operation** on
//! low-contention memory locations.
//!
//! The crate provides:
//!
//! * [`pmem`] — a simulated NVM substrate implementing the *explicit epoch
//!   persistency* model of the paper (§2): a persistent arena whose 64-byte
//!   lines each have a *live* (cache) and a *shadow* (NVM) copy; `pwb`,
//!   `pfence`, `psync` primitives with a calibrated latency/contention cost
//!   model; full-system crash simulation with nondeterministic line eviction.
//!   [`pmem::Topology`] groups several pools into a multi-socket NVM
//!   topology: per-socket bandwidth chains, round-robin thread homes,
//!   cross-socket `pwb`/RMW penalties, and a coordinated machine-wide
//!   crash cut — with pool-qualified [`pmem::GAddr`] addressing and
//!   shard-placement policies (`interleave` | `colocate` | `pinned`).
//!   Every pool fronts its bump arena with [`pmem::palloc`], a
//!   size-classed persistent allocator: per-thread magazines (the
//!   steady-state alloc/free pair touches no shared word), per-class
//!   freelists, durable one-line segment headers whose free/reuse flips
//!   piggyback on caller psyncs (the `Alloc` obs site shows **zero**
//!   psyncs, ever), and a conservative one-scan crash rebuild that
//!   never double-allocates. The queue tiers recycle through it —
//!   closed LCRQ rings, retired re-sharding stripes and consumed
//!   blockfifo blocks all return to circulation epoch-safely — so
//!   long-running churn holds a memory plateau instead of bumping the
//!   arena forever (`--recycle off` keeps the leak-and-bump ablation).
//! * [`queues`] — the paper's algorithm family: IQ / PerIQ (Alg. 1, 6),
//!   CRQ / PerCRQ (Alg. 3), LCRQ / PerLCRQ (Alg. 5), plus the baselines its
//!   evaluation compares against: Michael–Scott queue, a durable MS queue,
//!   and the combining-based PBQueue / PWFQueue. Beyond the paper,
//!   [`queues::sharded`] stripes operations over K inner PerLCRQs
//!   (relaxed-FIFO, contention ÷ K) and adds group-commit batching on
//!   **both endpoints**: enqueue batches amortize `psync`s to 1/B per
//!   enqueue, and consumer-side dequeue batches
//!   (`QueueConfig::batch_deq`, `PersistCfg::defer_dequeue_sync`)
//!   amortize the `Head_i` drain to 1/K per dequeue, each side with
//!   batch-log-based crash reconciliation (psyncs/op: per-op 1+1,
//!   enq-batched 1/B+1, both-batched 1/B+1/K). [`queues::asyncq`] adds
//!   the **async completion layer** on top: `enqueue_async`/`dequeue_async`
//!   futures executed by flat-combining flusher workers and resolved only
//!   when the group-commit `psync` covering the operation retires —
//!   **durability-gated completion** (a resolved future is proof of
//!   durability; a crash fails unflushed futures with `Crashed`), so the
//!   async API keeps the 1/B + 1/K psync cost while restoring strict
//!   durable linearizability at the resolution boundary. The stripe set
//!   itself is elastic ([`queues::sharded::plan`]): epoch-versioned
//!   ShardPlans over a persistent plan log let `resize(new_k)` grow or
//!   shrink K **online** — freeze commit in one psync, drain-priority
//!   dequeue scans empty the frozen stripes, retirement is one psync,
//!   and crash recovery rolls a mid-transition crash forward to exactly
//!   one plan. One step further out on the amortization curve,
//!   [`queues::blockfifo`] claims **whole blocks** per FAI and seals
//!   them per psync (BlockFIFO/MultiFIFO-style, durably): `~1/block`
//!   FAIs and psyncs per operation on *both* endpoints, in exchange for
//!   bounded FIFO relaxation and block-sized crash windows.
//! * [`verify`] — history recording and a durable-linearizability checker,
//!   including the k-relaxed FIFO mode ([`verify::check_relaxed`]) that
//!   machine-verifies sharded histories up to bounded shard skew, plus
//!   crash-gated allowances for buffered durability: trailing losses
//!   (unflushed enqueue batches) and trailing redeliveries (unflushed
//!   dequeue batches), each bounded per `(thread, epoch)`, a
//!   cross-plan overtake allowance for re-sharding boundaries
//!   ([`verify::resharding_relaxation`]), and executed-marker-tightened
//!   loss budgets on async histories.
//! * [`harness`] — workload generators, the multi-thread runner with
//!   virtual-time metering, and the crash/recovery ("cycle") framework of §5.
//! * [`runtime`] — a PJRT wrapper that loads the AOT-compiled JAX/Pallas
//!   metrics pipeline (`artifacts/metrics.hlo.txt`) and runs it from Rust.
//! * [`coordinator`] — a persistent task-broker service built on PerLCRQ:
//!   the end-to-end example application; `submit_async`/`take_async`/
//!   `ack_async` ride the async completion layer, and per-job leases +
//!   `reap_expired` redeliver jobs whose worker died without a crash.
//! * [`obs`] — crate-wide observability: every `pwb`/`psync` is
//!   attributed to the [`obs::ObsSite`] that issued it (batch seal,
//!   dequeue flush, resize, plan commit, recovery, broker ack), turning
//!   the paper's `1/B + 1/K` cost accounting into an asserted
//!   per-site persistence ledger; plus a per-thread padded metrics
//!   registry, bounded JSONL event tracing (`--trace`), Prometheus-style
//!   exposition (`persiq obs`, `serve --metrics-every N`), and the
//!   NVM-resident **flight recorder** ([`obs::flight`]): per-thread
//!   event rings written with pwbs that piggyback on the psyncs the
//!   algorithms already issue (zero extra psyncs, asserted in
//!   `obs_ledger.rs`), scanned post-crash by `persiq forensics` and
//!   cross-checked against what recovery delivers.
//! * [`util`] — self-contained infrastructure (PRNG, CLI, config, reporters)
//!   since this build environment is offline.
//!
//! ## Quickstart
//!
//! ```no_run
//! # // compile-only: rustdoc test binaries don't inherit the xla rpath
//! # // (behaviour covered by unit/integration tests)
//! use std::sync::Arc;
//! use persiq::pmem::{PmemPool, PmemConfig};
//! use persiq::queues::{perlcrq::PerLcrq, ConcurrentQueue};
//!
//! let pool = Arc::new(PmemPool::new(PmemConfig::default()));
//! let q = PerLcrq::new(&pool, 4 /* threads */, Default::default());
//! q.enqueue(0, 42).unwrap();
//! assert_eq!(q.dequeue(0).unwrap(), Some(42));
//! ```

pub mod config;
pub mod coordinator;
pub mod harness;
pub mod obs;
pub mod pmem;
pub mod queues;
pub mod runtime;
pub mod util;
pub mod verify;

/// Crate version string (from Cargo).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
