//! The metrics facade: PJRT artifact execution with pure-Rust fallback.

use std::path::Path;

use anyhow::Result;

use super::engine::{default_artifact_dir, Engine};
use super::fallback;

/// Aggregated statistics for one latency-sample set.
#[derive(Clone, Debug, Default)]
pub struct MetricsOut {
    pub count: f64,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub hist: Vec<f64>,
    /// "pjrt" or "fallback" — recorded in reports for transparency.
    pub backend: &'static str,
}

impl MetricsOut {
    fn from_raw(stats: [f64; 8], hist: Vec<f64>, backend: &'static str) -> Self {
        Self {
            count: stats[0],
            mean: stats[1],
            std: stats[2],
            min: stats[3],
            max: stats[4],
            p50: stats[5],
            p95: stats[6],
            p99: stats[7],
            hist,
            backend,
        }
    }
}

/// Scaling-model fit result (`t(n) = n / (a + b·n)`).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalingFit {
    pub a: f64,
    pub b: f64,
    /// Saturation throughput `1/b`.
    pub plateau: f64,
}

/// The engine: PJRT-compiled artifacts when available, fallback otherwise.
pub enum MetricsEngine {
    Pjrt(Engine),
    Fallback,
}

impl MetricsEngine {
    /// Load from the default artifact location; fall back (with a warning)
    /// when artifacts are missing or fail to compile.
    pub fn auto() -> MetricsEngine {
        match default_artifact_dir() {
            Some(dir) => match Engine::load(&dir) {
                Ok(e) => {
                    crate::log_info!(
                        "metrics engine: PJRT artifacts from {}",
                        dir.display()
                    );
                    MetricsEngine::Pjrt(e)
                }
                Err(e) => {
                    crate::log_warn!(
                        "metrics engine: artifact load failed ({e:#}); using Rust fallback"
                    );
                    MetricsEngine::Fallback
                }
            },
            None => {
                crate::log_warn!(
                    "metrics engine: no artifacts found (run `make artifacts`); using \
                     Rust fallback"
                );
                MetricsEngine::Fallback
            }
        }
    }

    /// Load from an explicit directory (errors instead of falling back).
    pub fn from_dir(dir: &Path) -> Result<MetricsEngine> {
        Ok(MetricsEngine::Pjrt(Engine::load(dir)?))
    }

    pub fn backend(&self) -> &'static str {
        match self {
            MetricsEngine::Pjrt(_) => "pjrt",
            MetricsEngine::Fallback => "fallback",
        }
    }

    /// Aggregate latency samples (negative entries are padding).
    pub fn metrics(&self, samples: &[f64]) -> Result<MetricsOut> {
        match self {
            MetricsEngine::Pjrt(e) => {
                let (stats, hist) = e.metrics(samples)?;
                Ok(MetricsOut::from_raw(stats, hist, "pjrt"))
            }
            MetricsEngine::Fallback => {
                let (stats, hist) = fallback::metrics(samples);
                Ok(MetricsOut::from_raw(stats, hist, "fallback"))
            }
        }
    }

    /// Fit the saturating scaling model to `(threads, throughput)` points.
    pub fn fit(&self, ns: &[f64], tputs: &[f64]) -> Result<ScalingFit> {
        let [a, b, plateau] = match self {
            MetricsEngine::Pjrt(e) => e.fit(ns, tputs)?,
            MetricsEngine::Fallback => fallback::fit(ns, tputs),
        };
        Ok(ScalingFit { a, b, plateau })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fallback_metrics_roundtrip() {
        let eng = MetricsEngine::Fallback;
        let samples: Vec<f64> = (0..100).map(|i| 100.0 + i as f64).collect();
        let m = eng.metrics(&samples).unwrap();
        assert_eq!(m.count, 100.0);
        assert_eq!(m.backend, "fallback");
        assert!(m.min >= 100.0 && m.max <= 199.0 + 1e-9);
        assert!(m.p50 > m.min && m.p99 <= m.max + 3.0);
    }

    #[test]
    fn fallback_fit() {
        let eng = MetricsEngine::Fallback;
        let ns: Vec<f64> = (1..=8).map(|i| i as f64).collect();
        let t: Vec<f64> = ns.iter().map(|&n| n / (1.0 + 0.2 * n)).collect();
        let f = eng.fit(&ns, &t).unwrap();
        assert!((f.plateau - 5.0).abs() < 1e-6);
    }
}
