//! Pure-Rust metrics fallback, mirroring the L2 pipeline's semantics
//! (including its histogram-CDF quantiles) so the PJRT artifact can be
//! cross-checked bit-for-bit-ish in integration tests, and the CLI keeps
//! working without artifacts.

use super::engine::NBINS;

/// Compute `(stats\[8\], hist[NBINS])` exactly like `model.metrics` does:
/// normalize to `[min, max)`, 64-bucket histogram, moments, CDF quantiles.
/// The math lives in [`crate::obs::summary::cdf_metrics`] (relocated
/// verbatim, still cross-checked bit-for-bit-ish against the PJRT
/// artifact by the integration tests).
pub fn metrics(samples: &[f64]) -> ([f64; 8], Vec<f64>) {
    crate::obs::summary::cdf_metrics(samples, NBINS)
}

/// Closed-form least-squares of `t(n) = n/(a + b·n)` (linearized), exactly
/// like `model.fit_scaling`. Entries with `tput <= 0` are masked.
pub fn fit(ns: &[f64], tputs: &[f64]) -> [f64; 3] {
    assert_eq!(ns.len(), tputs.len());
    let (mut n, mut sx, mut sy, mut sxx, mut sxy) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for (&x, &t) in ns.iter().zip(tputs) {
        if t <= 0.0 {
            continue;
        }
        let y = x / t;
        n += 1.0;
        sx += x;
        sy += y;
        sxx += x * x;
        sxy += x * y;
    }
    if n < 1.0 {
        return [0.0; 3];
    }
    let denom = n * sxx - sx * sx;
    let b = if denom.abs() > 1e-9 { (n * sxy - sx * sy) / denom } else { 0.0 };
    let a = (sy - b * sx) / n;
    let plateau = if b.abs() > 1e-12 { 1.0 / b } else { 0.0 };
    [a, b, plateau]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_on_known_data() {
        let samples: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let (s, hist) = metrics(&samples);
        assert_eq!(s[0], 1000.0);
        assert!((s[1] - 500.5).abs() < 0.5);
        assert!((s[3] - 1.0).abs() < 1e-9);
        assert!((s[4] - 1000.0).abs() < 1e-9);
        // p50 within one bucket (~15.6) of 500.
        assert!((s[5] - 500.0).abs() < 20.0, "p50={}", s[5]);
        assert!((s[6] - 950.0).abs() < 20.0, "p95={}", s[6]);
        assert_eq!(hist.iter().sum::<f64>(), 1000.0);
    }

    #[test]
    fn empty_and_padding() {
        let (s, hist) = metrics(&[-1.0, -1.0]);
        assert_eq!(s[0], 0.0);
        assert_eq!(hist.iter().sum::<f64>(), 0.0);
        let (s, _) = metrics(&[5.0, -1.0, 7.0]);
        assert_eq!(s[0], 2.0);
        assert!((s[1] - 6.0).abs() < 1e-6);
    }

    #[test]
    fn constant_data() {
        let (s, _) = metrics(&[42.0; 64]);
        assert_eq!(s[0], 64.0);
        assert!((s[1] - 42.0).abs() < 1e-6);
        assert!(s[2].abs() < 1e-6);
    }

    #[test]
    fn fit_recovers_parameters() {
        let ns: Vec<f64> = (1..=16).map(|i| i as f64).collect();
        let t: Vec<f64> = ns.iter().map(|&n| n / (2.0 + 0.05 * n)).collect();
        let [a, b, plateau] = fit(&ns, &t);
        assert!((a - 2.0).abs() < 1e-6);
        assert!((b - 0.05).abs() < 1e-9);
        assert!((plateau - 20.0).abs() < 1e-4);
    }

    #[test]
    fn fit_masks_zero_tput() {
        let ns: Vec<f64> = (1..=16).map(|i| i as f64).collect();
        let mut t: Vec<f64> = ns.iter().map(|&n| n / (1.0 + 0.1 * n)).collect();
        for v in t.iter_mut().skip(10) {
            *v = 0.0;
        }
        let [_, b, _] = fit(&ns, &t);
        assert!((b - 0.1).abs() < 1e-9);
    }
}
