//! PJRT execution of the AOT artifacts.
//!
//! Interchange contract (see python/compile/aot.py): artifacts are HLO
//! **text**; `HloModuleProto::from_text_file` reparses and reassigns
//! instruction ids, sidestepping the 64-bit-id protos that xla_extension
//! 0.5.1 rejects.
//!
//! The `xla` crate is not available in this offline build, so the real
//! engine is gated behind the off-by-default `pjrt` cargo feature (enable
//! it only in an environment that vendors/patches in an `xla` crate). The
//! default build compiles an API-identical stub whose [`Engine::load`]
//! always fails, which routes every caller through the pure-Rust
//! [`super::fallback`] with a warning — the CLI, benches and tests all
//! keep working.

use std::path::{Path, PathBuf};

use anyhow::Result;

/// Shapes the artifacts were exported with (must match python/compile).
pub const METRICS_ROWS: usize = 64;
pub const METRICS_COLS: usize = 128;
pub const METRICS_SAMPLES: usize = METRICS_ROWS * METRICS_COLS;
pub const NBINS: usize = 64;
pub const FIT_POINTS: usize = 16;

/// Locate the artifacts directory: `$PERSIQ_ARTIFACTS`, else `artifacts/`
/// relative to the workspace root (walking up from cwd).
pub fn default_artifact_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PERSIQ_ARTIFACTS") {
        let p = PathBuf::from(p);
        if p.join("metrics.hlo.txt").exists() {
            return Some(p);
        }
    }
    let mut cur = std::env::current_dir().ok()?;
    loop {
        let cand = cur.join("artifacts");
        if cand.join("metrics.hlo.txt").exists() {
            return Some(cand);
        }
        if !cur.pop() {
            return None;
        }
    }
}

/// Compiled artifact bundle on a PJRT CPU client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    metrics_exe: xla::PjRtLoadedExecutable,
    fit_exe: xla::PjRtLoadedExecutable,
    dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load and compile `metrics.hlo.txt` + `fit.hlo.txt` from `dir`.
    pub fn load(dir: &Path) -> Result<Engine> {
        use anyhow::Context;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = dir.join(name);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };
        Ok(Engine {
            metrics_exe: compile("metrics.hlo.txt")?,
            fit_exe: compile("fit.hlo.txt")?,
            client,
            dir: dir.to_path_buf(),
        })
    }

    /// Artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Run the metrics pipeline on up to [`METRICS_SAMPLES`] samples
    /// (extra samples are deterministically stride-downsampled; fewer are
    /// padded with the `-1` sentinel).
    ///
    /// Returns `(stats\[8\], hist[NBINS])` with stats
    /// `[count, mean, std, min, max, p50, p95, p99]`.
    pub fn metrics(&self, samples: &[f64]) -> Result<([f64; 8], Vec<f64>)> {
        let mut buf = vec![-1.0f32; METRICS_SAMPLES];
        if samples.len() <= METRICS_SAMPLES {
            for (i, &s) in samples.iter().enumerate() {
                buf[i] = s as f32;
            }
        } else {
            // Deterministic stride sampling keeps the distribution shape.
            let stride = samples.len() as f64 / METRICS_SAMPLES as f64;
            for i in 0..METRICS_SAMPLES {
                buf[i] = samples[(i as f64 * stride) as usize] as f32;
            }
        }
        let lit = xla::Literal::vec1(&buf)
            .reshape(&[METRICS_ROWS as i64, METRICS_COLS as i64])?;
        let result = self.metrics_exe.execute::<xla::Literal>(&[lit])?[0][0]
            .to_literal_sync()?;
        let (stats_l, hist_l) = result.to_tuple2()?;
        let stats_v = stats_l.to_vec::<f32>()?;
        let hist_v = hist_l.to_vec::<f32>()?;
        anyhow::ensure!(stats_v.len() == 8, "bad stats arity {}", stats_v.len());
        anyhow::ensure!(hist_v.len() == NBINS, "bad hist arity {}", hist_v.len());
        let mut stats = [0.0f64; 8];
        for (o, v) in stats.iter_mut().zip(&stats_v) {
            *o = *v as f64;
        }
        Ok((stats, hist_v.into_iter().map(|v| v as f64).collect()))
    }

    /// Fit the saturating-throughput model `t(n) = n/(a + b·n)` over up to
    /// [`FIT_POINTS`] `(threads, throughput)` points. Returns
    /// `[a, b, plateau]`.
    pub fn fit(&self, ns: &[f64], tputs: &[f64]) -> Result<[f64; 3]> {
        anyhow::ensure!(ns.len() == tputs.len(), "fit arity mismatch");
        anyhow::ensure!(ns.len() <= FIT_POINTS, "at most {FIT_POINTS} fit points");
        let mut nbuf = vec![0.0f32; FIT_POINTS];
        let mut tbuf = vec![0.0f32; FIT_POINTS]; // tput <= 0 is masked out
        for i in 0..ns.len() {
            nbuf[i] = ns[i] as f32;
            tbuf[i] = tputs[i] as f32;
        }
        let ln = xla::Literal::vec1(&nbuf);
        let lt = xla::Literal::vec1(&tbuf);
        let result =
            self.fit_exe.execute::<xla::Literal>(&[ln, lt])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?.to_vec::<f32>()?;
        anyhow::ensure!(out.len() == 3, "bad fit arity {}", out.len());
        Ok([out[0] as f64, out[1] as f64, out[2] as f64])
    }
}

/// Stub engine compiled when the `pjrt` feature (and thus the `xla` crate)
/// is absent: loading always fails, so [`super::MetricsEngine::auto`]
/// falls back to the pure-Rust implementation with a warning.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    dir: PathBuf,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always fails in this build: PJRT support is feature-gated off.
    pub fn load(dir: &Path) -> Result<Engine> {
        anyhow::bail!(
            "PJRT engine not compiled in (offline build without the `xla` crate; \
             artifacts at {}); rebuild with --features pjrt in an environment \
             providing it",
            dir.display()
        )
    }

    /// Artifact directory this engine was loaded from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Unreachable in practice: [`Engine::load`] never succeeds here.
    pub fn metrics(&self, _samples: &[f64]) -> Result<([f64; 8], Vec<f64>)> {
        anyhow::bail!("PJRT engine not compiled in")
    }

    /// Unreachable in practice: [`Engine::load`] never succeeds here.
    pub fn fit(&self, _ns: &[f64], _tputs: &[f64]) -> Result<[f64; 3]> {
        anyhow::bail!("PJRT engine not compiled in")
    }
}
