//! The PJRT runtime: loads the AOT-compiled JAX/Pallas metrics pipeline
//! (HLO text artifacts produced by `python/compile/aot.py`) and executes
//! it from Rust. Python never runs at analysis time; the `persiq` binary
//! is self-contained once `make artifacts` has been run.
//!
//! * [`engine`] — PJRT client wrapper: text → `HloModuleProto` → compile →
//!   execute (pattern from /opt/xla-example/load_hlo).
//! * [`fallback`] — a pure-Rust implementation of the same statistics,
//!   used (a) to cross-check the artifact numerics in tests, and (b) to
//!   keep the CLI functional when artifacts are absent (with a warning).
//! * [`metrics`] — the user-facing facade choosing PJRT or fallback.

pub mod engine;
pub mod fallback;
pub mod metrics;

pub use metrics::{MetricsEngine, MetricsOut, ScalingFit};
