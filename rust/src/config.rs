//! Central configuration: `persiq.toml` (TOML subset) + CLI overrides.
//!
//! Sections:
//! ```toml
//! [pmem]
//! capacity_words = 4194304
//! evict_prob = 0.25
//! pending_flush_prob = 0.5
//!
//! [pmem.cost]
//! pwb_ns = 60
//! psync_ns = 100
//! # ... every CostModel knob (see pmem/latency.rs)
//!
//! [queue]
//! ring_size = 1024
//! iq_capacity = 65536
//! starvation_limit = 4096
//! shards = 4          # sharded-perlcrq stripe count
//! batch = 1           # sharded-perlcrq enqueue group-commit size (1 = per-op)
//! batch_deq = 1       # sharded-perlcrq dequeue group-commit size (1 = per-op)
//!
//! [topology]
//! pools = 1                  # NVM pools (sockets), each with its own bandwidth chain
//! placement = "interleave"   # interleave | colocate | pinned:<p0,p1,...>
//!
//! [async]
//! flush_us = 50      # completion-layer deadline flush (µs)
//! depth = 32         # per-flusher in-flight window (depth flush trigger)
//! flushers = 1       # combiner worker threads
//!
//! [alloc]
//! recycle = true     # palloc segment recycling (false = leak-and-bump ablation)
//! magazine = 8       # per-thread magazine capacity (segments per size class)
//!
//! [broker]
//! lease_ms = 0       # per-job lease on in-flight jobs (0 = off)
//!
//! [resharding]
//! schedule = "4:8@50"   # start at 4 shards, resize online to 8 at 50% of ops
//!
//! [bench]
//! ops = 200000
//! seed = 42
//! ```

use std::path::Path;

use crate::pmem::{CostModel, PlacementPolicy, PmemConfig, Topology, MAX_POOLS};
use crate::queues::asyncq::AsyncCfg;
use crate::queues::{QueueConfig, MAX_SHARDS};
use crate::util::toml::Doc;

/// An online re-sharding schedule (`--resharding-schedule` /
/// `[resharding] schedule`): start at `from_k` stripes and resize to
/// `to_k` once `at_percent`% of the workload's ops have run on thread 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReshardSchedule {
    pub from_k: usize,
    pub to_k: usize,
    /// Percent of the run at which the resize triggers (1..=99).
    pub at_percent: u64,
}

impl ReshardSchedule {
    /// Parse `"<from>:<to>@<pct>"` (a trailing `%` is accepted), e.g.
    /// `4:8@50` or `8:4@25%`.
    pub fn parse(s: &str) -> Result<ReshardSchedule, String> {
        let t = s.trim().trim_end_matches('%');
        let (ks, pct) = t
            .split_once('@')
            .ok_or_else(|| format!("bad resharding schedule {s:?} (expected from:to@pct)"))?;
        let (from, to) = ks
            .split_once(':')
            .ok_or_else(|| format!("bad resharding schedule {s:?} (expected from:to@pct)"))?;
        let from_k: usize =
            from.trim().parse().map_err(|_| format!("bad shard count {from:?}"))?;
        let to_k: usize = to.trim().parse().map_err(|_| format!("bad shard count {to:?}"))?;
        let at_percent: u64 =
            pct.trim().parse().map_err(|_| format!("bad percentage {pct:?}"))?;
        if from_k == 0 || from_k > MAX_SHARDS || to_k == 0 || to_k > MAX_SHARDS {
            return Err(format!("shard counts must be in 1..={MAX_SHARDS}"));
        }
        if !(1..=99).contains(&at_percent) {
            return Err("resize percentage must be in 1..=99".to_string());
        }
        Ok(ReshardSchedule { from_k, to_k, at_percent })
    }
}

impl std::str::FromStr for ReshardSchedule {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ReshardSchedule::parse(s)
    }
}

impl std::fmt::Display for ReshardSchedule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}@{}", self.from_k, self.to_k, self.at_percent)
    }
}

/// Fully resolved configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub pmem: PmemConfig,
    pub queue: QueueConfig,
    /// NVM pools (sockets) in the topology; each gets its own
    /// `pmem.capacity_words`-sized arena and bandwidth chain.
    pub pools: usize,
    /// Async completion layer knobs (`--async` CLI paths).
    pub asyncq: AsyncCfg,
    /// Broker per-job lease in ms (0 = disabled).
    pub lease_ms: u64,
    /// Online re-sharding schedule for bench/verify workloads (`None` =
    /// fixed shard count).
    pub resharding: Option<ReshardSchedule>,
    pub bench_ops: u64,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            pmem: PmemConfig::default().with_capacity(1 << 22),
            queue: QueueConfig::default(),
            pools: 1,
            asyncq: AsyncCfg::default(),
            lease_ms: 0,
            resharding: None,
            bench_ops: 200_000,
            seed: 42,
        }
    }
}

impl Config {
    /// Load from a TOML file, falling back to defaults per key.
    pub fn from_file(path: &Path) -> anyhow::Result<Config> {
        let doc = crate::util::toml::parse_file(path)?;
        Ok(Self::from_doc(&doc))
    }

    /// Load `persiq.toml` from the working directory if present.
    pub fn load_default() -> Config {
        let path = Path::new("persiq.toml");
        if path.exists() {
            match Self::from_file(path) {
                Ok(c) => return c,
                Err(e) => {
                    crate::log_warn!("ignoring persiq.toml: {e:#}");
                }
            }
        }
        Config::default()
    }

    /// Build from a parsed document.
    pub fn from_doc(doc: &Doc) -> Config {
        let mut c = Config::default();
        c.pmem.capacity_words =
            doc.get_u64("pmem", "capacity_words", c.pmem.capacity_words as u64) as usize;
        c.pmem.evict_prob = doc.get_f64("pmem", "evict_prob", c.pmem.evict_prob);
        c.pmem.pending_flush_prob =
            doc.get_f64("pmem", "pending_flush_prob", c.pmem.pending_flush_prob);
        c.pmem.seed = doc.get_u64("pmem", "seed", c.pmem.seed);
        let mut cost = CostModel::default();
        cost.apply_toml(doc, "pmem.cost");
        c.pmem.cost = cost;

        c.queue.ring_size = doc.get_u64("queue", "ring_size", c.queue.ring_size as u64) as usize;
        c.queue.iq_capacity =
            doc.get_u64("queue", "iq_capacity", c.queue.iq_capacity as u64) as usize;
        c.queue.starvation_limit =
            doc.get_u64("queue", "starvation_limit", c.queue.starvation_limit as u64) as usize;
        c.queue.periq_tail_interval = doc
            .get_u64("queue", "periq_tail_interval", c.queue.periq_tail_interval as u64)
            as usize;
        c.queue.shards = doc.get_u64("queue", "shards", c.queue.shards as u64) as usize;
        c.queue.batch = doc.get_u64("queue", "batch", c.queue.batch as u64) as usize;
        c.queue.batch_deq =
            doc.get_u64("queue", "batch_deq", c.queue.batch_deq as u64) as usize;
        c.queue.block = doc.get_u64("queue", "block", c.queue.block as u64) as usize;
        c.queue.dchoice = doc.get_u64("queue", "dchoice", c.queue.dchoice as u64) as usize;

        c.queue.recycle = doc.get_bool("alloc", "recycle", c.queue.recycle);
        c.queue.magazine = doc.get_u64("alloc", "magazine", c.queue.magazine as u64) as usize;

        let pools = doc.get_u64("topology", "pools", c.pools as u64) as usize;
        if pools < 1 || pools > MAX_POOLS {
            // Config-file parsing is lenient throughout (bad keys fall
            // back with a warning, like placement below) — the CLI layer
            // re-validates with a hard error.
            crate::log_warn!(
                "ignoring [topology] pools = {pools} (must be in 1..={MAX_POOLS})"
            );
        } else {
            c.pools = pools;
        }
        let placement = doc.get_str("topology", "placement", "");
        if !placement.is_empty() {
            match PlacementPolicy::parse(placement) {
                Ok(p) => c.queue.placement = p,
                Err(e) => crate::log_warn!("ignoring [topology] placement: {e}"),
            }
        }

        c.asyncq.flush_us = doc.get_u64("async", "flush_us", c.asyncq.flush_us);
        c.asyncq.depth = doc.get_u64("async", "depth", c.asyncq.depth as u64) as usize;
        c.asyncq.flushers =
            doc.get_u64("async", "flushers", c.asyncq.flushers as u64) as usize;
        if let Err(e) = c.asyncq.validate() {
            // Lenient like the rest of the file parser; the CLI layer
            // re-validates with a hard error.
            crate::log_warn!("ignoring [async] section: {e}");
            c.asyncq = AsyncCfg::default();
        }
        c.lease_ms = doc.get_u64("broker", "lease_ms", c.lease_ms);

        let schedule = doc.get_str("resharding", "schedule", "");
        if !schedule.is_empty() {
            match ReshardSchedule::parse(schedule) {
                Ok(s) => c.resharding = Some(s),
                Err(e) => crate::log_warn!("ignoring [resharding] schedule: {e}"),
            }
        }

        c.bench_ops = doc.get_u64("bench", "ops", c.bench_ops);
        c.seed = doc.get_u64("bench", "seed", c.seed);
        c
    }

    /// Build the NVM topology this configuration describes (`pools`
    /// pools of `pmem` each, homes assigned round-robin). `from_doc`
    /// rejects out-of-range counts at parse time and the CLI re-validates
    /// with a hard error; the clamp here only guards programmatic
    /// `Config` construction with a bad literal.
    pub fn build_topology(&self) -> Topology {
        Topology::new(self.pmem.clone(), self.pools.clamp(1, MAX_POOLS))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = Config::default();
        assert!(c.pmem.capacity_words >= 1 << 20);
        assert!(c.queue.ring_size.is_power_of_two());
    }

    #[test]
    fn doc_overrides() {
        let doc = crate::util::toml::parse(
            "[pmem]\ncapacity_words = 1024\n[pmem.cost]\npwb_ns = 999\n\
             [queue]\nring_size = 64\nblock = 32\ndchoice = 3\n[bench]\nops = 7\nseed = 8\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.pmem.capacity_words, 1024);
        assert_eq!(c.pmem.cost.pwb_ns, 999);
        assert_eq!(c.queue.ring_size, 64);
        assert_eq!(c.queue.block, 32);
        assert_eq!(c.queue.dchoice, 3);
        assert_eq!(c.bench_ops, 7);
        assert_eq!(c.seed, 8);
        // Untouched keys keep defaults.
        assert_eq!(c.pmem.cost.psync_ns, CostModel::default().psync_ns);
        assert_eq!(c.pools, 1);
        assert_eq!(c.queue.placement, crate::pmem::PlacementPolicy::Interleave);
    }

    #[test]
    fn topology_section_overrides() {
        let doc = crate::util::toml::parse(
            "[topology]\npools = 2\nplacement = \"colocate\"\n\
             [pmem.cost]\nremote_pwb_ns = 240\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.pools, 2);
        assert_eq!(c.queue.placement, crate::pmem::PlacementPolicy::Colocate);
        assert_eq!(c.pmem.cost.remote_pwb_ns, 240);
        let topo = c.build_topology();
        assert_eq!(topo.len(), 2);
        assert_eq!(topo.home_of(1), 1);
        // Pinned parses too.
        let doc =
            crate::util::toml::parse("[topology]\npools = 2\nplacement = \"pinned:1,0\"\n")
                .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(
            c.queue.placement,
            crate::pmem::PlacementPolicy::Pinned(vec![1, 0])
        );
        // A bad placement string is ignored with a warning, not fatal.
        let doc = crate::util::toml::parse("[topology]\nplacement = \"nearest\"\n").unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.queue.placement, crate::pmem::PlacementPolicy::Interleave);
        // An out-of-range pool count is likewise rejected leniently at
        // parse time (the CLI layer hard-errors instead).
        let doc = crate::util::toml::parse("[topology]\npools = 99\n").unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.pools, 1, "out-of-range [topology] pools must fall back");
        assert_eq!(c.build_topology().len(), 1);
    }

    #[test]
    fn resharding_schedule_parses() {
        let s = ReshardSchedule::parse("4:8@50").unwrap();
        assert_eq!(s, ReshardSchedule { from_k: 4, to_k: 8, at_percent: 50 });
        assert_eq!(ReshardSchedule::parse(" 8:4@25% ").unwrap().to_string(), "8:4@25");
        assert!(ReshardSchedule::parse("4:8").is_err());
        assert!(ReshardSchedule::parse("0:8@50").is_err());
        assert!(ReshardSchedule::parse("4:65@50").is_err());
        assert!(ReshardSchedule::parse("4:8@0").is_err());
        assert!(ReshardSchedule::parse("4:8@100").is_err());
        // Config-file plumbing (lenient on bad values, like the rest).
        let doc =
            crate::util::toml::parse("[resharding]\nschedule = \"4:8@50\"\n").unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.resharding, Some(ReshardSchedule { from_k: 4, to_k: 8, at_percent: 50 }));
        let doc = crate::util::toml::parse("[resharding]\nschedule = \"nope\"\n").unwrap();
        assert_eq!(Config::from_doc(&doc).resharding, None);
    }

    #[test]
    fn alloc_section_overrides() {
        let doc =
            crate::util::toml::parse("[alloc]\nrecycle = false\nmagazine = 4\n").unwrap();
        let c = Config::from_doc(&doc);
        assert!(!c.queue.recycle);
        assert_eq!(c.queue.magazine, 4);
        // Untouched keys keep defaults (recycling on).
        let c = Config::from_doc(&crate::util::toml::parse("").unwrap());
        assert!(c.queue.recycle);
        assert_eq!(c.queue.magazine, crate::pmem::palloc::DEFAULT_MAGAZINE);
    }

    #[test]
    fn async_and_broker_sections_override() {
        let doc = crate::util::toml::parse(
            "[async]\nflush_us = 120\ndepth = 64\nflushers = 2\n[broker]\nlease_ms = 250\n",
        )
        .unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.asyncq.flush_us, 120);
        assert_eq!(c.asyncq.depth, 64);
        assert_eq!(c.asyncq.flushers, 2);
        assert_eq!(c.lease_ms, 250);
        // An invalid [async] section falls back leniently.
        let doc = crate::util::toml::parse("[async]\ndepth = 0\n").unwrap();
        let c = Config::from_doc(&doc);
        assert_eq!(c.asyncq.depth, AsyncCfg::default().depth);
        // Untouched keys keep defaults.
        let c = Config::from_doc(&crate::util::toml::parse("").unwrap());
        assert_eq!(c.asyncq.flush_us, AsyncCfg::default().flush_us);
        assert_eq!(c.lease_ms, 0);
    }
}
