//! Self-contained infrastructure utilities.
//!
//! This build image is offline: `clap`, `serde`, `rand`, `criterion`,
//! `proptest` are unavailable, so the framework ships minimal, tested
//! replacements: a splittable PRNG ([`rng`]), a CLI argument parser
//! ([`cli`]), a TOML-subset config reader ([`toml`]), CSV/JSON report
//! writers ([`report`]), a leveled logger ([`logging`]), and timing helpers
//! ([`time`]).

pub mod affinity;
pub mod cli;
pub mod logging;
pub mod report;
pub mod rng;
pub mod time;
pub mod toml;
