//! Timing helpers: monotonic stopwatch, simple duration stats, and a
//! calibrated busy-wait used by the pmem latency model to charge simulated
//! persistence costs in *wall-clock* mode (virtual-clock mode never spins).

use std::time::{Duration, Instant};

/// Monotonic stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_ns(&self) -> u64 {
        self.elapsed().as_nanos() as u64
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Summary statistics over a set of f64 samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute [`Stats`] (population std) over `xs`. Delegates to the
/// crate's one summarizer ([`crate::obs::summary`]) so every report
/// agrees on the math.
pub fn stats(xs: &[f64]) -> Stats {
    let m = crate::obs::summary::moments(xs);
    Stats { n: m.n, mean: m.mean, std: m.std, min: m.min, max: m.max }
}

/// Percentile (nearest-rank) over a *sorted* slice. Delegates to
/// [`crate::obs::summary::percentile_sorted`].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    crate::obs::summary::percentile_sorted(sorted, p)
}

/// Busy-wait for approximately `ns` nanoseconds (no syscall, no yield).
/// Used to make simulated persistence instructions consume real CPU the way
/// a blocking `psync` does on Optane, so wall-clock comparisons between
/// algorithms remain meaningful on this testbed.
#[inline]
pub fn spin_ns(ns: u64) {
    if ns == 0 {
        return;
    }
    let start = Instant::now();
    while (start.elapsed().as_nanos() as u64) < ns {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn stats_empty() {
        let s = stats(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&v, 50.0), 50.0);
        assert_eq!(percentile_sorted(&v, 95.0), 95.0);
        assert_eq!(percentile_sorted(&v, 100.0), 100.0);
        assert_eq!(percentile_sorted(&v, 1.0), 1.0);
    }

    #[test]
    fn spin_roughly_waits() {
        let sw = Stopwatch::start();
        spin_ns(100_000); // 100µs
        assert!(sw.elapsed_ns() >= 100_000);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        let a = sw.elapsed_ns();
        let b = sw.elapsed_ns();
        assert!(b >= a);
    }
}
