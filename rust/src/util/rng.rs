//! Deterministic, splittable pseudo-random number generation.
//!
//! `rand` is not available offline; we implement SplitMix64 (for seeding /
//! splitting) and xoshiro256** (the workhorse generator, Blackman–Vigna).
//! All harness randomness flows through these so every run is reproducible
//! from a single printed seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state and
/// to derive independent child seeds (one per worker thread).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // All-zero state is invalid; SplitMix64 cannot produce 4 zero outputs
        // from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent child generator (`stream`-th thread).
    pub fn split(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
        Self::seed_from(sm.next_u64())
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply; bias negligible for our use (no rejection loop
        // needed for simulation workloads, but we do one to be exact).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Random boolean.
    #[inline]
    pub fn next_bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.next_below(xs.len() as u64) as usize]
    }
}

/// Derive a fresh "entropy" seed from the OS monotonic clock + ASLR. Only
/// used when the user does not pass `--seed`; the chosen seed is printed so
/// runs remain reproducible after the fact.
pub fn entropy_seed() -> u64 {
    use std::time::{SystemTime, UNIX_EPOCH};
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0xDEAD_BEEF);
    let stack_probe = &t as *const _ as u64; // ASLR noise
    let mut sm = SplitMix64::new(t ^ stack_probe.rotate_left(17));
    sm.next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::split(42, 0);
        let mut b = Xoshiro256::split(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb, "streams must be independent");
        let mut a2 = Xoshiro256::split(42, 0);
        let va2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_eq!(va, va2, "same seed+stream must reproduce");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Xoshiro256::seed_from(7);
        for bound in [1u64, 2, 3, 10, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_small_range() {
        let mut r = Xoshiro256::seed_from(9);
        let mut seen = [false; 5];
        for _ in 0..500 {
            seen[r.next_below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from(11);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_extremes() {
        let mut r = Xoshiro256::seed_from(13);
        for _ in 0..100 {
            assert!(!r.chance(0.0));
            assert!(r.chance(1.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Xoshiro256::seed_from(23);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            match r.range_inclusive(3, 6) {
                3 => lo_seen = true,
                6 => hi_seen = true,
                4 | 5 => {}
                x => panic!("out of range: {x}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }
}
