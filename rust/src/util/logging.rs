//! Tiny leveled logger writing to stderr. Controlled by `PERSIQ_LOG` or
//! programmatically via [`set_level`].
//!
//! `PERSIQ_LOG` accepts a comma-separated directive list: a bare level
//! (`error|warn|info|debug|trace`) sets the global threshold, and
//! `<module>=<level>` overrides it for one module subtree (matched by
//! module-path prefix, with or without the leading `persiq::`), e.g.:
//!
//! ```text
//! PERSIQ_LOG=warn,coordinator=debug,persiq::queues::sharded=trace
//! ```
//!
//! Records carry a timestamp (seconds since the first log call) and the
//! issuing module path:
//!
//! ```text
//! [persiq INFO     0.142s persiq::coordinator::broker] lease reaped job=7
//! ```

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info default
static DIRECTIVES: OnceLock<Vec<(String, u8)>> = OnceLock::new();
static START: OnceLock<Instant> = OnceLock::new();
static INIT: std::sync::Once = std::sync::Once::new();

fn init_from_env() {
    INIT.call_once(|| {
        START.get_or_init(Instant::now);
        let mut dirs: Vec<(String, u8)> = Vec::new();
        if let Ok(v) = std::env::var("PERSIQ_LOG") {
            for part in v.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                if let Some((target, lvl)) = part.split_once('=') {
                    if let Some(l) = parse_level(lvl.trim()) {
                        dirs.push((target.trim().to_string(), l as u8));
                    }
                } else if let Some(l) = parse_level(part) {
                    LEVEL.store(l as u8, Ordering::Relaxed);
                }
            }
        }
        let _ = DIRECTIVES.set(dirs);
    });
}

/// Does `dir` name `target`'s module or an ancestor of it? Accepts
/// directives with or without the `persiq::` crate prefix.
fn dir_matches(dir: &str, target: &str) -> bool {
    let stripped = target.strip_prefix("persiq::").unwrap_or(target);
    for cand in [target, stripped] {
        if cand == dir || (cand.starts_with(dir) && cand[dir.len()..].starts_with("::")) {
            return true;
        }
    }
    false
}

/// The most specific (longest-prefix) directive for `target`, falling
/// back to the global level.
fn effective_level(dirs: &[(String, u8)], target: &str) -> u8 {
    let mut best = LEVEL.load(Ordering::Relaxed);
    let mut best_len = 0usize;
    for (dir, lvl) in dirs {
        if dir.len() >= best_len && dir_matches(dir, target) {
            best = *lvl;
            best_len = dir.len();
        }
    }
    best
}

/// Set the global log level (module directives from `PERSIQ_LOG` still
/// take precedence for their subtrees).
pub fn set_level(lvl: Level) {
    init_from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Is `lvl` enabled at the global threshold?
pub fn enabled(lvl: Level) -> bool {
    init_from_env();
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Is `lvl` enabled for module `target` (honoring `PERSIQ_LOG`
/// per-module directives)?
pub fn enabled_for(lvl: Level, target: &str) -> bool {
    init_from_env();
    let dirs = DIRECTIVES.get().map(|v| v.as_slice()).unwrap_or(&[]);
    (lvl as u8) <= effective_level(dirs, target)
}

/// Emit a log record (used by the macros, which pass `module_path!()`).
pub fn log(lvl: Level, target: &str, args: std::fmt::Arguments) {
    if enabled_for(lvl, target) {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[persiq {tag} {t:>9.3}s {target}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn directive_matching() {
        assert!(dir_matches("persiq::coordinator", "persiq::coordinator::broker"));
        assert!(dir_matches("coordinator", "persiq::coordinator::broker"));
        assert!(dir_matches("persiq::coordinator::broker", "persiq::coordinator::broker"));
        assert!(!dir_matches("persiq::coord", "persiq::coordinator::broker"));
        assert!(!dir_matches("persiq::queues", "persiq::coordinator::broker"));
    }

    #[test]
    fn most_specific_directive_wins() {
        set_level(Level::Info);
        let dirs = vec![
            ("persiq::queues".to_string(), Level::Error as u8),
            ("persiq::queues::sharded".to_string(), Level::Trace as u8),
        ];
        assert_eq!(
            effective_level(&dirs, "persiq::queues::sharded::plan"),
            Level::Trace as u8
        );
        assert_eq!(effective_level(&dirs, "persiq::queues::lcrq"), Level::Error as u8);
        assert_eq!(effective_level(&dirs, "persiq::pmem::pool"), Level::Info as u8);
        set_level(Level::Info);
    }
}
