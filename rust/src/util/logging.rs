//! Tiny leveled logger writing to stderr. Controlled by `PERSIQ_LOG`
//! (error|warn|info|debug|trace) or programmatically via [`set_level`].

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info default
static INIT: std::sync::Once = std::sync::Once::new();

fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("PERSIQ_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

/// Set the global log level.
pub fn set_level(lvl: Level) {
    init_from_env();
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// Is `lvl` currently enabled?
pub fn enabled(lvl: Level) -> bool {
    init_from_env();
    (lvl as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a log record (used by the macros).
pub fn log(lvl: Level, args: std::fmt::Arguments) {
    if enabled(lvl) {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[persiq {tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
