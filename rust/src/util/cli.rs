//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands. Produces `--help` text from registered option metadata.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option (for help text + validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed argument bag for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Option value as string (explicit or `None`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    /// Was the bare flag present?
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.values.contains_key(key)
    }

    /// Positional arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Typed getter with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|_| anyhow::anyhow!("invalid value for --{key}: {s:?}")),
        }
    }

    /// Comma-separated list getter, e.g. `--threads 1,2,4,8`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> anyhow::Result<Vec<T>>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .filter(|p| !p.is_empty())
                .map(|p| {
                    p.trim()
                        .parse::<T>()
                        .map_err(|_| anyhow::anyhow!("invalid element in --{key}: {p:?}"))
                })
                .collect(),
        }
    }

    /// Insert (used by the parser and by tests).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }
}

/// Command definition: name, about text, options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Render `--help` text.
    pub fn help_text(&self, prog: &str) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{} {}\n  {}\n\nOPTIONS:", prog, self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let _ = writeln!(out, "  --{}{}\n        {}{}", o.name, val, o.help, def);
        }
        out
    }

    /// Parse `argv` (after the subcommand name). Unknown `--opts` error out.
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.set(o.name, d);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                if key == "help" {
                    anyhow::bail!("{}", self.help_text("persiq"));
                }
                let spec = self
                    .spec(key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.help_text("persiq")))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?
                        }
                    };
                    args.set(key, &v);
                } else {
                    if inline_val.is_some() {
                        anyhow::bail!("--{key} does not take a value");
                    }
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("bench", "run a benchmark")
            .opt_default("ops", "total operations", "1000")
            .opt("threads", "thread list")
            .flag("verbose", "chatty output")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_parse::<u64>("ops", 0).unwrap(), 1000);
        let a = cmd().parse(&sv(&["--ops", "5"])).unwrap();
        assert_eq!(a.get_parse::<u64>("ops", 0).unwrap(), 5);
        let a = cmd().parse(&sv(&["--ops=7"])).unwrap();
        assert_eq!(a.get_parse::<u64>("ops", 0).unwrap(), 7);
    }

    #[test]
    fn flags_and_positional() {
        let a = cmd().parse(&sv(&["--verbose", "pos1", "pos2"])).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn list_parsing() {
        let a = cmd().parse(&sv(&["--threads", "1,2, 4,8"])).unwrap();
        assert_eq!(a.get_list::<usize>("threads", &[]).unwrap(), vec![1, 2, 4, 8]);
        let a = cmd().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_list::<usize>("threads", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&sv(&["--threads"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&sv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn help_renders() {
        let h = cmd().help_text("persiq");
        assert!(h.contains("--ops"));
        assert!(h.contains("default: 1000"));
    }
}
