//! Result reporters: CSV writer, tiny JSON writer, and aligned ASCII tables
//! (the bench harness prints the same rows the paper's figures plot, and
//! persists them as CSV under `results/`).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-ordered CSV writer.
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn escape(cell: &str) -> String {
        if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
            format!("\"{}\"", cell.replace('"', "\"\""))
        } else {
            cell.to_string()
        }
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|c| Self::escape(c)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                r.iter().map(|c| Self::escape(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    /// Write to `path`, creating parent dirs.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())?;
        Ok(())
    }

    /// Render as an aligned ASCII table for terminal output.
    pub fn to_table(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len().saturating_sub(1));
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(r, &widths));
        }
        out
    }
}

/// Format a float with engineering-style precision for table cells.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1e6 {
        format!("{:.3}M", x / 1e6)
    } else if x.abs() >= 1e3 {
        format!("{:.1}k", x / 1e3)
    } else if x.abs() >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

/// Minimal JSON value builder (objects/arrays/scalars) for machine-readable
/// result dumps. We only ever *write* JSON, never parse it.
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn push(self, key: &str, val: Json) -> Self {
        match self {
            Json::Obj(mut kv) => {
                kv.push((key.to_string(), val));
                Json::Obj(kv)
            }
            other => other,
        }
    }

    fn escape(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => {
                    let _ = write!(out, "\\u{:04x}", c as u32);
                }
                c => out.push(c),
            }
        }
        out
    }

    pub fn render(&self) -> String {
        match self {
            Json::Null => "null".to_string(),
            Json::Bool(b) => b.to_string(),
            Json::Num(x) => {
                if x.is_finite() {
                    // Integral values print without the trailing ".0".
                    if x.fract() == 0.0 && x.abs() < 9e15 {
                        format!("{}", *x as i64)
                    } else {
                        format!("{x}")
                    }
                } else {
                    "null".to_string()
                }
            }
            Json::Str(s) => format!("\"{}\"", Self::escape(s)),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(kv) => {
                let inner: Vec<String> = kv
                    .iter()
                    .map(|(k, v)| format!("\"{}\":{}", Self::escape(k), v.render()))
                    .collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut c = Csv::new(vec!["threads", "algo", "mops"]);
        c.row(vec!["1", "perlcrq", "5.2"]);
        c.row(vec!["2", "pb,queue", "3.1"]);
        let s = c.to_string();
        assert!(s.starts_with("threads,algo,mops\n"));
        assert!(s.contains("\"pb,queue\""), "comma cells must be quoted");
        assert_eq!(s.lines().count(), 3);
    }

    #[test]
    #[should_panic]
    fn csv_arity_mismatch_panics() {
        let mut c = Csv::new(vec!["a", "b"]);
        c.row(vec!["only-one"]);
    }

    #[test]
    fn table_alignment() {
        let mut c = Csv::new(vec!["x", "longer"]);
        c.row(vec!["1234", "y"]);
        let t = c.to_table();
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("longer"));
    }

    #[test]
    fn json_rendering() {
        let j = Json::obj()
            .push("name", Json::Str("per\"lcrq".into()))
            .push("ops", Json::Num(1000.0))
            .push("ratio", Json::Num(2.5))
            .push("ok", Json::Bool(true))
            .push("xs", Json::Arr(vec![Json::Num(1.0), Json::Null]));
        let s = j.render();
        assert_eq!(
            s,
            "{\"name\":\"per\\\"lcrq\",\"ops\":1000,\"ratio\":2.5,\"ok\":true,\"xs\":[1,null]}"
        );
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(5_200_000.0), "5.200M");
        assert_eq!(fnum(1500.0), "1.5k");
        assert_eq!(fnum(2.5), "2.50");
        assert_eq!(fnum(0.1234), "0.1234");
    }

    #[test]
    fn csv_save_and_read_back() {
        let dir = std::env::temp_dir().join("persiq_test_report");
        let path = dir.join("t.csv");
        let mut c = Csv::new(vec!["a"]);
        c.row(vec!["1"]);
        c.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
