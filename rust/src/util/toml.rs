//! TOML-subset parser for config files (serde/toml unavailable offline).
//!
//! Supported: `[section]` headers, `key = value` with values of types
//! integer, float, bool, string (`"..."`), and flat arrays (`[1, 2, 3]`).
//! Comments (`# ...`) and blank lines are ignored. This covers everything
//! `persiq.toml` needs; anything fancier errors out loudly.

use std::collections::BTreeMap;

/// A parsed scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: `section.key -> Value`; keys before any `[section]` live
/// under the empty section `""`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

impl Doc {
    /// Look up `section.key` (use `""` for the root section).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        self.entries.get(&full)
    }

    pub fn get_u64(&self, section: &str, key: &str, default: u64) -> u64 {
        self.get(section, key).and_then(Value::as_u64).unwrap_or(default)
    }
    pub fn get_f64(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }
    pub fn get_bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
    pub fn get_str<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    /// All `(key, value)` pairs in a section.
    pub fn section(&self, section: &str) -> Vec<(&str, &Value)> {
        let prefix = format!("{section}.");
        self.entries
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(&prefix).map(|rest| (rest, v)))
            .collect()
    }
}

fn parse_scalar(tok: &str, line_no: usize) -> anyhow::Result<Value> {
    let t = tok.trim();
    if t.starts_with('"') {
        let inner = t
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .ok_or_else(|| anyhow::anyhow!("line {line_no}: unterminated string {t:?}"))?;
        // Minimal escapes.
        let un = inner.replace("\\\"", "\"").replace("\\\\", "\\").replace("\\n", "\n");
        return Ok(Value::Str(un));
    }
    match t {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    let clean = t.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("line {line_no}: cannot parse value {t:?}")
}

/// Parse a TOML-subset document from text.
pub fn parse(text: &str) -> anyhow::Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip comments outside strings (simple heuristic: split at '#' not
        // inside quotes).
        let mut in_str = false;
        let mut cut = raw.len();
        for (i, c) in raw.char_indices() {
            match c {
                '"' => in_str = !in_str,
                '#' if !in_str => {
                    cut = i;
                    break;
                }
                _ => {}
            }
        }
        let line = raw[..cut].trim();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix('[') {
            let name = h
                .strip_suffix(']')
                .ok_or_else(|| anyhow::anyhow!("line {line_no}: malformed section header"))?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("line {line_no}: expected key = value"))?;
        let key = k.trim();
        if key.is_empty() {
            anyhow::bail!("line {line_no}: empty key");
        }
        let vt = v.trim();
        let value = if vt.starts_with('[') {
            let inner = vt
                .strip_prefix('[')
                .and_then(|s| s.strip_suffix(']'))
                .ok_or_else(|| anyhow::anyhow!("line {line_no}: unterminated array"))?;
            let items: anyhow::Result<Vec<Value>> = inner
                .split(',')
                .map(|p| p.trim())
                .filter(|p| !p.is_empty())
                .map(|p| parse_scalar(p, line_no))
                .collect();
            Value::Array(items?)
        } else {
            parse_scalar(vt, line_no)?
        };
        let full = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(full, value);
    }
    Ok(doc)
}

/// Parse a config file from disk.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Doc> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# root settings
seed = 42
name = "perlcrq"   # trailing comment

[pmem]
pwb_ns = 60.5
evict_prob = 0.25
enabled = true
threads = [1, 2, 4, 8]
big = 1_000_000
"#;

    #[test]
    fn parses_all_types() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.get_u64("", "seed", 0), 42);
        assert_eq!(d.get_str("", "name", ""), "perlcrq");
        assert_eq!(d.get_f64("pmem", "pwb_ns", 0.0), 60.5);
        assert_eq!(d.get_f64("pmem", "evict_prob", 0.0), 0.25);
        assert!(d.get_bool("pmem", "enabled", false));
        assert_eq!(d.get_u64("pmem", "big", 0), 1_000_000);
        let arr = d.get("pmem", "threads").unwrap().as_array().unwrap();
        let v: Vec<i64> = arr.iter().map(|x| x.as_i64().unwrap()).collect();
        assert_eq!(v, vec![1, 2, 4, 8]);
    }

    #[test]
    fn defaults_on_missing() {
        let d = parse(SAMPLE).unwrap();
        assert_eq!(d.get_u64("pmem", "missing", 7), 7);
        assert_eq!(d.get_str("nope", "x", "dflt"), "dflt");
    }

    #[test]
    fn section_listing() {
        let d = parse(SAMPLE).unwrap();
        let keys: Vec<&str> = d.section("pmem").into_iter().map(|(k, _)| k).collect();
        assert!(keys.contains(&"pwb_ns"));
        assert!(keys.contains(&"threads"));
    }

    #[test]
    fn string_with_hash_inside() {
        let d = parse("s = \"a#b\"").unwrap();
        assert_eq!(d.get_str("", "s", ""), "a#b");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("key").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("k = @nope").is_err());
        assert!(parse("k = [1, 2").is_err());
    }

    #[test]
    fn negative_and_float_ints() {
        let d = parse("a = -5\nb = -2.5").unwrap();
        assert_eq!(d.get("", "a").unwrap().as_i64(), Some(-5));
        assert_eq!(d.get_f64("", "b", 0.0), -2.5);
    }
}
