//! Thread placement. The paper pins threads round-robin across NUMA nodes
//! (§5). On this single-core testbed pinning is a no-op, but the API and the
//! NUMA-style round-robin *placement order* are kept so thread ids map to
//! simulated sockets deterministically (the virtual-time model can charge
//! cross-socket penalties based on it).
//!
//! This build is offline and dependency-minimal (no `libc`), so
//! [`pin_to_cpu`] is a best-effort stub: callers must treat pinning as
//! advisory, which they already do — placement determinism comes from
//! [`place`], not from OS affinity.

/// Logical placement of a worker thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Simulated socket (NUMA node) index.
    pub socket: usize,
    /// Simulated core within the socket.
    pub core: usize,
}

/// Compute the paper's round-robin-across-sockets placement for `tid` out of
/// `sockets` simulated sockets with `cores_per_socket` cores each
/// (hyperthreads fold onto the same core once all cores are used).
pub fn place(tid: usize, sockets: usize, cores_per_socket: usize) -> Placement {
    let sockets = sockets.max(1);
    let cps = cores_per_socket.max(1);
    let socket = tid % sockets;
    let round = tid / sockets;
    Placement { socket, core: round % cps }
}

/// Best-effort thread pinning. Real affinity syscalls need `libc`, which
/// this offline build deliberately does not depend on; returns `false`
/// ("not pinned") so callers fall through to unpinned execution.
pub fn pin_to_cpu(cpu: usize) -> bool {
    let _ = cpu;
    false
}

/// Number of online CPUs (via the standard library; 1 when unknown).
pub fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_across_sockets() {
        // 2 sockets, 24 cores each — the paper's topology.
        let p: Vec<Placement> = (0..6).map(|t| place(t, 2, 24)).collect();
        assert_eq!(p[0], Placement { socket: 0, core: 0 });
        assert_eq!(p[1], Placement { socket: 1, core: 0 });
        assert_eq!(p[2], Placement { socket: 0, core: 1 });
        assert_eq!(p[3], Placement { socket: 1, core: 1 });
        assert_eq!(p[4], Placement { socket: 0, core: 2 });
        assert_eq!(p[5], Placement { socket: 1, core: 2 });
    }

    #[test]
    fn hyperthread_folding() {
        // 1 socket, 2 cores: tids 0,1 on cores 0,1; tids 2,3 fold back.
        assert_eq!(place(2, 1, 2).core, 0);
        assert_eq!(place(3, 1, 2).core, 1);
    }

    #[test]
    fn degenerate_topology() {
        assert_eq!(place(5, 0, 0), Placement { socket: 0, core: 0 });
    }

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }

    #[test]
    fn pin_is_advisory() {
        assert!(!pin_to_cpu(0), "stub must report not-pinned");
    }
}
