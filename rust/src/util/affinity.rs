//! Thread placement. The paper pins threads round-robin across NUMA nodes
//! (§5). On this single-core testbed pinning is a no-op, but the API and the
//! NUMA-style round-robin *placement order* are kept so thread ids map to
//! simulated sockets deterministically (the virtual-time model can charge
//! cross-socket penalties based on it).

/// Logical placement of a worker thread.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Simulated socket (NUMA node) index.
    pub socket: usize,
    /// Simulated core within the socket.
    pub core: usize,
}

/// Compute the paper's round-robin-across-sockets placement for `tid` out of
/// `sockets` simulated sockets with `cores_per_socket` cores each
/// (hyperthreads fold onto the same core once all cores are used).
pub fn place(tid: usize, sockets: usize, cores_per_socket: usize) -> Placement {
    let sockets = sockets.max(1);
    let cps = cores_per_socket.max(1);
    let socket = tid % sockets;
    let round = tid / sockets;
    Placement { socket, core: round % cps }
}

/// Try to pin the calling thread to `cpu` (Linux). Returns false if the
/// syscall fails or there is only one CPU — callers treat pinning as
/// best-effort.
pub fn pin_to_cpu(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    unsafe {
        let ncpu = libc::sysconf(libc::_SC_NPROCESSORS_ONLN);
        if ncpu <= 1 {
            return false;
        }
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_SET(cpu % ncpu as usize, &mut set);
        libc::pthread_setaffinity_np(
            libc::pthread_self(),
            std::mem::size_of::<libc::cpu_set_t>(),
            &set,
        ) == 0
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// Number of online CPUs.
pub fn num_cpus() -> usize {
    #[cfg(target_os = "linux")]
    unsafe {
        let n = libc::sysconf(libc::_SC_NPROCESSORS_ONLN);
        if n < 1 {
            1
        } else {
            n as usize
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_across_sockets() {
        // 2 sockets, 24 cores each — the paper's topology.
        let p: Vec<Placement> = (0..6).map(|t| place(t, 2, 24)).collect();
        assert_eq!(p[0], Placement { socket: 0, core: 0 });
        assert_eq!(p[1], Placement { socket: 1, core: 0 });
        assert_eq!(p[2], Placement { socket: 0, core: 1 });
        assert_eq!(p[3], Placement { socket: 1, core: 1 });
        assert_eq!(p[4], Placement { socket: 0, core: 2 });
        assert_eq!(p[5], Placement { socket: 1, core: 2 });
    }

    #[test]
    fn hyperthread_folding() {
        // 1 socket, 2 cores: tids 0,1 on cores 0,1; tids 2,3 fold back.
        assert_eq!(place(2, 1, 2).core, 0);
        assert_eq!(place(3, 1, 2).core, 1);
    }

    #[test]
    fn degenerate_topology() {
        assert_eq!(place(5, 0, 0), Placement { socket: 0, core: 0 });
    }

    #[test]
    fn num_cpus_positive() {
        assert!(num_cpus() >= 1);
    }
}
