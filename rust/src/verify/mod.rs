//! Durable-linearizability verification (paper §2 definitions).
//!
//! The harness records per-operation invoke/response events with a global
//! sequence counter and the crash epoch; [`checker`] then validates the
//! queue axioms across crash boundaries:
//!
//! * **V1 — no duplication / at-most-once**: every dequeued value was
//!   enqueued, and no value is dequeued twice (even across epochs).
//! * **V2 — no loss (durability)**: every *completed* enqueue's value is
//!   eventually dequeued or still present at the final drain.
//! * **V3 — FIFO real-time order**: if `enq(a)` completed strictly before
//!   `enq(b)` was invoked and both values are dequeued, then `deq(b)` must
//!   not complete strictly before `deq(a)` is invoked.
//! * **V4 — EMPTY soundness**: a dequeue returning EMPTY is invalid if some
//!   value was enqueued-completed before it started and remained undequeued
//!   until after it returned.
//! * **V5 — no invention**: every observed value traces to an *invoked*
//!   enqueue (uncompleted enqueues may legitimately linearize — §4.1).
//!
//! V1–V3, V5 are exact; V4 is a sound interval check (no false positives).
//!
//! ## Relaxed mode (sharded + blockfifo queues)
//!
//! [`check_relaxed`]`(h, k)` replaces V3's strict real-time FIFO with a
//! k-relaxed variant: a dequeue may overtake up to `k` strictly-older
//! values (the bounded skew a `queues::sharded::ShardedQueue` or
//! `queues::blockfifo::BlockFifo` introduces) before it counts as an
//! inversion. All other axioms stay exact.
//! [`options_for`] bundles the per-algorithm policy — relaxation bound,
//! crash-gated trailing windows, EMPTY-check applicability — into one
//! [`checker::CheckOptions`] shared by the CLI and registry-driven tests.
//! [`check_with`] additionally exposes the batched-durability knobs, all
//! gated on epochs that actually crashed: the trailing-loss allowance
//! (V2, unflushed enqueue batches), the trailing-redelivery allowance
//! (V1, unflushed dequeue batches — returned-but-unpersisted consumption
//! may come back after a crash), and EMPTY-check gating — see
//! [`checker::CheckOptions`].
//!
//! [`proptest`] is a minimal property-testing harness (the `proptest`
//! crate is unavailable offline) used to drive randomized crash workloads
//! through every persistent queue.

pub mod checker;
pub mod history;
pub mod proptest;

pub use checker::{
    block_relaxation, calibrate_relaxation, check, check_relaxed, check_with, options_for,
    overtake_stats, relaxation_for, resharding_relaxation, shard_relaxation, CheckOptions,
    CheckReport, OvertakeStats, Violation,
};
pub use history::{Event, EventKind, History, Recorder};
