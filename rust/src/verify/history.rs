//! Operation history recording.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What happened.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    EnqInvoke { value: u64 },
    EnqOk { value: u64 },
    DeqInvoke,
    /// Async-boundary marker: the oldest open dequeue of this thread has
    /// EXECUTED against the queue (it may have consumed an item) but has
    /// not yet reached its durability point. Histories carrying these
    /// markers let the checker's V2 loss budget count only
    /// executed-but-unresponded dequeues instead of every open invoke —
    /// on async histories the latter scales with the future window while
    /// the former is exactly the combiner's crash-in-flight count.
    DeqExecuted,
    DeqOk { value: u64 },
    DeqEmpty,
}

/// One timestamped event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Global total-order timestamp (monotone across all threads).
    pub seq: u64,
    /// Recording thread.
    pub tid: usize,
    /// Crash epoch the event belongs to.
    pub epoch: u64,
    pub kind: EventKind,
}

/// Process-wide sequence source (a single static counter: histories
/// assembled from multiple runs/cycles stay totally ordered).
static GLOBAL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Shared sequence source handed to per-thread recorders.
pub struct Recorder {}

impl Recorder {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {})
    }

    /// Next global timestamp (unique + monotone across all recorders).
    #[inline]
    pub fn stamp(&self) -> u64 {
        GLOBAL_SEQ.fetch_add(1, Ordering::SeqCst)
    }

    /// Record an event into a thread-local log.
    #[inline]
    pub fn record(&self, log: &mut Vec<Event>, tid: usize, epoch: u64, kind: EventKind) {
        log.push(Event { seq: self.stamp(), tid, epoch, kind });
    }
}

impl Default for Recorder {
    fn default() -> Self {
        Self {}
    }
}

/// A merged history plus the values recovered by the final post-crash
/// drain (used by the no-loss check).
#[derive(Clone, Debug, Default)]
pub struct History {
    pub events: Vec<Event>,
    /// Values returned by the final exhaustive drain (after the last
    /// recovery), in drain order.
    pub final_drain: Vec<u64>,
}

impl History {
    /// Merge per-thread logs (events keep their global seq; we sort).
    pub fn from_logs(logs: Vec<Vec<Event>>, final_drain: Vec<u64>) -> Self {
        let mut events: Vec<Event> = logs.into_iter().flatten().collect();
        events.sort_by_key(|e| e.seq);
        Self { events, final_drain }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stamps_are_unique_and_monotone() {
        let r = Recorder::new();
        let mut log = Vec::new();
        for i in 0..10u64 {
            r.record(&mut log, 0, 0, EventKind::EnqInvoke { value: i });
        }
        for w in log.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
    }

    #[test]
    fn merge_sorts_by_seq() {
        let r = Recorder::new();
        let mut a = Vec::new();
        let mut b = Vec::new();
        r.record(&mut a, 0, 0, EventKind::DeqInvoke);
        r.record(&mut b, 1, 0, EventKind::DeqEmpty);
        r.record(&mut a, 0, 0, EventKind::DeqInvoke);
        let h = History::from_logs(vec![b, a], vec![]);
        assert_eq!(h.len(), 3);
        for w in h.events.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
    }

    #[test]
    fn concurrent_stamps_unique() {
        let r = Recorder::new();
        let mut hs = Vec::new();
        for _ in 0..4 {
            let r = Arc::clone(&r);
            hs.push(std::thread::spawn(move || {
                (0..1000).map(|_| r.stamp()).collect::<Vec<u64>>()
            }));
        }
        let mut all: Vec<u64> = hs.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n);
    }
}
