//! The durable-linearizability checker (see [`super`] for the axioms),
//! including the **relaxed-FIFO** mode used by `queues::sharded`.
//!
//! ## Relaxation semantics
//!
//! A sharded queue distributes items over K inner FIFOs, so a dequeue may
//! legitimately *overtake* items sitting in sibling shards. We follow the
//! k-relaxed out-of-order definition from the relaxed-queue literature: a
//! dequeued value `b` violates k-relaxed FIFO iff **more than k** values
//! `a` exist with `enq(a)` completed strictly before `enq(b)` was invoked
//! and `deq(b)` completed strictly before `deq(a)` was invoked (i.e. `b`
//! jumped over more than `k` strictly-older items). `k = 0` is exactly the
//! strict real-time FIFO check (V3). The count is computed exactly in
//! `O(n log n)` with a Fenwick tree over dequeue-invocation ranks.
//!
//! ## Trailing-loss allowance (batched enqueue durability)
//!
//! Under the sharded queue's group-commit batching, an enqueue is durably
//! linearized at its batch *flush*, not at its return; a crash may lose up
//! to `B − 1` unflushed trailing enqueues per thread. With
//! [`CheckOptions::trailing_loss_per_thread`] `= B − 1`, a completed
//! enqueue's value may vanish without violation **only** if it is among
//! the last `B − 1` completed enqueues of its `(thread, epoch)` group —
//! exactly the window a crash can erase. Everything else still counts as
//! a loss.
//!
//! ## Trailing-redelivery allowance (batched dequeue durability)
//!
//! The symmetric consumer-side window: with `batch_deq = K`, a dequeue's
//! *consumption* is durable at its batch flush, so a crash may roll the
//! durable `Head` back over up to `K − 1` returned-but-unflushed items per
//! thread — those items are **redelivered** after recovery. With
//! [`CheckOptions::trailing_redelivery_per_thread`] `= K − 1`, a value
//! dequeued twice (or dequeued then found in the final drain) is excused
//! **only** if its first dequeue (a) happened in an epoch that ended in a
//! crash, (b) was among the last `K − 1` completed dequeues of its
//! `(thread, epoch)` group, and (c) the second delivery happened in a
//! strictly later epoch. Everything else is still a duplication
//! violation.

use std::collections::{HashMap, VecDeque};

use super::history::{EventKind, History};

/// A detected violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Value dequeued more than once (or drained after being dequeued).
    Duplicate { value: u64 },
    /// Value dequeued/drained without any invoked enqueue.
    Invented { value: u64 },
    /// Completed enqueue's value neither dequeued nor drained, beyond the
    /// budget of in-flight dequeues that may have legitimately consumed it
    /// (an uncompleted dequeue linearized at a crash — paper §4, Scenario
    /// 2 — absorbs at most one value) and beyond the batched trailing-loss
    /// allowance.
    Lost { value: u64 },
    /// Real-time FIFO inversion between two dequeued values (`second`
    /// overtook more than the allowed number of strictly-older values;
    /// `first` is the strongest witness).
    FifoInversion { first: u64, second: u64 },
    /// EMPTY returned while some value was provably present throughout.
    BogusEmpty { witness: u64, empty_seq: u64 },
    /// The same value was enqueued twice (workload bug, not queue bug).
    ValueReused { value: u64 },
}

/// Checker knobs. [`check`] and [`check_relaxed`] are thin wrappers over
/// [`check_with`].
#[derive(Clone, Copy, Debug)]
pub struct CheckOptions {
    /// Cap on reported violations.
    pub max_report: usize,
    /// Allowed out-of-order overtakes per dequeue (`0` = strict FIFO).
    pub relaxation: usize,
    /// Completed enqueues per `(thread, epoch)` that may vanish at a crash
    /// (batched durability window; `B − 1` for batch size `B`).
    pub trailing_loss_per_thread: usize,
    /// Completed dequeues per `(thread, epoch)` whose value may be
    /// *redelivered* after that epoch's crash (consumer-side batching
    /// window; `K − 1` for dequeue batch size `K`). `0` = any duplicate
    /// delivery is a violation.
    pub trailing_redelivery_per_thread: usize,
    /// How many leading epochs ended in a crash: the trailing-loss and
    /// trailing-redelivery allowances only excuse anomalies in epochs
    /// `< crashed_epochs` — an epoch that ended cleanly (flushed/quiesced)
    /// has no crash to lose its tail to, and a vanished or redelivered
    /// value there is a real violation. Harnesses that crash every cycle
    /// pass their cycle count.
    pub crashed_epochs: u64,
    /// Run the EMPTY-soundness check (V4). Disable for batched histories:
    /// with buffered durability an EMPTY may legitimately overlap another
    /// thread's not-yet-flushed enqueues.
    pub check_empty: bool,
    /// Record every dequeue's overtake count into
    /// [`CheckReport::overtake_counts`] (one entry per checked dequeue) —
    /// the input to [`calibrate_relaxation`]. Off by default: the
    /// distribution costs memory proportional to the history.
    pub collect_overtakes: bool,
}

impl Default for CheckOptions {
    fn default() -> Self {
        Self {
            max_report: 10,
            relaxation: 0,
            trailing_loss_per_thread: 0,
            trailing_redelivery_per_thread: 0,
            crashed_epochs: 0,
            check_empty: true,
            collect_overtakes: false,
        }
    }
}

/// Conservative overtake bound for a sharded queue's histories: covers
/// steady-state shard skew plus crash-reconciliation displacement. One
/// definition shared by the CLI, tests and examples so it cannot drift.
pub fn shard_relaxation(nthreads: usize, shards: usize, batch: usize) -> usize {
    nthreads * shards.max(1) * batch.max(1) * 4 + 64
}

/// Overtake bound for a history that crossed one or more **re-sharding
/// boundaries** (`ShardedQueue::resize`): the steady-state bound at the
/// largest shard count any live plan had, plus a cross-plan allowance of
/// the frozen-shard residue. During a transition the old plan's residue
/// is strictly older than every new-plan item, and although drain
/// priority delivers it first, batching windows and crash reconciliation
/// (which re-inserts frozen-epoch positions at active-plan tails) can
/// displace a dequeue past at most `residue` such items per flip —
/// `residue` summed over flips (`ResizeStats::residue_total`) bounds the
/// whole run.
pub fn resharding_relaxation(
    nthreads: usize,
    max_shards: usize,
    batch: usize,
    residue_total: u64,
) -> usize {
    shard_relaxation(nthreads, max_shards, batch) + residue_total as usize
}

/// Conservative overtake bound for a blockfifo history: consumers skip
/// blocks still being filled, so an item can be overtaken by everything
/// committed in younger blocks across the lanes while its own block was
/// open — the same shape as shard skew with the block size in the batch
/// slot (plus the same 4× + 64 reconciliation headroom).
pub fn block_relaxation(nthreads: usize, lanes: usize, block: usize) -> usize {
    shard_relaxation(nthreads, lanes, block)
}

/// The relaxation policy for a registry algorithm: sharded algorithms are
/// k-relaxed FIFO (bounded shard skew), blockfifo is k-relaxed with the
/// block size as the skew unit, everything else is checked strictly
/// (`k = 0` is the exact check). The single definition the CLI, tests and
/// examples all share.
pub fn relaxation_for(
    algo_name: &str,
    nthreads: usize,
    cfg: &crate::queues::QueueConfig,
) -> usize {
    if algo_name.starts_with("sharded") {
        shard_relaxation(nthreads, cfg.shards, cfg.batch.max(cfg.batch_deq))
    } else if algo_name.starts_with("blockfifo") {
        block_relaxation(nthreads, cfg.shards, cfg.block)
    } else {
        0
    }
}

/// The full checker configuration for a registry algorithm's history:
/// relaxation bound (via [`relaxation_for`]) plus the crash-gated
/// trailing-loss/redelivery windows and EMPTY-soundness applicability its
/// durability mode implies. The single definition registry-driven tests
/// and the CLI share, so adding an algorithm cannot silently get the
/// wrong allowances:
///
/// * `sharded-*` with batching: producers may lose `batch − 1` returned
///   enqueues and consumers redeliver `batch_deq − 1` returned dequeues
///   per crash; EMPTY soundness only holds unbatched.
/// * `blockfifo*`: an open (unsealed) block may lose `block − 1` returned
///   enqueues (the `block`-th seals synchronously); a DRAINING block
///   rolls back to its durable start and redelivers up to `block`
///   returned dequeues. Open blocks are invisible to other consumers, so
///   EMPTY soundness never applies.
/// * everything else: per-operation durability — zero windows, strict
///   EMPTY check.
pub fn options_for(
    algo_name: &str,
    nthreads: usize,
    cfg: &crate::queues::QueueConfig,
    crashed_epochs: u64,
) -> CheckOptions {
    let relaxation = relaxation_for(algo_name, nthreads, cfg);
    let (loss, redelivery, check_empty) = if algo_name.starts_with("sharded") {
        (
            cfg.batch.saturating_sub(1),
            cfg.batch_deq.saturating_sub(1),
            cfg.batch <= 1,
        )
    } else if algo_name.starts_with("blockfifo") {
        (cfg.block.saturating_sub(1), cfg.block, false)
    } else {
        (0, 0, true)
    };
    CheckOptions {
        relaxation,
        trailing_loss_per_thread: loss,
        trailing_redelivery_per_thread: redelivery,
        crashed_epochs,
        check_empty,
        ..Default::default()
    }
}

/// Summary of an observed overtake distribution (reported by
/// `persiq verify --relax auto`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OvertakeStats {
    pub checked: usize,
    pub p50: usize,
    pub p99: usize,
    pub max: usize,
}

/// Summarize a collected overtake distribution
/// ([`CheckReport::overtake_counts`]).
pub fn overtake_stats(counts: &[usize]) -> OvertakeStats {
    if counts.is_empty() {
        return OvertakeStats::default();
    }
    let mut sorted = counts.to_vec();
    sorted.sort_unstable();
    let q = |p: f64| -> usize {
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    };
    OvertakeStats {
        checked: sorted.len(),
        p50: q(0.50),
        p99: q(0.99),
        max: *sorted.last().unwrap(),
    }
}

/// Derive a relaxation bound `k` from an **observed** overtake
/// distribution, instead of the conservative static
/// [`relaxation_for`] formula: the bound is the observed maximum plus
/// headroom (25%, at least 8) for the tail the sample missed. A fully
/// ordered sample (max = 0) calibrates to `0` — the strict bound is the
/// honest reading, and padding it would *weaken* the check for
/// strict-FIFO algorithms. A history re-checked against its own
/// calibrated bound passes by construction — the value of `--relax auto`
/// is the *reported* bound (how relaxed the configuration actually runs,
/// typically orders of magnitude below the static formula) and the
/// regression signal when a future run exceeds a previously calibrated
/// bound. Only meaningful for relaxed (sharded) algorithms; `persiq
/// verify` keeps strict queues at `k = 0` regardless.
pub fn calibrate_relaxation(counts: &[usize]) -> usize {
    let max = counts.iter().copied().max().unwrap_or(0);
    if max == 0 {
        0
    } else {
        max + (max / 4).max(8)
    }
}

/// Check outcome.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub violations: Vec<Violation>,
    pub enq_invoked: usize,
    pub enq_completed: usize,
    pub deq_values: usize,
    pub deq_empties: usize,
    pub drained: usize,
    /// Dequeues invoked but never responded (crashed mid-operation); each
    /// may absorb one otherwise-"lost" value.
    pub pending_deqs: usize,
    /// Values that vanished within the pending-dequeue budget (not
    /// violations, but reported for transparency).
    pub absorbed_losses: usize,
    /// Values that vanished within the batched trailing-loss allowance.
    pub absorbed_trailing: usize,
    /// Duplicate deliveries excused by the consumer-side
    /// trailing-redelivery allowance (returned-but-unpersisted dequeues
    /// whose value came back after the crash).
    pub absorbed_redelivered: usize,
    /// Largest observed overtake count across dequeues (how relaxed the
    /// history actually was; useful for calibrating `relaxation`).
    pub max_overtakes: usize,
    /// Per-dequeue overtake counts (only when
    /// [`CheckOptions::collect_overtakes`]; one entry per dequeue the V3
    /// sweep checked). Feed to [`calibrate_relaxation`].
    pub overtake_counts: Vec<usize>,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

#[derive(Default, Clone, Copy)]
struct OpSpan {
    invoke: u64,
    response: Option<u64>,
}

/// Fenwick (binary indexed) tree for exact overtake counting.
struct Bit {
    t: Vec<usize>,
}

impl Bit {
    fn new(n: usize) -> Self {
        Self { t: vec![0; n + 1] }
    }

    /// Add 1 at 1-based position `i`.
    fn add(&mut self, mut i: usize) {
        while i < self.t.len() {
            self.t[i] += 1;
            i += i & i.wrapping_neg();
        }
    }

    /// Sum of positions `1..=i`.
    fn prefix(&self, mut i: usize) -> usize {
        let mut s = 0;
        while i > 0 {
            s += self.t[i];
            i -= i & i.wrapping_neg();
        }
        s
    }
}

/// Strict check over a history (`k = 0`, no trailing allowance).
/// `max_report` caps reported violations.
pub fn check(h: &History, max_report: usize) -> CheckReport {
    check_with(h, &CheckOptions { max_report, ..Default::default() })
}

/// Relaxed-FIFO check: accept up to `k` out-of-order overtakes per dequeue
/// (for sharded queues, `k` bounds the shard skew). All other axioms stay
/// exact.
pub fn check_relaxed(h: &History, k: usize) -> CheckReport {
    check_with(h, &CheckOptions { relaxation: k, ..Default::default() })
}

/// Run all checks over a history with explicit options.
pub fn check_with(h: &History, opts: &CheckOptions) -> CheckReport {
    let mut report = CheckReport::default();
    let max_report = opts.max_report;
    let push = |vs: &mut Vec<Violation>, v: Violation| {
        if vs.len() < max_report {
            vs.push(v);
        }
    };

    // --- Index the history ---
    let mut enq: HashMap<u64, OpSpan> = HashMap::new();
    // value -> (tid, epoch) of its completed enqueue (trailing-loss groups).
    let mut enq_meta: HashMap<u64, (usize, u64)> = HashMap::new();
    // tid -> FIFO of open dequeue invokes `(seq, epoch, executed)`. A
    // thread may hold SEVERAL open dequeues at once (the async API's
    // future window); responses on a thread arrive in submission order
    // (futures are awaited oldest-first), so pairing pops the front. Sync
    // histories (one open op per thread) behave exactly as before.
    //
    // When the history carries `DeqExecuted` markers (async harnesses
    // record one when the combiner actually runs a dequeue against the
    // queue), only EXECUTED open invokes can have consumed a value — the
    // V2 pending budget counts those alone, i.e. exactly the combiner's
    // crash-in-flight dequeues instead of the whole future window.
    // Marker-free histories keep the conservative every-open-invoke
    // budget.
    let exec_markers =
        h.events.iter().any(|e| matches!(e.kind, EventKind::DeqExecuted));
    let mut open_deq: HashMap<usize, VecDeque<(u64, u64, bool)>> = HashMap::new();
    // Pop the pairing invoke for a response on `tid` at `epoch`: invokes
    // left open by an earlier (crashed) epoch can never respond — count
    // them as pending (budget-eligible ones only) and skip past.
    fn pair_deq(
        open: &mut HashMap<usize, VecDeque<(u64, u64, bool)>>,
        pending: &mut usize,
        exec_markers: bool,
        tid: usize,
        epoch: u64,
        fallback: u64,
    ) -> u64 {
        let q = open.entry(tid).or_default();
        while q.front().is_some_and(|&(_, ep, _)| ep < epoch) {
            let (_, _, executed) = q.pop_front().expect("front checked");
            if executed || !exec_markers {
                *pending += 1;
            }
        }
        q.pop_front().map(|(s, _, _)| s).unwrap_or(fallback)
    }
    let mut deq: HashMap<u64, OpSpan> = HashMap::new(); // value -> span
    // value -> (tid, epoch, response seq) of its FIRST dequeue
    // (trailing-redelivery groups).
    let mut deq_meta: HashMap<u64, (usize, u64, u64)> = HashMap::new();
    // (tid, epoch) -> response seqs of all completed dequeues.
    let mut deq_groups: HashMap<(usize, u64), Vec<u64>> = HashMap::new();
    // Repeat deliveries: (value, tid, epoch, response seq), in history
    // order; judged after indexing against the redelivery allowance.
    let mut dup_candidates: Vec<(u64, usize, u64, u64)> = Vec::new();
    let mut empties: Vec<OpSpan> = Vec::new();

    for e in &h.events {
        match e.kind {
            EventKind::EnqInvoke { value } => {
                if enq.contains_key(&value) {
                    push(&mut report.violations, Violation::ValueReused { value });
                }
                enq.insert(value, OpSpan { invoke: e.seq, response: None });
                report.enq_invoked += 1;
            }
            EventKind::EnqOk { value } => {
                if let Some(span) = enq.get_mut(&value) {
                    span.response = Some(e.seq);
                }
                enq_meta.insert(value, (e.tid, e.epoch));
                report.enq_completed += 1;
            }
            EventKind::DeqInvoke => {
                // Dequeues left open at a crash (or forever) are counted
                // as pending when a later-epoch response skips past them
                // (`pair_deq`) or at end of history below.
                open_deq.entry(e.tid).or_default().push_back((e.seq, e.epoch, false));
            }
            EventKind::DeqExecuted => {
                // Mark the oldest unexecuted open invoke of this thread
                // IN THE MARKER'S EPOCH: it has touched the queue and may
                // have consumed a value. The epoch filter matters: a
                // crashed epoch can leave never-executed invokes open
                // (ring-drained, failed futures), and a later epoch's
                // marker must not land on one of those — that would both
                // inflate the pending budget with provably-never-executed
                // ops and starve the mark the actually-executing invoke
                // needs.
                if let Some(entry) = open_deq
                    .entry(e.tid)
                    .or_default()
                    .iter_mut()
                    .find(|en| !en.2 && en.1 == e.epoch)
                {
                    entry.2 = true;
                }
            }
            EventKind::DeqOk { value } => {
                let invoke = pair_deq(
                    &mut open_deq,
                    &mut report.pending_deqs,
                    exec_markers,
                    e.tid,
                    e.epoch,
                    e.seq,
                );
                if opts.trailing_redelivery_per_thread > 0 {
                    // Only the redelivery allowance reads these groups;
                    // strict checks skip the bookkeeping.
                    deq_groups.entry((e.tid, e.epoch)).or_default().push(e.seq);
                }
                if deq.contains_key(&value) {
                    // Judged after indexing: may fall inside the
                    // consumer-side trailing-redelivery window.
                    dup_candidates.push((value, e.tid, e.epoch, e.seq));
                } else {
                    deq.insert(value, OpSpan { invoke, response: Some(e.seq) });
                    deq_meta.insert(value, (e.tid, e.epoch, e.seq));
                }
                if !enq.contains_key(&value) {
                    push(&mut report.violations, Violation::Invented { value });
                }
                report.deq_values += 1;
            }
            EventKind::DeqEmpty => {
                let invoke = pair_deq(
                    &mut open_deq,
                    &mut report.pending_deqs,
                    exec_markers,
                    e.tid,
                    e.epoch,
                    e.seq,
                );
                empties.push(OpSpan { invoke, response: Some(e.seq) });
                report.deq_empties += 1;
            }
        }
    }
    report.drained = h.final_drain.len();
    // Dequeues still open at the end of the history also count as pending
    // (with markers present: only the executed ones — the rest provably
    // never touched the queue).
    report.pending_deqs += open_deq
        .values()
        .flatten()
        .filter(|&&(_, _, executed)| executed || !exec_markers)
        .count();

    // --- V1/V5 for the final drain ---
    let mut drained: HashMap<u64, ()> = HashMap::new();
    for &v in &h.final_drain {
        if deq.contains_key(&v) {
            // Dequeued during the run AND surfaced by the post-recovery
            // drain: a redelivery — judge against the allowance below
            // (the drain runs after every crash, hence epoch = MAX).
            dup_candidates.push((v, usize::MAX, u64::MAX, u64::MAX));
        } else if drained.contains_key(&v) {
            // The same value twice within one single-threaded drain can
            // never be a batching artifact — always a real duplication.
            push(&mut report.violations, Violation::Duplicate { value: v });
        }
        if !enq.contains_key(&v) {
            push(&mut report.violations, Violation::Invented { value: v });
        }
        drained.insert(v, ());
    }

    // --- V1 (batched dequeues): judge repeat deliveries against the
    // consumer-side trailing-redelivery allowance. Each delivery is
    // judged against the PREVIOUS excused delivery of the same value
    // (chained), so a genuine same-epoch duplicate cannot hide behind an
    // earlier legitimate crash redelivery ---
    if !dup_candidates.is_empty() {
        for seqs in deq_groups.values_mut() {
            seqs.sort_unstable();
        }
        // Previous-delivery record per value: (tid, epoch, response seq).
        // Candidates arrive in history order (event loop, then drain).
        let mut prev: HashMap<u64, (usize, u64, u64)> = deq_meta;
        for (v, tid, epoch, dresp) in dup_candidates {
            let excusable = opts.trailing_redelivery_per_thread > 0
                && prev.get(&v).is_some_and(|&(ptid, pepoch, pdresp)| {
                    // The previous delivery must sit in the unflushed tail
                    // of a crashed epoch, and this one must come after
                    // that crash.
                    if pepoch >= opts.crashed_epochs || epoch <= pepoch {
                        return false;
                    }
                    let seqs = &deq_groups[&(ptid, pepoch)];
                    let rank = seqs.partition_point(|&s| s < pdresp);
                    seqs.len() - rank <= opts.trailing_redelivery_per_thread
                });
            if excusable {
                report.absorbed_redelivered += 1;
                prev.insert(v, (tid, epoch, dresp));
            } else {
                push(&mut report.violations, Violation::Duplicate { value: v });
            }
        }
    }

    // --- V2: no loss (modulo trailing-batch + in-flight-dequeue budgets) ---
    // A dequeue that crashed mid-operation may have been linearized (its
    // following persisted dequeue or an eviction witnessed it — §4,
    // Scenarios 2/3), consuming exactly one value without ever returning.
    // So up to `pending_deqs` completed-enqueue values may legitimately
    // vanish; additionally, under batched durability, the last
    // `trailing_loss_per_thread` completed enqueues of each (tid, epoch)
    // group may vanish at that epoch's crash. Anything beyond is a loss.
    {
        let mut lost: Vec<u64> = enq
            .iter()
            .filter(|&(v, span)| {
                span.response.is_some() && !deq.contains_key(v) && !drained.contains_key(v)
            })
            .map(|(&v, _)| v)
            .collect();
        lost.sort_unstable();

        if opts.trailing_loss_per_thread > 0 && !lost.is_empty() {
            // Per (tid, epoch): the E_resp seqs of all completed enqueues,
            // to identify each group's trailing window.
            let mut groups: HashMap<(usize, u64), Vec<u64>> = HashMap::new();
            for (v, span) in &enq {
                if let (Some(eresp), Some(&meta)) = (span.response, enq_meta.get(v)) {
                    groups.entry(meta).or_default().push(eresp);
                }
            }
            for seqs in groups.values_mut() {
                seqs.sort_unstable();
            }
            lost.retain(|v| {
                let excusable = enq_meta.get(v).is_some_and(|meta| {
                    if meta.1 >= opts.crashed_epochs {
                        return false; // epoch ended cleanly: nothing to lose to
                    }
                    let seqs = &groups[meta];
                    let eresp = enq[v].response.expect("lost values have completed enqueues");
                    let rank = seqs.partition_point(|&s| s < eresp);
                    // Among the last `trailing` of its group?
                    seqs.len() - rank <= opts.trailing_loss_per_thread
                });
                if excusable {
                    report.absorbed_trailing += 1;
                }
                !excusable
            });
        }

        let budget = report.pending_deqs.min(lost.len());
        report.absorbed_losses = budget;
        for &v in lost.iter().skip(budget) {
            push(&mut report.violations, Violation::Lost { value: v });
        }
    }

    // --- V3: (k-relaxed) FIFO real-time order, O(n log n) ---
    // For each dequeued b, count values a with
    //   E_resp(a) < E_inv(b)  AND  D_inv(a) > D_resp(b)
    // — the strictly-older items b jumped over. Strict FIFO (k = 0)
    // flags any such a; k-relaxed flags counts > k. The sweep inserts
    // candidates in E_resp order into a Fenwick tree keyed by D_inv rank
    // while visiting b's in E_inv order.
    {
        // Values with completed enqueue AND completed dequeue.
        let mut a_side: Vec<(u64, u64, u64)> = Vec::new(); // (E_resp, D_inv, v)
        let mut b_side: Vec<(u64, u64, u64)> = Vec::new(); // (E_inv, D_resp, v)
        for (&v, es) in &enq {
            let (Some(eresp), Some(ds)) = (es.response, deq.get(&v)) else { continue };
            let Some(dresp) = ds.response else { continue };
            a_side.push((eresp, ds.invoke, v));
            b_side.push((es.invoke, dresp, v));
        }
        a_side.sort_unstable();
        b_side.sort_unstable();
        // Coordinate-compress D_inv values for the Fenwick tree.
        let mut dinvs: Vec<u64> = a_side.iter().map(|&(_, dinv, _)| dinv).collect();
        dinvs.sort_unstable();
        let mut bit = Bit::new(dinvs.len());
        let mut inserted = 0usize;
        let mut j = 0usize;
        // Running max of inserted D_inv (strongest witness) for reporting.
        let mut max_dinv: (u64, u64) = (0, 0); // (dinv, value)
        for &(einv_b, dresp_b, vb) in &b_side {
            while j < a_side.len() && a_side[j].0 < einv_b {
                let (_, dinv, va) = a_side[j];
                let rank = dinvs.partition_point(|&d| d < dinv) + 1;
                bit.add(rank);
                inserted += 1;
                if dinv >= max_dinv.0 {
                    max_dinv = (dinv, va);
                }
                j += 1;
            }
            if inserted == 0 {
                continue;
            }
            // Inserted entries with D_inv <= D_resp(b) did not overtake.
            let le = bit.prefix(dinvs.partition_point(|&d| d <= dresp_b));
            let overtakes = inserted - le;
            report.max_overtakes = report.max_overtakes.max(overtakes);
            if opts.collect_overtakes {
                report.overtake_counts.push(overtakes);
            }
            if overtakes > opts.relaxation {
                push(
                    &mut report.violations,
                    Violation::FifoInversion { first: max_dinv.1, second: vb },
                );
            }
        }
    }

    // --- V4: EMPTY soundness ---
    // Violation iff some value v: E_resp(v) < EMPTY.invoke and v's dequeue
    // was invoked only after EMPTY.response (or never — and not drained
    // either... a drained value was still in the queue, which also
    // justifies the violation only if it was enqueued before; drained
    // values count as "never dequeued during the run").
    if opts.check_empty {
        // Values with completed enqueues, sorted by E_resp, carrying their
        // dequeue-invoke seq. A value never dequeued during the run can
        // witness only if it reached the final drain (provably present
        // throughout); otherwise it may have been consumed by a crashed,
        // linearized dequeue (the V2 absorbed-loss budget) and cannot
        // witness an EMPTY.
        let mut vals: Vec<(u64, u64, u64)> = Vec::new(); // (E_resp, D_inv, v)
        for (&v, es) in &enq {
            if let Some(eresp) = es.response {
                match deq.get(&v) {
                    Some(d) => vals.push((eresp, d.invoke, v)),
                    None if drained.contains_key(&v) => vals.push((eresp, u64::MAX, v)),
                    None => {} // possibly absorbed at a crash — not a witness
                }
            }
        }
        vals.sort_unstable();
        // Prefix max of D_inv (a value whose dequeue started LATEST — the
        // strongest witness candidate).
        let mut prefix: Vec<(u64, u64)> = Vec::with_capacity(vals.len());
        let mut cur = (0u64, 0u64);
        for &(_, dinv, v) in &vals {
            if dinv >= cur.0 {
                cur = (dinv, v);
            }
            prefix.push(cur);
        }
        for emp in &empties {
            let Some(eresp) = emp.response else { continue };
            let idx = vals.partition_point(|&(er, _, _)| er < emp.invoke);
            if idx == 0 {
                continue;
            }
            let (max_dinv, witness) = prefix[idx - 1];
            if max_dinv > eresp {
                push(
                    &mut report.violations,
                    Violation::BogusEmpty { witness, empty_seq: emp.invoke },
                );
            }
        }
    }

    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::history::{Event, EventKind as K};

    fn ev(seq: u64, tid: usize, kind: K) -> Event {
        Event { seq, tid, epoch: 0, kind }
    }

    fn hist(events: Vec<Event>, drain: Vec<u64>) -> History {
        History { events, final_drain: drain }
    }

    #[test]
    fn clean_history_passes() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 1 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 2 }),
                ev(8, 1, K::DeqInvoke),
                ev(9, 1, K::DeqEmpty),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.enq_completed, 2);
        assert_eq!(r.deq_values, 2);
        assert_eq!(r.deq_empties, 1);
    }

    #[test]
    fn windowed_async_ops_pair_fifo_per_thread() {
        // The async API holds several open ops per thread (a future
        // window); responses come back in submission order. The pairing
        // must match response i to invoke i — not to the latest invoke —
        // and must not count the overlap as pending dequeues.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqInvoke { value: 2 }),
                ev(2, 0, K::EnqOk { value: 1 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqInvoke),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 1 }),
                ev(8, 1, K::DeqOk { value: 2 }),
                ev(9, 1, K::DeqEmpty),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.pending_deqs, 0, "overlapping open deqs are not 'pending'");
        assert_eq!(r.deq_values, 2);
        assert_eq!(r.deq_empties, 1);
    }

    #[test]
    fn crossepoch_dangling_deq_counts_pending_once() {
        // A dequeue left open by a crashed epoch is skipped by the next
        // epoch's pairing and lands in the pending budget exactly once
        // (it may have consumed value 5 at the crash).
        let mut e4 = ev(3, 1, K::DeqInvoke);
        e4.epoch = 0;
        let mut e5 = ev(4, 1, K::DeqInvoke);
        e5.epoch = 1;
        let mut e6 = ev(5, 1, K::DeqEmpty);
        e6.epoch = 1;
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
                e4,
                e5,
                e6,
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.pending_deqs, 1);
        assert_eq!(r.absorbed_losses, 1, "value 5 absorbed by the crashed dequeue");
    }

    #[test]
    fn executed_markers_tighten_the_pending_budget() {
        // Two completed enqueues vanish; three dequeues were open at the
        // crash but only ONE ever executed against the queue. A
        // marker-free history must absorb both losses (every open invoke
        // may have consumed); a marker-carrying history may absorb only
        // one — the second loss is real.
        let base = vec![
            ev(0, 0, K::EnqInvoke { value: 1 }),
            ev(1, 0, K::EnqOk { value: 1 }),
            ev(2, 0, K::EnqInvoke { value: 2 }),
            ev(3, 0, K::EnqOk { value: 2 }),
            ev(4, 1, K::DeqInvoke),
            ev(5, 1, K::DeqInvoke),
            ev(6, 1, K::DeqInvoke),
        ];
        let r = check(&hist(base.clone(), vec![]), 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.pending_deqs, 3, "marker-free: every open invoke is budget");
        let mut marked = base;
        marked.push(ev(7, 1, K::DeqExecuted));
        let r = check(&hist(marked, vec![]), 10);
        assert_eq!(r.pending_deqs, 1, "markers: only executed invokes are budget");
        assert_eq!(r.absorbed_losses, 1);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert!(matches!(r.violations[0], Violation::Lost { .. }));
    }

    #[test]
    fn executed_markers_bind_to_their_own_epoch() {
        // Epoch 0 crashed with two never-executed open invokes (ring-
        // drained futures record no response). Epoch 1's marker must mark
        // the epoch-1 invoke — not a stale epoch-0 one — so the pending
        // budget stays exactly the executed-unresponded count (1), not 2.
        fn eve(seq: u64, tid: usize, epoch: u64, kind: K) -> Event {
            Event { seq, tid, epoch, kind }
        }
        let h = hist(
            vec![
                eve(0, 0, 0, K::EnqInvoke { value: 1 }),
                eve(1, 0, 0, K::EnqOk { value: 1 }),
                eve(2, 1, 0, K::DeqInvoke), // never executed (crashed in ring)
                eve(3, 1, 0, K::DeqInvoke), // never executed
                eve(4, 1, 1, K::DeqInvoke),
                eve(5, 1, 1, K::DeqExecuted), // must mark seq-4, not seq-2
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert_eq!(
            r.pending_deqs, 1,
            "only the epoch-1 executed invoke may enter the budget"
        );
        assert_eq!(r.absorbed_losses, 1, "value 1 absorbed by the executed in-flight deq");
        assert!(r.ok(), "{:?}", r.violations);
    }

    #[test]
    fn resharding_relaxation_adds_residue() {
        assert_eq!(
            resharding_relaxation(4, 8, 2, 100),
            shard_relaxation(4, 8, 2) + 100
        );
        assert_eq!(resharding_relaxation(4, 8, 2, 0), shard_relaxation(4, 8, 2));
    }

    #[test]
    fn detects_duplicate() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 7 }),
                ev(1, 0, K::EnqOk { value: 7 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqOk { value: 7 }),
                ev(4, 2, K::DeqInvoke),
                ev(5, 2, K::DeqOk { value: 7 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Duplicate { value: 7 }));
    }

    #[test]
    fn detects_invented() {
        let h = hist(
            vec![ev(0, 0, K::DeqInvoke), ev(1, 0, K::DeqOk { value: 99 })],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Invented { value: 99 }));
    }

    #[test]
    fn detects_loss() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
            ],
            vec![], // not drained either
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Lost { value: 5 }));
    }

    #[test]
    fn crashed_dequeue_absorbs_one_loss() {
        // An in-flight dequeue (no response) may have consumed the value —
        // legal per §4 Scenario 2 — so no violation...
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
                ev(2, 1, K::DeqInvoke), // crashed mid-dequeue
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.pending_deqs, 1);
        assert_eq!(r.absorbed_losses, 1);
        // ...but it absorbs at most ONE value.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
                ev(2, 0, K::EnqInvoke { value: 6 }),
                ev(3, 0, K::EnqOk { value: 6 }),
                ev(4, 1, K::DeqInvoke),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert!(matches!(r.violations[0], Violation::Lost { .. }));
    }

    #[test]
    fn drained_value_is_not_lost() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
            ],
            vec![5],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
    }

    #[test]
    fn uncompleted_enqueue_may_vanish() {
        // Enqueue invoked but not completed: value disappearing is fine.
        let h = hist(vec![ev(0, 0, K::EnqInvoke { value: 5 })], vec![]);
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn uncompleted_enqueue_may_linearize() {
        // Crashed mid-enqueue but the value shows up post-crash: fine (§4.1).
        let h = hist(vec![ev(0, 0, K::EnqInvoke { value: 5 })], vec![5]);
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn detects_fifo_inversion() {
        // enq(1) completes before enq(2) is invoked, but deq(2) completes
        // before deq(1) is invoked.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 2 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(
            r.violations.iter().any(|v| matches!(v, Violation::FifoInversion { .. })),
            "{:?}",
            r.violations
        );
        assert_eq!(r.max_overtakes, 1);
    }

    #[test]
    fn relaxation_tolerates_bounded_overtakes() {
        // Same single-overtake history as above: k = 1 must accept it,
        // k = 0 must reject it.
        let events = vec![
            ev(0, 0, K::EnqInvoke { value: 1 }),
            ev(1, 0, K::EnqOk { value: 1 }),
            ev(2, 0, K::EnqInvoke { value: 2 }),
            ev(3, 0, K::EnqOk { value: 2 }),
            ev(4, 1, K::DeqInvoke),
            ev(5, 1, K::DeqOk { value: 2 }),
            ev(6, 1, K::DeqInvoke),
            ev(7, 1, K::DeqOk { value: 1 }),
        ];
        let h = hist(events, vec![]);
        assert!(!check_relaxed(&h, 0).ok());
        let r = check_relaxed(&h, 1);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.max_overtakes, 1);
    }

    #[test]
    fn overtake_distribution_collection_and_calibration() {
        // Value 4 overtakes 1, 2, 3; the rest are in order.
        let mut events = Vec::new();
        let mut seq = 0u64;
        for v in 1..=4u64 {
            events.push(ev(seq, 0, K::EnqInvoke { value: v }));
            seq += 1;
            events.push(ev(seq, 0, K::EnqOk { value: v }));
            seq += 1;
        }
        for v in [4u64, 1, 2, 3] {
            events.push(ev(seq, 1, K::DeqInvoke));
            seq += 1;
            events.push(ev(seq, 1, K::DeqOk { value: v }));
            seq += 1;
        }
        let h = hist(events, vec![]);
        let r = check_with(
            &h,
            &CheckOptions {
                relaxation: usize::MAX,
                collect_overtakes: true,
                ..Default::default()
            },
        );
        assert!(r.ok(), "{:?}", r.violations);
        assert!(!r.overtake_counts.is_empty());
        assert_eq!(*r.overtake_counts.iter().max().unwrap(), 3);
        let stats = overtake_stats(&r.overtake_counts);
        assert_eq!(stats.max, 3);
        assert!(stats.p50 <= stats.p99 && stats.p99 <= stats.max);
        let k = calibrate_relaxation(&r.overtake_counts);
        assert!(k >= 3, "calibrated bound must cover the observed max");
        // The history passes its own calibrated bound.
        assert!(check_relaxed(&h, k).ok());
        // Collection off by default: no distribution is stored.
        let r0 = check_relaxed(&h, 3);
        assert!(r0.overtake_counts.is_empty());
    }

    #[test]
    fn calibration_headroom() {
        assert_eq!(calibrate_relaxation(&[]), 0, "no overtakes observed: strict bound");
        assert_eq!(calibrate_relaxation(&[0, 0, 0]), 0, "fully ordered: strict bound");
        assert_eq!(calibrate_relaxation(&[10]), 18, "10 + max(10/4, 8)");
        assert_eq!(calibrate_relaxation(&[100]), 125, "100 + 25%");
        assert_eq!(overtake_stats(&[]), OvertakeStats::default());
    }

    #[test]
    fn relaxation_bound_is_tight() {
        // Value 4 overtakes 1, 2, 3 (three strictly-older values): k = 2
        // rejects, k = 3 accepts.
        let mut events = Vec::new();
        let mut seq = 0u64;
        for v in 1..=4u64 {
            events.push(ev(seq, 0, K::EnqInvoke { value: v }));
            seq += 1;
            events.push(ev(seq, 0, K::EnqOk { value: v }));
            seq += 1;
        }
        // Dequeue 4 first, then 1, 2, 3.
        for v in [4u64, 1, 2, 3] {
            events.push(ev(seq, 1, K::DeqInvoke));
            seq += 1;
            events.push(ev(seq, 1, K::DeqOk { value: v }));
            seq += 1;
        }
        let h = hist(events, vec![]);
        let r = check_relaxed(&h, 2);
        assert!(!r.ok(), "3 overtakes must exceed k=2");
        assert_eq!(r.max_overtakes, 3);
        assert!(check_relaxed(&h, 3).ok());
    }

    #[test]
    fn trailing_loss_allowance_absorbs_batched_tail() {
        // Thread 0 completed enqueues 1, 2, 3; the last two vanished at the
        // crash (batch B = 3 → allowance 2). Value 1 was dequeued.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 0, K::EnqInvoke { value: 3 }),
                ev(5, 0, K::EnqOk { value: 3 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        let strict = check(&h, 10);
        assert_eq!(strict.violations.len(), 2, "{:?}", strict.violations);
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_loss_per_thread: 2,
                crashed_epochs: 1,
                ..Default::default()
            },
        );
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.absorbed_trailing, 2);
        // Same history, but epoch 0 never ended in a crash: the losses are
        // real again.
        let clean = check_with(
            &h,
            &CheckOptions { trailing_loss_per_thread: 2, ..Default::default() },
        );
        assert_eq!(clean.violations.len(), 2, "{:?}", clean.violations);
    }

    #[test]
    fn trailing_allowance_does_not_excuse_middle_losses() {
        // Value 1 (NOT in the trailing window — 2 and 3 completed after it
        // and survived) vanishes: still a loss even with an allowance.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 0, K::EnqInvoke { value: 3 }),
                ev(5, 0, K::EnqOk { value: 3 }),
            ],
            vec![2, 3],
        );
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_loss_per_thread: 2,
                crashed_epochs: 1,
                ..Default::default()
            },
        );
        assert!(
            r.violations.contains(&Violation::Lost { value: 1 }),
            "middle loss must not be excused: {:?}",
            r.violations
        );
    }

    #[test]
    fn redelivery_allowance_absorbs_unflushed_dequeues() {
        // Thread 1 dequeued values 1 and 2 in epoch 0 (which crashed); the
        // consumer batch (K = 3 → allowance 2) was never flushed, so both
        // values came back in epoch 1.
        fn eve(seq: u64, tid: usize, epoch: u64, kind: K) -> Event {
            Event { seq, tid, epoch, kind }
        }
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 1 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 2 }),
                eve(8, 2, 1, K::DeqInvoke),
                eve(9, 2, 1, K::DeqOk { value: 1 }),
            ],
            vec![2], // value 2 redelivered into the final drain
        );
        // Strict mode: both redeliveries are duplications.
        let strict = check(&h, 10);
        assert_eq!(strict.violations.len(), 2, "{:?}", strict.violations);
        // With the allowance and a crashed epoch 0: both are absorbed.
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_redelivery_per_thread: 2,
                crashed_epochs: 1,
                ..Default::default()
            },
        );
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.absorbed_redelivered, 2);
        // Same history but epoch 0 never crashed: real duplications again.
        let clean = check_with(
            &h,
            &CheckOptions { trailing_redelivery_per_thread: 2, ..Default::default() },
        );
        assert_eq!(clean.violations.len(), 2, "{:?}", clean.violations);
    }

    #[test]
    fn redelivery_allowance_does_not_excuse_early_dequeues() {
        // Value 1's dequeue is NOT in the trailing window (values 2 and 3
        // were dequeued after it by the same thread in the same epoch, and
        // the allowance is only 2): its reappearance is a real duplicate.
        let mut events = vec![];
        let mut seq = 0u64;
        for v in 1..=3u64 {
            events.push(ev(seq, 0, K::EnqInvoke { value: v }));
            seq += 1;
            events.push(ev(seq, 0, K::EnqOk { value: v }));
            seq += 1;
        }
        for v in 1..=3u64 {
            events.push(ev(seq, 1, K::DeqInvoke));
            seq += 1;
            events.push(ev(seq, 1, K::DeqOk { value: v }));
            seq += 1;
        }
        let h = hist(events, vec![1]);
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_redelivery_per_thread: 2,
                crashed_epochs: 1,
                ..Default::default()
            },
        );
        assert!(
            r.violations.contains(&Violation::Duplicate { value: 1 }),
            "early dequeue's redelivery must not be excused: {:?}",
            r.violations
        );
    }

    #[test]
    fn same_epoch_duplicate_never_excused() {
        // A duplicate delivery within one epoch cannot be a crash
        // redelivery — the allowance must not apply.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 7 }),
                ev(1, 0, K::EnqOk { value: 7 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqOk { value: 7 }),
                ev(4, 2, K::DeqInvoke),
                ev(5, 2, K::DeqOk { value: 7 }),
            ],
            vec![],
        );
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_redelivery_per_thread: 8,
                crashed_epochs: 5,
                ..Default::default()
            },
        );
        assert!(r.violations.contains(&Violation::Duplicate { value: 7 }), "{:?}", r.violations);
    }

    #[test]
    fn drain_internal_duplicate_never_excused() {
        // The same value twice in the single-threaded final drain is a
        // structural duplication regardless of any allowance.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 0, K::EnqOk { value: 9 }),
            ],
            vec![9, 9],
        );
        let r = check_with(
            &h,
            &CheckOptions {
                trailing_redelivery_per_thread: 8,
                crashed_epochs: 5,
                ..Default::default()
            },
        );
        assert!(r.violations.contains(&Violation::Duplicate { value: 9 }), "{:?}", r.violations);
    }

    #[test]
    fn overlapping_enqueues_may_reorder() {
        // enq(1) and enq(2) overlap: either dequeue order is legal.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 1, K::EnqInvoke { value: 2 }),
                ev(2, 1, K::EnqOk { value: 2 }),
                ev(3, 0, K::EnqOk { value: 1 }),
                ev(4, 2, K::DeqInvoke),
                ev(5, 2, K::DeqOk { value: 2 }),
                ev(6, 2, K::DeqInvoke),
                ev(7, 2, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn overlapping_dequeues_may_reorder() {
        // Sequential enqueues but OVERLAPPING dequeues: no inversion.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 2, K::DeqInvoke),
                ev(6, 2, K::DeqOk { value: 2 }),
                ev(7, 1, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn detects_bogus_empty() {
        // enq(9) completed before the EMPTY started; its dequeue began
        // only after the EMPTY returned.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 0, K::EnqOk { value: 9 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqEmpty),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 9 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(
            r.violations.iter().any(|v| matches!(v, Violation::BogusEmpty { witness: 9, .. })),
            "{:?}",
            r.violations
        );
        // Buffered mode (check_empty = false) skips V4.
        let r = check_with(&h, &CheckOptions { check_empty: false, ..Default::default() });
        assert!(r.ok(), "{:?}", r.violations);
    }

    #[test]
    fn empty_overlapping_enqueue_is_fine() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 1, K::DeqInvoke),
                ev(2, 1, K::DeqEmpty),
                ev(3, 0, K::EnqOk { value: 9 }),
            ],
            vec![9],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn empty_with_undequeued_prior_value_flagged_via_drain() {
        // Value 9 enqueued-completed before EMPTY, never dequeued (only
        // drained at the end): the EMPTY was bogus.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 0, K::EnqOk { value: 9 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqEmpty),
            ],
            vec![9],
        );
        let r = check(&h, 10);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::BogusEmpty { .. })));
    }

    #[test]
    fn value_reuse_flagged() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 3 }),
                ev(1, 0, K::EnqOk { value: 3 }),
                ev(2, 0, K::EnqInvoke { value: 3 }),
            ],
            vec![3],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::ValueReused { value: 3 }));
    }

    #[test]
    fn options_for_encodes_each_durability_mode() {
        let cfg = crate::queues::QueueConfig {
            shards: 4,
            batch: 8,
            batch_deq: 4,
            block: 16,
            ..Default::default()
        };
        let strict = options_for("perlcrq", 8, &cfg, 3);
        assert_eq!(strict.relaxation, 0);
        assert_eq!(strict.trailing_loss_per_thread, 0);
        assert_eq!(strict.trailing_redelivery_per_thread, 0);
        assert!(strict.check_empty);
        assert_eq!(strict.crashed_epochs, 3);

        let sharded = options_for("sharded-perlcrq", 8, &cfg, 3);
        assert_eq!(sharded.relaxation, shard_relaxation(8, 4, 8));
        assert_eq!(sharded.trailing_loss_per_thread, 7);
        assert_eq!(sharded.trailing_redelivery_per_thread, 3);
        assert!(!sharded.check_empty, "batched EMPTY is unsound");

        let bf = options_for("blockfifo", 8, &cfg, 3);
        assert_eq!(bf.relaxation, block_relaxation(8, 4, 16));
        assert_eq!(bf.trailing_loss_per_thread, 15, "open block holds block - 1");
        assert_eq!(bf.trailing_redelivery_per_thread, 16, "DRAINING rollback is whole-block");
        assert!(!bf.check_empty, "open blocks are invisible to other consumers");
        let bfm = options_for("blockfifo-multi", 8, &cfg, 3);
        assert_eq!(bfm.relaxation, bf.relaxation);
    }

    #[test]
    fn blockfifo_relaxation_scales_with_block() {
        let mut cfg = crate::queues::QueueConfig { shards: 2, block: 8, ..Default::default() };
        let small = relaxation_for("blockfifo", 4, &cfg);
        cfg.block = 32;
        let large = relaxation_for("blockfifo-multi", 4, &cfg);
        assert!(large > small);
        assert_eq!(relaxation_for("iq", 4, &cfg), 0);
    }
}
