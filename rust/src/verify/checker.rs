//! The durable-linearizability checker (see [`super`] for the axioms).

use std::collections::HashMap;

use super::history::{EventKind, History};

/// A detected violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// Value dequeued more than once (or drained after being dequeued).
    Duplicate { value: u64 },
    /// Value dequeued/drained without any invoked enqueue.
    Invented { value: u64 },
    /// Completed enqueue's value neither dequeued nor drained, beyond the
    /// budget of in-flight dequeues that may have legitimately consumed it
    /// (an uncompleted dequeue linearized at a crash — paper §4, Scenario
    /// 2 — absorbs at most one value).
    Lost { value: u64 },
    /// Real-time FIFO inversion between two dequeued values.
    FifoInversion { first: u64, second: u64 },
    /// EMPTY returned while some value was provably present throughout.
    BogusEmpty { witness: u64, empty_seq: u64 },
    /// The same value was enqueued twice (workload bug, not queue bug).
    ValueReused { value: u64 },
}

/// Check outcome.
#[derive(Clone, Debug, Default)]
pub struct CheckReport {
    pub violations: Vec<Violation>,
    pub enq_invoked: usize,
    pub enq_completed: usize,
    pub deq_values: usize,
    pub deq_empties: usize,
    pub drained: usize,
    /// Dequeues invoked but never responded (crashed mid-operation); each
    /// may absorb one otherwise-"lost" value.
    pub pending_deqs: usize,
    /// Values that vanished within the pending-dequeue budget (not
    /// violations, but reported for transparency).
    pub absorbed_losses: usize,
}

impl CheckReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

#[derive(Default, Clone, Copy)]
struct OpSpan {
    invoke: u64,
    response: Option<u64>,
}

/// Run all checks over a history. `max_report` caps reported violations.
pub fn check(h: &History, max_report: usize) -> CheckReport {
    let mut report = CheckReport::default();
    let push = |vs: &mut Vec<Violation>, v: Violation| {
        if vs.len() < max_report {
            vs.push(v);
        }
    };

    // --- Index the history ---
    let mut enq: HashMap<u64, OpSpan> = HashMap::new();
    // Pending (per-thread) open spans to match responses to invokes.
    let mut open_enq: HashMap<usize, (u64, u64)> = HashMap::new(); // tid -> (value, seq)
    let mut open_deq: HashMap<usize, u64> = HashMap::new(); // tid -> invoke seq
    let mut deq: HashMap<u64, OpSpan> = HashMap::new(); // value -> span
    let mut empties: Vec<OpSpan> = Vec::new();

    for e in &h.events {
        match e.kind {
            EventKind::EnqInvoke { value } => {
                if enq.contains_key(&value) {
                    push(&mut report.violations, Violation::ValueReused { value });
                }
                enq.insert(value, OpSpan { invoke: e.seq, response: None });
                open_enq.insert(e.tid, (value, e.seq));
                report.enq_invoked += 1;
            }
            EventKind::EnqOk { value } => {
                if let Some(span) = enq.get_mut(&value) {
                    span.response = Some(e.seq);
                }
                open_enq.remove(&e.tid);
                report.enq_completed += 1;
            }
            EventKind::DeqInvoke => {
                // A dequeue left open (crashed) stays in `open_deq` and is
                // counted below; a thread's new invoke replaces its old
                // one only if that one responded, so count leftovers per
                // (tid, invoke): track crashed dequeues explicitly.
                if let Some(prev) = open_deq.insert(e.tid, e.seq) {
                    let _ = prev;
                    report.pending_deqs += 1; // previous invoke never responded
                }
            }
            EventKind::DeqOk { value } => {
                let invoke = open_deq.remove(&e.tid).unwrap_or(e.seq);
                if deq.contains_key(&value) {
                    push(&mut report.violations, Violation::Duplicate { value });
                } else {
                    deq.insert(value, OpSpan { invoke, response: Some(e.seq) });
                }
                if !enq.contains_key(&value) {
                    push(&mut report.violations, Violation::Invented { value });
                }
                report.deq_values += 1;
            }
            EventKind::DeqEmpty => {
                let invoke = open_deq.remove(&e.tid).unwrap_or(e.seq);
                empties.push(OpSpan { invoke, response: Some(e.seq) });
                report.deq_empties += 1;
            }
        }
    }
    report.drained = h.final_drain.len();
    // Dequeues still open at the end of the history also count as pending.
    report.pending_deqs += open_deq.len();

    // --- V1/V5 for the final drain ---
    let mut drained: HashMap<u64, ()> = HashMap::new();
    for &v in &h.final_drain {
        if deq.contains_key(&v) || drained.contains_key(&v) {
            push(&mut report.violations, Violation::Duplicate { value: v });
        }
        if !enq.contains_key(&v) {
            push(&mut report.violations, Violation::Invented { value: v });
        }
        drained.insert(v, ());
    }

    // --- V2: no loss (modulo the in-flight-dequeue budget) ---
    // A dequeue that crashed mid-operation may have been linearized (its
    // following persisted dequeue or an eviction witnessed it — §4,
    // Scenarios 2/3), consuming exactly one value without ever returning.
    // So up to `pending_deqs` completed-enqueue values may legitimately
    // vanish; anything beyond that is a real loss.
    {
        let mut lost: Vec<u64> = enq
            .iter()
            .filter(|(v, span)| {
                span.response.is_some() && !deq.contains_key(v) && !drained.contains_key(v)
            })
            .map(|(&v, _)| v)
            .collect();
        lost.sort_unstable();
        let budget = report.pending_deqs.min(lost.len());
        report.absorbed_losses = budget;
        for &v in lost.iter().skip(budget) {
            push(&mut report.violations, Violation::Lost { value: v });
        }
    }

    // --- V3: FIFO real-time order, O(n log n) ---
    // For dequeued pairs: violation iff ∃ a, b with
    //   E_resp(a) < E_inv(b)  AND  D_resp(b) < D_inv(a).
    // Sweep ops in increasing E_resp; maintain prefix-max of D_inv; for
    // each b compare against the prefix of enqueues completed before
    // E_inv(b).
    {
        // (E_resp, D_inv, value) for values dequeued AND enqueue-completed.
        let mut by_eresp: Vec<(u64, u64, u64)> = Vec::new();
        for (&v, es) in &enq {
            if let (Some(eresp), Some(ds)) = (es.response, deq.get(&v)) {
                by_eresp.push((eresp, ds.invoke, v));
            }
        }
        by_eresp.sort_unstable();
        // prefix_max_dinv[i] = max D_inv over by_eresp[..=i], with the
        // owning value for reporting.
        let mut prefix: Vec<(u64, u64)> = Vec::with_capacity(by_eresp.len());
        let mut cur = (0u64, 0u64);
        for &(_, dinv, v) in &by_eresp {
            if dinv >= cur.0 {
                cur = (dinv, v);
            }
            prefix.push(cur);
        }
        // For each b: find enqueues with E_resp < E_inv(b).
        for (&vb, eb) in &enq {
            let (Some(db), true) = (deq.get(&vb), eb.response.is_some()) else {
                continue;
            };
            let Some(dresp_b) = db.response else { continue };
            // Binary search on by_eresp for E_resp < E_inv(b).
            let idx = by_eresp.partition_point(|&(eresp, _, _)| eresp < eb.invoke);
            if idx == 0 {
                continue;
            }
            let (max_dinv, va) = prefix[idx - 1];
            if max_dinv > dresp_b && va != vb {
                push(
                    &mut report.violations,
                    Violation::FifoInversion { first: va, second: vb },
                );
            }
        }
    }

    // --- V4: EMPTY soundness ---
    // Violation iff some value v: E_resp(v) < EMPTY.invoke and v's dequeue
    // was invoked only after EMPTY.response (or never — and not drained
    // either... a drained value was still in the queue, which also
    // justifies the violation only if it was enqueued before; drained
    // values count as "never dequeued during the run").
    {
        // Values with completed enqueues, sorted by E_resp, carrying their
        // dequeue-invoke seq. A value never dequeued during the run can
        // witness only if it reached the final drain (provably present
        // throughout); otherwise it may have been consumed by a crashed,
        // linearized dequeue (the V2 absorbed-loss budget) and cannot
        // witness an EMPTY.
        let mut vals: Vec<(u64, u64, u64)> = Vec::new(); // (E_resp, D_inv, v)
        for (&v, es) in &enq {
            if let Some(eresp) = es.response {
                match deq.get(&v) {
                    Some(d) => vals.push((eresp, d.invoke, v)),
                    None if drained.contains_key(&v) => vals.push((eresp, u64::MAX, v)),
                    None => {} // possibly absorbed at a crash — not a witness
                }
            }
        }
        vals.sort_unstable();
        // Prefix max of D_inv (a value whose dequeue started LATEST — the
        // strongest witness candidate).
        let mut prefix: Vec<(u64, u64)> = Vec::with_capacity(vals.len());
        let mut cur = (0u64, 0u64);
        for &(_, dinv, v) in &vals {
            if dinv >= cur.0 {
                cur = (dinv, v);
            }
            prefix.push(cur);
        }
        for emp in &empties {
            let Some(eresp) = emp.response else { continue };
            let idx = vals.partition_point(|&(er, _, _)| er < emp.invoke);
            if idx == 0 {
                continue;
            }
            let (max_dinv, witness) = prefix[idx - 1];
            if max_dinv > eresp {
                push(
                    &mut report.violations,
                    Violation::BogusEmpty { witness, empty_seq: emp.invoke },
                );
            }
        }
    }

    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::verify::history::{Event, EventKind as K};

    fn ev(seq: u64, tid: usize, kind: K) -> Event {
        Event { seq, tid, epoch: 0, kind }
    }

    fn hist(events: Vec<Event>, drain: Vec<u64>) -> History {
        History { events, final_drain: drain }
    }

    #[test]
    fn clean_history_passes() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 1 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 2 }),
                ev(8, 1, K::DeqInvoke),
                ev(9, 1, K::DeqEmpty),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.enq_completed, 2);
        assert_eq!(r.deq_values, 2);
        assert_eq!(r.deq_empties, 1);
    }

    #[test]
    fn detects_duplicate() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 7 }),
                ev(1, 0, K::EnqOk { value: 7 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqOk { value: 7 }),
                ev(4, 2, K::DeqInvoke),
                ev(5, 2, K::DeqOk { value: 7 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Duplicate { value: 7 }));
    }

    #[test]
    fn detects_invented() {
        let h = hist(
            vec![ev(0, 0, K::DeqInvoke), ev(1, 0, K::DeqOk { value: 99 })],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Invented { value: 99 }));
    }

    #[test]
    fn detects_loss() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
            ],
            vec![], // not drained either
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::Lost { value: 5 }));
    }

    #[test]
    fn crashed_dequeue_absorbs_one_loss() {
        // An in-flight dequeue (no response) may have consumed the value —
        // legal per §4 Scenario 2 — so no violation...
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
                ev(2, 1, K::DeqInvoke), // crashed mid-dequeue
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
        assert_eq!(r.pending_deqs, 1);
        assert_eq!(r.absorbed_losses, 1);
        // ...but it absorbs at most ONE value.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
                ev(2, 0, K::EnqInvoke { value: 6 }),
                ev(3, 0, K::EnqOk { value: 6 }),
                ev(4, 1, K::DeqInvoke),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert_eq!(r.violations.len(), 1, "{:?}", r.violations);
        assert!(matches!(r.violations[0], Violation::Lost { .. }));
    }

    #[test]
    fn drained_value_is_not_lost() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 5 }),
                ev(1, 0, K::EnqOk { value: 5 }),
            ],
            vec![5],
        );
        let r = check(&h, 10);
        assert!(r.ok(), "{:?}", r.violations);
    }

    #[test]
    fn uncompleted_enqueue_may_vanish() {
        // Enqueue invoked but not completed: value disappearing is fine.
        let h = hist(vec![ev(0, 0, K::EnqInvoke { value: 5 })], vec![]);
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn uncompleted_enqueue_may_linearize() {
        // Crashed mid-enqueue but the value shows up post-crash: fine (§4.1).
        let h = hist(vec![ev(0, 0, K::EnqInvoke { value: 5 })], vec![5]);
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn detects_fifo_inversion() {
        // enq(1) completes before enq(2) is invoked, but deq(2) completes
        // before deq(1) is invoked.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 2 }),
                ev(6, 1, K::DeqInvoke),
                ev(7, 1, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(
            r.violations.iter().any(|v| matches!(v, Violation::FifoInversion { .. })),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn overlapping_enqueues_may_reorder() {
        // enq(1) and enq(2) overlap: either dequeue order is legal.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 1, K::EnqInvoke { value: 2 }),
                ev(2, 1, K::EnqOk { value: 2 }),
                ev(3, 0, K::EnqOk { value: 1 }),
                ev(4, 2, K::DeqInvoke),
                ev(5, 2, K::DeqOk { value: 2 }),
                ev(6, 2, K::DeqInvoke),
                ev(7, 2, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn overlapping_dequeues_may_reorder() {
        // Sequential enqueues but OVERLAPPING dequeues: no inversion.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 1 }),
                ev(1, 0, K::EnqOk { value: 1 }),
                ev(2, 0, K::EnqInvoke { value: 2 }),
                ev(3, 0, K::EnqOk { value: 2 }),
                ev(4, 1, K::DeqInvoke),
                ev(5, 2, K::DeqInvoke),
                ev(6, 2, K::DeqOk { value: 2 }),
                ev(7, 1, K::DeqOk { value: 1 }),
            ],
            vec![],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn detects_bogus_empty() {
        // enq(9) completed before the EMPTY started; its dequeue began
        // only after the EMPTY returned.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 0, K::EnqOk { value: 9 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqEmpty),
                ev(4, 1, K::DeqInvoke),
                ev(5, 1, K::DeqOk { value: 9 }),
            ],
            vec![],
        );
        let r = check(&h, 10);
        assert!(
            r.violations.iter().any(|v| matches!(v, Violation::BogusEmpty { witness: 9, .. })),
            "{:?}",
            r.violations
        );
    }

    #[test]
    fn empty_overlapping_enqueue_is_fine() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 1, K::DeqInvoke),
                ev(2, 1, K::DeqEmpty),
                ev(3, 0, K::EnqOk { value: 9 }),
            ],
            vec![9],
        );
        assert!(check(&h, 10).ok());
    }

    #[test]
    fn empty_with_undequeued_prior_value_flagged_via_drain() {
        // Value 9 enqueued-completed before EMPTY, never dequeued (only
        // drained at the end): the EMPTY was bogus.
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 9 }),
                ev(1, 0, K::EnqOk { value: 9 }),
                ev(2, 1, K::DeqInvoke),
                ev(3, 1, K::DeqEmpty),
            ],
            vec![9],
        );
        let r = check(&h, 10);
        assert!(r.violations.iter().any(|v| matches!(v, Violation::BogusEmpty { .. })));
    }

    #[test]
    fn value_reuse_flagged() {
        let h = hist(
            vec![
                ev(0, 0, K::EnqInvoke { value: 3 }),
                ev(1, 0, K::EnqOk { value: 3 }),
                ev(2, 0, K::EnqInvoke { value: 3 }),
            ],
            vec![3],
        );
        let r = check(&h, 10);
        assert!(r.violations.contains(&Violation::ValueReused { value: 3 }));
    }
}
