//! Minimal property-testing harness (the `proptest` crate is unavailable
//! offline). Runs a property over many seeded random cases and reports the
//! first failing seed so the case can be replayed exactly. Shrinking is
//! intentionally out of scope — failures carry their generating seed, and
//! generators are expected to produce small cases by construction.

use crate::util::rng::Xoshiro256;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; case `i` uses stream `i`.
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 32, seed: 0x5EED_0BAD_F00D }
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cfg.cases` random cases. Each case gets an independent
/// RNG derived from `(cfg.seed, case_index)`. Panics with the failing seed
/// and message on the first violation.
pub fn forall(cfg: PropConfig, mut prop: impl FnMut(&mut Xoshiro256, u32) -> PropResult) {
    for case in 0..cfg.cases {
        let mut rng = Xoshiro256::split(cfg.seed, case as u64);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property failed at case {case} (replay with seed={:#x}, stream={case}): {msg}",
                cfg.seed
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Xoshiro256;

    /// A vector of length in `[min_len, max_len]` with elements from `f`.
    pub fn vec_of<T>(
        rng: &mut Xoshiro256,
        min_len: usize,
        max_len: usize,
        mut f: impl FnMut(&mut Xoshiro256) -> T,
    ) -> Vec<T> {
        let len = rng.range_inclusive(min_len as u64, max_len as u64) as usize;
        (0..len).map(|_| f(rng)).collect()
    }

    /// Weighted boolean.
    pub fn weighted(rng: &mut Xoshiro256, p_true: f64) -> bool {
        rng.chance(p_true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(PropConfig { cases: 10, seed: 1 }, |_rng, _case| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_panics_with_seed() {
        forall(PropConfig { cases: 10, seed: 1 }, |rng, _case| {
            if rng.next_below(4) == 0 {
                Err("boom".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        forall(PropConfig { cases: 5, seed: 9 }, |rng, _| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        forall(PropConfig { cases: 5, seed: 9 }, |rng, _| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn gen_vec_bounds() {
        let mut rng = Xoshiro256::seed_from(3);
        for _ in 0..50 {
            let v = gen::vec_of(&mut rng, 2, 7, |r| r.next_below(10));
            assert!(v.len() >= 2 && v.len() <= 7);
        }
    }
}
