//! Michael–Scott lock-free queue \[19\] — the classic volatile baseline whose
//! list skeleton LCRQ follows (paper §3). Included for conventional-setting
//! comparisons and as the substrate for the persist-everything
//! [`super::durable_msq`] baseline.
//!
//! Node layout in the arena: `[next][value]` (2 words).

use std::sync::Arc;

use super::{ConcurrentQueue, QueueError, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool};

pub struct MsQueue {
    pool: Arc<PmemPool>,
    head: PAddr,
    tail: PAddr,
}

impl MsQueue {
    pub fn new(pool: &Arc<PmemPool>, _nthreads: usize) -> Self {
        let head = pool.alloc_lines(1);
        let tail = pool.alloc_lines(1);
        pool.set_hot(head, 1, crate::pmem::Hotness::Global);
        pool.set_hot(tail, 1, crate::pmem::Hotness::Global);
        // Sentinel node.
        let sentinel = pool.alloc(2, 2);
        pool.store(0, head, sentinel.to_u64());
        pool.store(0, tail, sentinel.to_u64());
        Self { pool: Arc::clone(pool), head, tail }
    }

    fn next_of(node: PAddr) -> PAddr {
        node
    }

    fn value_of(node: PAddr) -> PAddr {
        node.add(1)
    }

    /// List length excluding the sentinel (test observability).
    pub fn len(&self, tid: usize) -> usize {
        let p = &self.pool;
        let mut n = 0;
        let mut node = PAddr::from_u64(p.load(tid, self.head));
        loop {
            let next = p.load(tid, Self::next_of(node));
            if next == 0 {
                return n;
            }
            n += 1;
            node = PAddr::from_u64(next);
        }
    }
}

impl ConcurrentQueue for MsQueue {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        let node = p.alloc(2, 2);
        p.store(tid, Self::value_of(node), item);
        // next is already 0 (fresh arena).
        loop {
            let l = PAddr::from_u64(p.load(tid, self.tail));
            let next = p.load(tid, Self::next_of(l));
            if l.to_u64() != p.load(tid, self.tail) {
                continue;
            }
            if next == 0 {
                if p.cas(tid, Self::next_of(l), 0, node.to_u64()) {
                    let _ = p.cas(tid, self.tail, l.to_u64(), node.to_u64());
                    return Ok(());
                }
            } else {
                // Help advance the lagging tail.
                let _ = p.cas(tid, self.tail, l.to_u64(), next);
            }
        }
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let p = &self.pool;
        loop {
            let h = PAddr::from_u64(p.load(tid, self.head));
            let t = p.load(tid, self.tail);
            let next = p.load(tid, Self::next_of(h));
            if h.to_u64() != p.load(tid, self.head) {
                continue;
            }
            if h.to_u64() == t {
                if next == 0 {
                    return Ok(None);
                }
                let _ = p.cas(tid, self.tail, t, next);
            } else {
                let v = p.load(tid, Self::value_of(PAddr::from_u64(next)));
                if p.cas(tid, self.head, h.to_u64(), next) {
                    return Ok(Some(v));
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "msq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk() -> MsQueue {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 20).with_cost(CostModel::zero()),
        ));
        MsQueue::new(&pool, 8)
    }

    #[test]
    fn fifo() {
        let q = mk();
        for v in 0..100u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.len(0), 100);
        for v in 0..100u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
        assert_eq!(q.len(0), 0);
    }

    #[test]
    fn empty() {
        let q = mk();
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn mpmc_stress() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let q = Arc::new(mk());
        let total = 4 * 1500u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let q = Arc::clone(&q);
            hs.push(std::thread::spawn(move || {
                for i in 0..1500u64 {
                    q.enqueue(pid, pid as u64 * 10_000 + i).unwrap();
                }
            }));
        }
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(4 + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total);
    }
}
