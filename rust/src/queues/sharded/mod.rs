//! `ShardedQueue` — a K-way striped, optionally batch-persisted FIFO layer
//! over the paper's persistent queues (PerLCRQ by default).
//!
//! The paper's core insight is that persistence cost is governed by *where*
//! the `pwb`+`psync` pair lands: low-contention locations scale, hot spots
//! do not. A single PerLCRQ still funnels every thread through one
//! `Head`/`Tail` FAI pair. This subsystem takes the next step the related
//! work points at (BlockFIFO/MultiFIFO's relaxed sharded designs, and the
//! *Durable Queues: The Second Amendment* batching idea):
//!
//! * **Sharding** — operations stripe across `K = QueueConfig::shards`
//!   inner persistent queues via a per-thread round-robin ticket, dividing
//!   the FAI serialization chains (and the hot `Tail` flush traffic) by
//!   `K`. FIFO becomes *relaxed*: a dequeue may overtake items that sit in
//!   sibling shards, bounded by the shard skew. Histories are checked with
//!   [`crate::verify::check_relaxed`], which accepts at most `k`
//!   out-of-order dequeues per operation.
//! * **Batching (producer side)** — with `QueueConfig::batch = B > 1`,
//!   enqueues run in group-commit mode: each op issues its cell `pwb` but
//!   *defers* the `psync`
//!   ([`crate::queues::crq::PersistCfg::defer_enqueue_sync`]); every
//!   `B`-th enqueue seals the thread's persistent [`batch`] log and issues
//!   **one `psync`** that realizes the whole batch (log lines + all
//!   deferred cell flushes) in a single drain. Amortized persistence:
//!   `1/B` psyncs per enqueue.
//! * **Batching (consumer side)** — with `QueueConfig::batch_deq = K > 1`,
//!   dequeues run in the symmetric group-commit mode: each successful
//!   dequeue issues its `Head_i` `pwb` but defers the `psync`
//!   ([`crate::queues::crq::PersistCfg::defer_dequeue_sync`]) and records
//!   the consumed position in a per-thread persistent *dequeue log*; every
//!   `K`-th dequeue seals the log and issues **one `psync`** realizing the
//!   log lines and every deferred `Head_i` flush together. Amortized:
//!   `1/K` psyncs per dequeue — closing the asymmetry the Second-Amendment
//!   line of work points at (relaxing per-dequeue persistence is where the
//!   remaining cost lives).
//!
//! ## Durability contract under batching
//!
//! A batched enqueue is durably linearized **at the flush**, not at its
//! return ("buffered durable linearizability" — the same contract as group
//! commit in databases). A crash can therefore lose at most the last
//! `B − 1` *unflushed* enqueues of each thread; the checker accounts for
//! exactly that window via `CheckOptions::trailing_loss_per_thread`.
//!
//! Symmetrically, a batched dequeue's *consumption* is durable at its
//! flush: a crash may **redeliver** at most the last `K − 1` returned but
//! unflushed items of each thread (their durable `Head_i` is stale, so the
//! recovered queue still holds them). The checker accounts for exactly
//! that window via `CheckOptions::trailing_redelivery_per_thread`.
//!
//! ## Persistence cost (psyncs per operation)
//!
//! | configuration | enqueue | dequeue |
//! |---|---|---|
//! | per-op (`batch = batch_deq = 1`) | 1 | 1 |
//! | enqueue-batched (`batch = B`) | 1/B | 1 |
//! | both-batched (`batch = B`, `batch_deq = K`) | 1/B | 1/K |
//!
//! On a multi-pool topology the flush issues one `psync` **per pool the
//! batch touched** (each pool drains its own pending flushes): colocated
//! placement keeps a batch on the enqueuer's home socket (1 psync per
//! flush, the table above); interleaved placement can touch every socket
//! (up to `P` psyncs per flush — part of what `benches/fig8_topology`
//! measures).
//!
//! ## NVM topology placement
//!
//! On a multi-pool [`Topology`] the queue maps every shard — and each
//! thread's batch/dequeue logs — onto a pool via
//! [`QueueConfig::placement`] (see [`crate::pmem::PlacementPolicy`]):
//!
//! * `interleave` — shards stripe round-robin across pools; every
//!   thread's RR ticket cycles over **all** shards. Classic striping:
//!   maximum spread, constant cross-socket `pwb` traffic.
//! * `colocate` — same shard→pool stripe, but a thread's enqueue ticket
//!   cycles only over its **home** socket's shards, and its dequeue scan
//!   probes home shards first (then steals from siblings, so no item is
//!   ever stranded). Persistence traffic stays socket-local.
//! * `pinned:<p0,p1,...>` — explicit shard→pool map (`shard s` on
//!   `p[s mod len]`); dispatch behaves like `colocate`.
//!
//! Batch/dequeue logs always live on their thread's home pool. A
//! single-pool topology degenerates every policy to the pre-topology
//! behavior — identical dispatch order, identical histories.
//!
//! ## Crash recovery and batch reconciliation
//!
//! [`ShardedQueue::recover`] re-runs each shard's recovery, then
//! reconciles in-flight batches from the per-thread logs — the dequeue
//! side first, then the enqueue side, because the enqueue verdicts depend
//! on which consumptions are known-durable:
//!
//! **Dequeue logs.** Shard recovery restores each ring's `Head` from the
//! durable `Head_i` copies, which the batch flush realizes together with
//! the log seal; a sealed dequeue-log entry therefore normally finds its
//! position already settled (`Head > idx`). The log is load-bearing in
//! one window: a crash *during* the flush's `psync` realizes each queued
//! line independently, so the sealed log can land while some `Head_i`
//! flush does not. For every valid entry whose item is still durably
//! present at its logged position, recovery re-executes the consumption
//! (clears the cell durably) — the item was returned to a caller
//! pre-crash and must **not** be redelivered. Positions never logged
//! belong to items that may or may not have been returned; they survive
//! (never-returned items must not be lost; returned-but-unlogged ones are
//! the bounded redelivery window above).
//!
//! **Enqueue logs.** For every entry of a sealed log (`item`, shard,
//! node, ring index, seq):
//!
//! * the position appears in a valid **dequeue-log** entry → the item was
//!   returned; never re-insert (without this check, re-executing the
//!   logged consumption above would make the cell look "missing" below
//!   and re-insert a delivered item).
//! * ring `Head > idx` → **settled**: the position was durably consumed
//!   or passed — do not re-insert.
//! * cell at `idx` still holds `item` → **present**: nothing to do.
//! * otherwise → **missing**: the cell flush never landed and no durable
//!   record says the item was returned; it is re-enqueued (it lands at
//!   the tail — a bounded relaxation the relaxed checker absorbs).
//!
//! Logs are retired durably after reconciliation so a later crash cannot
//! replay them; batch sequence numbers stored in every entry detect torn
//! logs (header and entry lines realized independently at a crash).
//!
//! ## Worker threads and slot reuse
//!
//! Per-thread state (round-robin ticket, filling batches) is keyed by
//! `tid`. A worker that dies mid-batch (panic, simulated crash) strands
//! its filling batches; a replacement thread reusing the `tid` would also
//! restart the round-robin ticket at the same phase, skewing shard
//! pressure. [`ShardedQueue::attach_worker`] hands out a RAII
//! [`WorkerSlot`] that (a) flushes anything a dead predecessor left
//! behind, (b) reseeds the ticket from a global counter so reused slots
//! stay spread across shards, and (c) flushes both logs on drop. The same
//! behavior is reachable through `dyn PersistentQueue` via the
//! [`crate::queues::PersistentQueue::attach`] /
//! [`crate::queues::PersistentQueue::detach`] hooks — the broker service
//! calls them around every producer/worker thread's lifetime.
//!
//! ## Elastic re-sharding (versioned shard plans)
//!
//! The stripe set itself is a first-class, crash-recoverable object: the
//! queue dispatches over an epoch-versioned **ShardPlan** (see [`plan`])
//! and [`ShardedQueue::resize`] can grow or shrink `K` **online**, under
//! concurrent enqueuers/dequeuers and async flushers:
//!
//! 1. **Stage** — allocate the new stripes (placed per
//!    [`QueueConfig::placement`], construction charged to the resizing
//!    thread's slot), write the new plan record into the plan log's
//!    spare slot, `psync`.
//! 2. **Freeze** — commit `Freezing(old, new)` with a one-word state
//!    write + `psync`, then flip the volatile plan set: enqueue tickets
//!    stripe over the **new** plan immediately; the old plan is frozen
//!    (no enqueue can ever target it again).
//! 3. **Drain** — dequeues scan the frozen stripes *first* (drain
//!    priority), so normal consumer traffic drains the residue; each
//!    item leaves through an ordinary dequeue with all its existing
//!    durability machinery. Because the frozen side is enqueue-free,
//!    one linearizable EMPTY observation per shard is a permanent
//!    "drained" witness.
//! 4. **Retire** — once every frozen shard is witnessed empty, a single
//!    state-word write + **one `psync`** lands `Active(new)` and the old
//!    plan drops out of the dispatch path.
//!
//! Steady-state cost is untouched outside the transition: the same
//! 1/B + 1/K psyncs per op before, during (plus the drain-priority
//! scans) and after; a resize itself costs `new_K + 3` psyncs (one per
//! fresh stripe, record + freeze + retire).
//!
//! **Plan-access concurrency (epoch pinning).** Hot paths reach the
//! plan pair through an epoch-pinned pointer, not a lock (see
//! [`epoch`]): every enqueue/dequeue pins its own cache-padded slot,
//! reads the published [`plan::PlanSet`] snapshot, and unpins on
//! return — wait-free, no shared lock word, no refcount traffic. A
//! plan flip (freeze, retire, recovery adoption) swaps the pointer and
//! then waits out a **grace period** — volatile-only, zero psyncs —
//! until every pinned reader has passed through a quiescent point. An
//! op pinned across the freeze flip may therefore still enqueue into
//! the now-frozen plan *within the grace window*; `resize` reads the
//! frozen residue and runs retirement verification only after the
//! window closes, which restores the old lock's invariant ("no
//! enqueue lands in a frozen stripe") at the point it is actually
//! consumed. Durability points are unmoved: record/freeze/retire
//! psyncs happen exactly where they did under the lock, so the
//! `new_K + 3` budget and the crash-sweep behavior are unchanged.
//!
//! **Crash recovery.** Batch-log entries are plan-epoch-qualified, so
//! reconciliation resolves every logged position against the plan
//! generation it was recorded under (a volatile plan history keyed by
//! epoch; re-insertions always land in the *current* active plan). A
//! crash mid-transition recovers from the logged plan pair: durably
//! `Freezing` means the new record is durable by construction, so
//! recovery adopts the new plan, recovers and reconciles both
//! generations, drains the frozen residue single-threadedly into the
//! active stripes, and retires the old plan itself — recovery always
//! converges to exactly one plan. Relaxed-FIFO order across the
//! boundary is checked with a cross-plan overtake allowance derived
//! from the frozen-shard residue
//! ([`crate::verify::resharding_relaxation`], fed by
//! [`ShardedQueue::resize_stats`]).

pub mod batch;
pub mod epoch;
pub mod plan;

use std::cell::UnsafeCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crossbeam_utils::CachePadded;

use super::perlcrq::PerLcrq;
use super::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError, MAX_SHARDS};
use crate::obs::{self, ObsSite};
use crate::pmem::{PAddr, PlacementPolicy, PmemPool, Topology};

use self::batch::BatchLog;
use self::epoch::{EpochRegistry, PlanCell};
use self::plan::{Plan, PlanLog, PlanSet, PlanState};
pub use self::plan::ResizeStats;

/// Where a traced enqueue landed: the LCRQ node and the ring index within
/// it. Stable across crashes (node addresses are arena offsets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnqPos {
    pub node: PAddr,
    pub idx: u64,
}

/// Reconciliation verdict for a logged batch entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Probe {
    /// The position was durably consumed or passed — do not re-insert.
    Settled,
    /// The item is still durably present at its logged position.
    Present,
    /// The item is gone and provably was never returned to a caller:
    /// re-insertion is safe.
    Missing,
}

/// An inner queue the sharded layer can stripe over: a persistent queue
/// that can additionally report *where* an enqueue landed and answer
/// recovery probes about logged positions.
pub trait Shardable: PersistentQueue {
    /// Enqueue and report the landing position.
    fn enqueue_traced(&self, tid: usize, item: u64) -> Result<EnqPos, QueueError>;

    /// Dequeue and report the position the item came from (for the
    /// consumer-side dequeue log).
    fn dequeue_traced(&self, tid: usize) -> Result<Option<(u64, EnqPos)>, QueueError>;

    /// Post-crash, post-recovery: classify a logged `(pos, item)` pair.
    /// Single-threaded (recovery context).
    fn probe(&self, tid: usize, pos: &EnqPos, item: u64) -> Probe;

    /// Post-crash, post-recovery: re-execute a logged consumption. If the
    /// item is still durably present at `pos` (the recovered queue would
    /// redeliver it even though it was returned pre-crash), clear the cell
    /// exactly as its dequeue transition did and request write-back; the
    /// caller issues the final `psync`. Returns whether a cell was
    /// cleared. Single-threaded (recovery context).
    fn retire(&self, tid: usize, pos: &EnqPos, item: u64) -> bool;

    /// Cheap, non-linearizable emptiness hint used by the dequeue scan to
    /// skip shards that currently look empty. Must never report `false`
    /// while an item whose enqueue completed before the call started is
    /// still in the queue (reads of live state satisfy this). Defaults to
    /// "always probe".
    fn maybe_nonempty(&self, _tid: usize) -> bool {
        true
    }

    /// Occupancy estimate with the same one-sided soundness contract as
    /// [`Shardable::maybe_nonempty`]: must never report `0` while an item
    /// whose enqueue completed before the call started is still in the
    /// queue. Overcounting is allowed (it only delays plan retirement) —
    /// the value is strictly an **upper bound** on occupancy, never an
    /// exact count, and every surface that reports it (the `audit`
    /// draining residue, `resize` residue columns, the broker's
    /// `persiq_broker_queue_depth` gauge) must label it as such.
    /// Used to verify a frozen stripe is empty before the old plan is
    /// durably retired, and to size the checker's cross-plan overtake
    /// allowance. Defaults to the binary hint.
    fn len_hint(&self, tid: usize) -> u64 {
        if self.maybe_nonempty(tid) {
            1
        } else {
            0
        }
    }

    /// Hand every pmem segment this queue owns back to the pool's
    /// allocator tier. Called exactly once, after the queue is durably
    /// unreachable (its plan generation was durably retired and pruned
    /// from the recovery history) and quiescent (the plan-set grace
    /// period elapsed, so no reader can still hold it). Defaults to the
    /// historical leak-by-design no-op.
    fn reclaim_pmem(&self, _tid: usize) {}
}

impl Shardable for PerLcrq {
    fn enqueue_traced(&self, tid: usize, item: u64) -> Result<EnqPos, QueueError> {
        let (node, idx) = self.core().enqueue_at(tid, item)?;
        Ok(EnqPos { node, idx })
    }

    fn dequeue_traced(&self, tid: usize) -> Result<Option<(u64, EnqPos)>, QueueError> {
        Ok(self
            .core()
            .dequeue_at(tid)
            .map(|(v, node, idx)| (v, EnqPos { node, idx })))
    }

    fn retire(&self, tid: usize, pos: &EnqPos, item: u64) -> bool {
        let core = self.core();
        if core.node_settled(pos.node) {
            // The durable `First` had advanced past this node at crash
            // time (it is off the recovered chain), so the recovered
            // queue can never redeliver from it — and with recycling on
            // its memory may already be scrubbed or reused. Nothing to
            // clear; do not read it.
            return false;
        }
        let pool = &core.pool;
        let ring = core.ring_of(pos.node);
        let (head, _tail) = ring.endpoints(pool, tid);
        if head > pos.idx {
            return false; // already settled by the recovered Head
        }
        let r = ring.ring_size as u64;
        let u = pos.idx % r;
        let (uns, idx, val) = ring.read_cell(pool, tid, u);
        if idx != pos.idx || val != item + 1 {
            return false; // cell moved on / item not there — nothing to do
        }
        // The dequeue transition the pre-crash consumer already performed:
        // (s, idx, v) → (s, idx + R, ⊥), preserving the safe/unsafe bit
        // exactly as the live transition does (ring recovery has already
        // cleared unsafe flags, so `uns` is false here in practice — kept
        // for fidelity). Request write-back so a repeat crash cannot
        // resurrect the value; the caller psyncs once.
        ring.write_cell(pool, tid, u, uns, pos.idx + r, crate::queues::crq::BOT);
        pool.pwb(tid, ring.cell_addr(u));
        true
    }

    fn probe(&self, tid: usize, pos: &EnqPos, item: u64) -> Probe {
        let core = self.core();
        if core.node_settled(pos.node) {
            // Off the durable chain: the durable `First` advanced past
            // the node, which only happens after every cell in its ring
            // was consumed — the logged position was returned pre-crash.
            // With recycling on the node may be scrubbed or reused, so
            // answer from chain membership instead of reading it.
            return Probe::Settled;
        }
        let pool = &core.pool;
        let ring = core.ring_of(pos.node);
        let (head, _tail) = ring.endpoints(pool, tid);
        if head > pos.idx {
            // A dequeue returns only after its persist_head pair, so a
            // durable Head past idx means the position is accounted for.
            return Probe::Settled;
        }
        let u = pos.idx % ring.ring_size as u64;
        let (_uns, idx, val) = ring.read_cell(pool, tid, u);
        if idx == pos.idx && val == item + 1 {
            Probe::Present
        } else {
            Probe::Missing
        }
    }

    fn maybe_nonempty(&self, tid: usize) -> bool {
        let core = self.core();
        // Pin against node recycling: `first` must stay readable.
        let _pin = core.pin_walk(tid);
        let pool = &core.pool;
        let first = PAddr::from_u64(pool.load(tid, core.first));
        if first.is_null() {
            return true; // defensive: always probe
        }
        let (head, tail) = core.ring_of(first).endpoints(pool, tid);
        // Items in the first ring, or a successor node (next ptr at node+0).
        tail > head || pool.load(tid, first) != 0
    }

    fn len_hint(&self, tid: usize) -> u64 {
        // Walk the node chain summing ring occupancy (tail is read with
        // the closed bit masked). Sound for the retire gate: an enqueue's
        // cell write precedes its Tail FAI becoming visible... the FAI
        // itself publishes the slot, and any completed enqueue has
        // executed it, so a completed item is always inside some ring's
        // [Head, Tail) window. Bounded walk for defensiveness.
        let core = self.core();
        // Pin against node recycling: without it a concurrently-retired
        // node could be scrubbed mid-walk, truncating the chain and
        // undercounting — which the one-sided contract forbids.
        let _pin = core.pin_walk(tid);
        let pool = &core.pool;
        let mut node = PAddr::from_u64(pool.load(tid, core.first));
        let mut sum = 0u64;
        let mut hops = 0u32;
        while !node.is_null() && hops < 1 << 20 {
            let (head, tail) = core.ring_of(node).endpoints(pool, tid);
            sum += tail.saturating_sub(head);
            node = PAddr::from_u64(pool.load(tid, node));
            hops += 1;
        }
        sum
    }

    fn reclaim_pmem(&self, tid: usize) {
        self.core().reclaim_pmem(tid);
    }
}

/// Per-thread volatile dispatch state. Slot `tid` is touched only by the
/// thread running as `tid` while workers are live, and by the single
/// coordinator thread (recovery, `flush_all`) after all workers have
/// stopped — the same exclusive-logical-owner pattern as the pool's
/// pending-flush slots.
#[derive(Default)]
struct SlotState {
    /// Round-robin enqueue ticket (indexes the thread's enqueue order).
    ticket: u64,
    /// Dequeue scan start (position in the thread's scan order).
    cursor: usize,
    /// Entries recorded in the filling enqueue batch.
    pending: usize,
    /// Current enqueue-batch sequence number (starts at 1; 0 = never
    /// sealed).
    seq: u64,
    /// Entries recorded in the filling dequeue batch.
    deq_pending: usize,
    /// Current dequeue-batch sequence number (starts at 1).
    deq_seq: u64,
    /// Bitmask of pools touched by the filling enqueue batch's cell
    /// `pwb`s — the flush must `psync` each of them.
    enq_pools: u64,
    /// Bitmask of pools touched by the filling dequeue batch's `Head_i`
    /// `pwb`s.
    deq_pools: u64,
}

struct Slot(UnsafeCell<SlotState>);

unsafe impl Sync for Slot {}

/// Volatile resize counters (see [`ResizeStats`]). Each counter sits on
/// its own cache line: `drained_from_frozen` is `fetch_add`ed by **every
/// dequeuer** while a frozen plan drains, and an unpadded block would
/// put that RMW traffic on the same line as the read-mostly gauges (the
/// same false-sharing audit that padded `AsyncStats` — the per-thread
/// pmem `OpCounters` were already isolated, see `pmem/stats.rs`).
#[derive(Default)]
struct ResizeCells {
    flips: CachePadded<AtomicU64>,
    retires: CachePadded<AtomicU64>,
    residue_total: CachePadded<AtomicU64>,
    last_residue: CachePadded<AtomicU64>,
    drained_from_frozen: CachePadded<AtomicU64>,
}

/// The sharded (and optionally batched) persistent queue. See module docs.
pub struct ShardedQueue<Q: Shardable = PerLcrq> {
    topo: Topology,
    /// The epoch-versioned plan pair the hot paths dispatch over: the
    /// active plan (enqueue target) plus, mid-transition, the frozen old
    /// plan still being drained. Published as an immutable snapshot
    /// behind an epoch-pinned pointer (see [`epoch`]): readers pin their
    /// own cache-padded slot for the duration of an operation — no
    /// shared lock word, no refcount traffic — and a plan flip swaps the
    /// pointer, then waits out a grace period before the displaced
    /// snapshot is freed or its frozen side trusted drained. The old
    /// "no enqueue lands in a frozen stripe after the flip" lock
    /// invariant is relaxed to "…after the flip's grace period":
    /// `resize` reads residue and verifies retirement only post-grace.
    plans: PlanCell<PlanSet<Q>>,
    /// Per-thread pin slots guarding [`ShardedQueue::plans`].
    epochs: EpochRegistry,
    /// Every plan generation created since the last recovery, by epoch:
    /// batch-log reconciliation resolves epoch-qualified entries against
    /// retired generations too (their sealed logs outlive retirement).
    history: Mutex<HashMap<u64, Arc<Plan<Q>>>>,
    /// The persistent plan log (primary pool) — the re-sharding state
    /// machine's durable root.
    plan_log: PlanLog,
    /// Serializes resize/retire transitions (single logical writer of the
    /// plan log).
    resize_lock: Mutex<()>,
    /// Cheap lock-free copy of the active plan's epoch.
    epoch_hint: AtomicU64,
    /// Which plan-log record slot holds the active (or, mid-freeze, the
    /// incoming) plan.
    cur_slot: AtomicUsize,
    /// Factory for fresh stripes: `(topo, pool, tid) -> shard`. `None`
    /// for queues built from caller-provided shards — those cannot
    /// re-shard.
    #[allow(clippy::type_complexity)]
    shard_ctor: Option<Box<dyn Fn(&Topology, usize, usize) -> Q + Send + Sync>>,
    /// Placement policy new plans are laid out with.
    placement: PlacementPolicy,
    batch: usize,
    batch_deq: usize,
    nthreads: usize,
    slots: Vec<CachePadded<Slot>>,
    /// Per-thread persistent enqueue batch logs (empty when `batch == 1`),
    /// each allocated on its thread's home pool (`log_pool`).
    logs: Vec<BatchLog>,
    /// Per-thread persistent dequeue logs (empty when `batch_deq == 1`),
    /// on the same home pool.
    deq_logs: Vec<BatchLog>,
    /// Pool holding thread `tid`'s batch + dequeue logs.
    log_pool: Vec<usize>,
    /// Monotone seed for [`ShardedQueue::attach_worker`] ticket reseeding,
    /// so reused thread slots keep spreading across shards.
    ticket_seed: AtomicU64,
    rstats: ResizeCells,
    name: &'static str,
}

impl ShardedQueue<PerLcrq> {
    /// The default construction: `cfg.shards` PerLCRQ shards placed onto
    /// the topology's pools per `cfg.placement`, batched when
    /// `cfg.batch > 1`. Fails with [`QueueError::BadConfig`] on zero
    /// shards/batch, an out-of-range pinned pool id (and the other
    /// `QueueConfig::validate` rules) instead of panicking.
    pub fn new_perlcrq(
        topo: &Topology,
        nthreads: usize,
        cfg: QueueConfig,
    ) -> Result<Self, QueueError> {
        cfg.validate()?;
        let mut shard_cfg = cfg.clone();
        // Batched modes defer the per-op psync to the flush; plain
        // sharding keeps the paper's per-op pair on both sides.
        shard_cfg.defer_enqueue_sync = cfg.batch > 1;
        shard_cfg.defer_dequeue_sync = cfg.batch_deq > 1;
        let shard_pool: Vec<usize> =
            (0..cfg.shards).map(|s| cfg.placement.pool_of(s, topo.len())).collect();
        // Range-check BEFORE dereferencing pools: a pinned id outside the
        // topology must surface as BadConfig, not an index panic
        // (from_shards re-checks for its own direct callers).
        if shard_pool.iter().any(|&p| p >= topo.len()) {
            return Err(QueueError::BadConfig(
                "placement names a pool outside the topology (check pinned ids vs --pools)",
            ));
        }
        let shards: Vec<PerLcrq> = {
            // Stripe-root psyncs during construction are Setup traffic,
            // not steady-state per-op cost.
            let _site = obs::enter_site(ObsSite::Setup);
            shard_pool
                .iter()
                .map(|&p| PerLcrq::new(topo.pool(p), nthreads, shard_cfg.clone()))
                .collect()
        };
        // The stripe factory resize uses to grow fresh plans: identical
        // configuration, constructed on the resizing thread's slot.
        let ctor = Box::new(move |t: &Topology, pool: usize, tid: usize| {
            PerLcrq::new_at(t.pool(pool), nthreads, shard_cfg.clone(), tid)
        });
        Self::build(topo, nthreads, &cfg, shards, shard_pool, Some(ctor), "sharded-perlcrq")
    }
}

impl<Q: Shardable> ShardedQueue<Q> {
    /// Generic construction over caller-built shards. The shards must
    /// already be configured consistently with `cfg` (in particular,
    /// `defer_enqueue_sync` iff `cfg.batch > 1` and `defer_dequeue_sync`
    /// iff `cfg.batch_deq > 1`) and built on the pools named by
    /// `shard_pool` (shard `s` on `topo.pool(shard_pool[s])`).
    pub fn from_shards(
        topo: &Topology,
        nthreads: usize,
        cfg: &QueueConfig,
        shards: Vec<Q>,
        shard_pool: Vec<usize>,
        name: &'static str,
    ) -> Result<Self, QueueError> {
        Self::build(topo, nthreads, cfg, shards, shard_pool, None, name)
    }

    /// Shared construction tail: installs plan epoch 1 over the given
    /// shards, durably initializes the plan log (record + `Active` state,
    /// two psyncs — construction is a quiescent, thread-0 context) and
    /// wires the optional stripe factory [`ShardedQueue::resize`] needs.
    #[allow(clippy::type_complexity)]
    fn build(
        topo: &Topology,
        nthreads: usize,
        cfg: &QueueConfig,
        shards: Vec<Q>,
        shard_pool: Vec<usize>,
        shard_ctor: Option<Box<dyn Fn(&Topology, usize, usize) -> Q + Send + Sync>>,
        name: &'static str,
    ) -> Result<Self, QueueError> {
        cfg.validate()?;
        if shards.is_empty() {
            return Err(QueueError::BadConfig("at least one shard is required"));
        }
        if shard_pool.len() != shards.len() {
            return Err(QueueError::BadConfig("shard_pool must name a pool per shard"));
        }
        if shard_pool.iter().any(|&p| p >= topo.len()) {
            return Err(QueueError::BadConfig(
                "placement names a pool outside the topology (check pinned ids vs --pools)",
            ));
        }
        // Everything below (log allocation, plan-log record + Active
        // commit) is construction-time persistence: attribute it to the
        // Setup site so the steady-state ledger starts clean.
        let _site = obs::enter_site(ObsSite::Setup);
        let log_pool: Vec<usize> = (0..nthreads).map(|t| topo.home_pool(t)).collect();
        let logs = if cfg.batch > 1 {
            (0..nthreads).map(|t| BatchLog::alloc(topo.pool(log_pool[t]), cfg.batch)).collect()
        } else {
            Vec::new()
        };
        let deq_logs = if cfg.batch_deq > 1 {
            (0..nthreads)
                .map(|t| BatchLog::alloc(topo.pool(log_pool[t]), cfg.batch_deq))
                .collect()
        } else {
            Vec::new()
        };
        let initial = Arc::new(Plan::new(
            1,
            shards,
            shard_pool,
            topo.len(),
            cfg.placement.prefers_home(),
        ));
        // Durably root the initial plan before any operation can run:
        // recovery always finds a decodable Active state.
        let plan_log = PlanLog::alloc(topo.primary());
        plan_log.write_record(topo.primary(), 0, 0, 1, &initial.shard_pool);
        topo.primary().psync(0);
        plan_log.set_active(topo.primary(), 0, 0, 1);
        topo.primary().psync(0);
        let mut history = HashMap::new();
        history.insert(1, Arc::clone(&initial));
        Ok(Self {
            topo: topo.clone(),
            plans: PlanCell::new(Arc::new(PlanSet { active: initial, draining: None })),
            epochs: EpochRegistry::new(nthreads),
            history: Mutex::new(history),
            plan_log,
            resize_lock: Mutex::new(()),
            epoch_hint: AtomicU64::new(1),
            cur_slot: AtomicUsize::new(0),
            shard_ctor,
            placement: cfg.placement.clone(),
            batch: cfg.batch,
            batch_deq: cfg.batch_deq,
            nthreads,
            slots: (0..nthreads)
                .map(|_| {
                    CachePadded::new(Slot(UnsafeCell::new(SlotState {
                        seq: 1,
                        deq_seq: 1,
                        ..Default::default()
                    })))
                })
                .collect(),
            logs,
            deq_logs,
            log_pool,
            ticket_seed: AtomicU64::new(nthreads as u64),
            rstats: ResizeCells::default(),
            name,
        })
    }

    /// The active plan (test/reconciliation observability). Cold path,
    /// no `tid`: serializes against plan flips via the resize lock
    /// instead of pinning (a flip is impossible while the guard is
    /// held, so the owner-side snapshot clone is safe).
    pub(crate) fn active_plan(&self) -> Arc<Plan<Q>> {
        let _g = self.resize_guard();
        Arc::clone(&self.plans.load_owner().active)
    }

    /// Number of shards in the **active** plan.
    pub fn shard_count(&self) -> usize {
        self.active_plan().shards.len()
    }

    /// The pool (socket) the active plan's shard `s` lives on.
    pub fn shard_pool_of(&self, s: usize) -> usize {
        self.active_plan().shard_pool[s]
    }

    /// The active plan's epoch (1 = the construction-time plan; each
    /// committed [`ShardedQueue::resize`] increments it).
    pub fn plan_epoch(&self) -> u64 {
        self.epoch_hint.load(Ordering::Acquire)
    }

    /// Mid-transition observability: `(epoch, shard_count, residue)` of
    /// the frozen plan still draining, or `None` when the queue has
    /// exactly one plan. `residue` is a [`Shardable::len_hint`] sum —
    /// an overestimate at worst, never an undercount.
    pub fn draining_info(&self, tid: usize) -> Option<(u64, usize, u64)> {
        let set = self.epochs.pin(&self.plans, tid);
        set.draining.as_ref().map(|d| {
            (d.epoch, d.shards.len(), d.shards.iter().map(|s| s.len_hint(tid)).sum())
        })
    }

    /// Occupancy estimate across the active plan's stripes plus any
    /// draining residue (a [`Shardable::len_hint`] sum — an overestimate
    /// at worst). Metrics-collector use; walks every stripe.
    pub fn depth_hint(&self, tid: usize) -> u64 {
        let set = self.epochs.pin(&self.plans, tid);
        let live: u64 = set.active.shards.iter().map(|s| s.len_hint(tid)).sum();
        let frozen: u64 = set
            .draining
            .as_ref()
            .map(|d| d.shards.iter().map(|s| s.len_hint(tid)).sum())
            .unwrap_or(0);
        live + frozen
    }

    /// Resize counters (flips, retirements, frozen residue) — the input
    /// to [`crate::verify::resharding_relaxation`].
    pub fn resize_stats(&self) -> ResizeStats {
        ResizeStats {
            flips: self.rstats.flips.load(Ordering::Relaxed),
            retires: self.rstats.retires.load(Ordering::Relaxed),
            residue_total: self.rstats.residue_total.load(Ordering::Relaxed),
            last_residue: self.rstats.last_residue.load(Ordering::Relaxed),
            drained_from_frozen: self.rstats.drained_from_frozen.load(Ordering::Relaxed),
        }
    }

    /// Registry-style metric families for this queue: resize counters,
    /// plan-state gauges, and — mid-transition — the per-plan-epoch drain
    /// residue still held by the frozen plan. `tid` is the calling
    /// thread's slot (residue probing reads shard state).
    pub fn metric_families(&self, tid: usize) -> Vec<obs::Family> {
        use obs::{Family, Kind, Sample};
        let rs = self.resize_stats();
        let counter = |name: &str, help: &str, v: u64| {
            Family::scalar(name, help, Kind::Counter, vec![Sample::plain(v as f64)])
        };
        let gauge = |name: &str, help: &str, v: f64| {
            Family::scalar(name, help, Kind::Gauge, vec![Sample::plain(v)])
        };
        let mut out = vec![
            counter(
                "persiq_sharded_resize_flips_total",
                "Committed re-shard plan flips",
                rs.flips,
            ),
            counter(
                "persiq_sharded_resize_retires_total",
                "Frozen plans durably retired",
                rs.retires,
            ),
            counter(
                "persiq_sharded_resize_residue_total",
                "Items left in frozen plans at flip time (cumulative)",
                rs.residue_total,
            ),
            counter(
                "persiq_sharded_resize_drained_total",
                "Items drained out of frozen plans (dequeues + recovery moves)",
                rs.drained_from_frozen,
            ),
            gauge(
                "persiq_sharded_resize_last_residue",
                "Items the most recent flip left in its frozen plan",
                rs.last_residue as f64,
            ),
            gauge(
                "persiq_sharded_plan_epoch",
                "Active plan epoch (1 = construction-time plan)",
                self.plan_epoch() as f64,
            ),
            gauge("persiq_sharded_shards", "Stripes in the active plan", self.shard_count() as f64),
            // Epoch-pinned plan access (see [`epoch`]): hot-path pin
            // traffic plus the cold writer-side flip/grace counters (the
            // per-wait distribution is the registry histogram
            // `persiq_epoch_grace_wait_rounds`).
            counter(
                "persiq_epoch_pins_total",
                "Outermost plan pins taken (one per queue operation)",
                self.epochs.pins_total(),
            ),
            counter(
                "persiq_epoch_unpins_total",
                "Completed plan unpins (pins minus currently-live pins)",
                self.epochs.unpins_total(),
            ),
            counter(
                "persiq_epoch_plan_flips_total",
                "Plan-pointer flips published through the epoch cell",
                self.epochs.flips_total(),
            ),
            counter(
                "persiq_epoch_grace_spins_total",
                "Cumulative spin rounds plan writers burned waiting out grace periods",
                self.epochs.grace_spins_total(),
            ),
        ];
        // Per-plan-epoch drain residue: a labelled sample only while a
        // frozen plan is draining (empty family otherwise).
        let residue = match self.draining_info(tid) {
            Some((epoch, _, residue)) => vec![Sample::labelled("epoch", epoch, residue as f64)],
            None => Vec::new(),
        };
        out.push(Family::scalar(
            "persiq_sharded_draining_residue",
            "Items still held by the frozen (draining) plan, by its epoch",
            Kind::Gauge,
            residue,
        ));
        out
    }

    /// Configured enqueue batch size (1 = per-op persistence).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Configured dequeue batch size (1 = per-op persistence).
    pub fn batch_deq_size(&self) -> usize {
        self.batch_deq
    }

    /// Claim thread slot `tid` for a worker: flushes any batches a dead
    /// predecessor stranded in the slot and reseeds the round-robin
    /// ticket from a global counter (so a replacement worker does not
    /// restart at shard 0 and skew pressure). The returned guard flushes
    /// both logs when dropped — including on unwind, so a panicking
    /// worker cannot strand its filling batches. The usual `tid`
    /// exclusivity contract applies: one live owner per slot.
    pub fn attach_worker(&self, tid: usize) -> WorkerSlot<'_, Q> {
        PersistentQueue::attach(self, tid);
        WorkerSlot { q: self, tid }
    }

    #[allow(clippy::mut_from_ref)]
    fn slot(&self, tid: usize) -> &mut SlotState {
        // SAFETY: exclusive-logical-owner — see SlotState docs.
        unsafe { &mut *self.slots[tid].0.get() }
    }

    /// Thread `tid`'s home pool within this queue's topology.
    #[inline]
    fn home(&self, tid: usize) -> usize {
        self.topo.home_pool(tid)
    }

    fn enqueue_impl(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        // Pin (own cache line, no shared RMW) for the whole operation: a
        // plan flip swaps the pointer immediately but waits out this pin
        // before trusting the frozen side — an enqueue through a stale
        // pin lands in the frozen plan *within the flip's grace period*,
        // and `resize` reads residue / verifies retirement only after it.
        let set = self.epochs.pin(&self.plans, tid);
        let plan = &set.active;
        let slot = self.slot(tid);
        let order = &plan.enq_orders[self.home(tid)];
        let shard = order[(slot.ticket % order.len() as u64) as usize];
        slot.ticket += 1;
        if self.batch <= 1 {
            return plan.shards[shard].enqueue(tid, item);
        }
        let pos = plan.shards[shard].enqueue_traced(tid, item)?;
        slot.enq_pools |= 1 << plan.shard_pool[shard];
        let i = slot.pending;
        let lp = self.log_pool[tid];
        self.logs[tid]
            .record(self.topo.pool(lp), tid, i, item, plan.epoch, shard, &pos, slot.seq);
        // Advisory flight event (plain stores): becomes durable with the
        // batch seal's psync, which certifies it.
        obs::flight::record_advisory(self.topo.pool(lp), tid, obs::flight::FlightKind::OpEnq, item);
        slot.pending = i + 1;
        if slot.pending >= self.batch {
            self.flush(tid);
        }
        Ok(())
    }

    /// Flush thread `tid`'s filling batches (enqueue and dequeue sides):
    /// seal whichever logs have pending entries and issue **one** `psync`
    /// per pool the batches touched, draining the log lines plus every
    /// deferred cell / `Head_i` `pwb`. Colocated placement keeps a batch
    /// on one pool (exactly one `psync`); interleaved batches may span
    /// pools. No-op when nothing is pending or batching is off.
    ///
    /// Returns the bitmask of pools actually `psync`ed (0 when nothing was
    /// pending). The async completion layer uses this to know which pools'
    /// pending `pwb`s of `tid` were drained alongside the batch — a
    /// `psync` realizes **all** of the calling thread's queued flushes in
    /// that pool, not just the queue's own lines.
    pub fn flush(&self, tid: usize) -> u64 {
        let slot = self.slot(tid);
        let lp = self.log_pool[tid];
        let mut pools_mask = 0u64;
        let mut enq_sealed = 0usize;
        let mut deq_sealed = 0usize;
        if self.batch > 1 && slot.pending > 0 {
            self.logs[tid].seal(self.topo.pool(lp), tid, slot.pending, slot.seq);
            enq_sealed = slot.pending;
            slot.pending = 0;
            slot.seq += 1;
            pools_mask |= slot.enq_pools | (1 << lp);
            slot.enq_pools = 0;
        }
        if self.batch_deq > 1 && slot.deq_pending > 0 {
            self.deq_logs[tid].seal(self.topo.pool(lp), tid, slot.deq_pending, slot.deq_seq);
            deq_sealed = slot.deq_pending;
            slot.deq_pending = 0;
            slot.deq_seq += 1;
            pools_mask |= slot.deq_pools | (1 << lp);
            slot.deq_pools = 0;
        }
        if pools_mask != 0 {
            // Attribute the group-commit psyncs: a flush realizing an
            // enqueue batch is the 1/B stream (BatchFlush) even when a
            // dequeue log rides along; a pure dequeue-log seal is the
            // 1/K stream (DeqFlush). The site ledger separates the two
            // so `tests/obs_ledger` can assert each bound independently.
            // An explicit ambient scope (recovery's forward-drain runs
            // flushes under Recovery) wins — those psyncs are transition
            // cost, not steady-state amortization.
            let ambient = obs::current_site();
            let site = if ambient != ObsSite::Op {
                ambient
            } else if enq_sealed > 0 {
                ObsSite::BatchFlush
            } else {
                ObsSite::DeqFlush
            };
            let _site = obs::enter_site(site);
            // Queue the flight ring's advisory backlog (this batch's
            // OpEnq/OpDeq events) behind the seal psync below — the
            // recorder's zero-extra-psync piggyback.
            obs::flight::presync(self.topo.pool(lp), tid);
            for p in 0..self.topo.len() {
                if pools_mask & (1 << p) != 0 {
                    self.topo.pool(p).psync(tid);
                }
            }
            // The seal psync has retired: record the certified seal
            // events (write-after-psync — their durability alone proves
            // the batch durable, and they certify the advisory prefix).
            if enq_sealed > 0 {
                obs::flight::record_sealed(
                    self.topo.pool(lp),
                    tid,
                    obs::flight::FlightKind::BatchSeal,
                    enq_sealed as u64,
                );
            }
            if deq_sealed > 0 {
                obs::flight::record_sealed(
                    self.topo.pool(lp),
                    tid,
                    obs::flight::FlightKind::DeqSeal,
                    deq_sealed as u64,
                );
            }
            if obs::trace::enabled() {
                let now = self.topo.vtime(tid);
                if enq_sealed > 0 {
                    obs::trace::batch_seal(tid, now, "enq", enq_sealed, pools_mask);
                }
                if deq_sealed > 0 {
                    obs::trace::batch_seal(tid, now, "deq", deq_sealed, pools_mask);
                }
            }
        }
        pools_mask
    }

    /// Thread `tid`'s unflushed op counts: `(enqueues, dequeues)` recorded
    /// in the filling batches since the last flush. Both zero means every
    /// operation `tid` has executed on this queue is durably realized
    /// (each recorded op either sits pending or was sealed + `psync`ed by
    /// a completed flush). The async completion layer's wake rule is built
    /// on exactly this: a flush that unwinds mid-`psync` (simulated crash)
    /// never returns to the caller, so "`flush`/`enqueue`/`dequeue`
    /// returned normally and the counts read zero" certifies durability.
    pub fn pending_ops(&self, tid: usize) -> (usize, usize) {
        let slot = self.slot(tid);
        (slot.pending, slot.deq_pending)
    }

    /// The topology this queue places its shards and logs on.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Flush every thread's pending batch. **Quiescent contexts only**
    /// (all workers stopped): the caller acts as each thread in turn, the
    /// same contract as [`PmemPool::crash`]. Used before a final drain.
    pub fn flush_all(&self) {
        for t in 0..self.nthreads {
            self.flush(t);
        }
    }

    fn dequeue_impl(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let (result, retire_candidate) = {
            // Pin scoped to the scans only: it MUST drop before
            // `try_retire` below, whose retirement flip waits out a
            // grace period — waiting on this thread's own pin would
            // self-deadlock.
            let set = self.epochs.pin(&self.plans, tid);
            let mut retire = false;
            let mut res = None;
            // Drain priority: frozen stripes are scanned first, so
            // consumer traffic empties the old plan before touching new
            // items — the transition's residue leaves through ordinary
            // dequeues with all their durability machinery.
            if let Some(dr) = &set.draining {
                res = self.dequeue_scan(tid, dr, true)?;
                if res.is_some() {
                    self.rstats.drained_from_frozen.fetch_add(1, Ordering::Relaxed);
                } else {
                    retire = dr.all_drained();
                }
            }
            if res.is_none() {
                res = self.dequeue_scan(tid, &set.active, false)?;
            }
            (res, retire)
        };
        if retire_candidate {
            // Every frozen stripe has an emptiness witness: attempt the
            // one-psync retirement (idempotent, serialized, re-verified).
            self.try_retire(tid);
        }
        Ok(result)
    }

    /// One scan over `plan`'s stripes. `frozen` scans skip stripes that
    /// already have an emptiness witness and record new witnesses (sound
    /// post-freeze: no enqueue can target the plan, so emptiness is
    /// monotone); live scans use the thread's rotating cursor.
    fn dequeue_scan(
        &self,
        tid: usize,
        plan: &Arc<Plan<Q>>,
        frozen: bool,
    ) -> Result<Option<u64>, QueueError> {
        let slot = self.slot(tid);
        let order = &plan.deq_orders[self.home(tid)];
        let n = order.len();
        let start = if frozen { 0 } else { slot.cursor % n };
        for i in 0..n {
            let pos_in_order = (start + i) % n;
            let s = order[pos_in_order];
            if frozen && plan.drained[s].load(Ordering::Relaxed) {
                continue;
            }
            if !plan.shards[s].maybe_nonempty(tid) {
                if frozen {
                    plan.drained[s].store(true, Ordering::Relaxed);
                }
                continue;
            }
            let got = if self.batch_deq <= 1 {
                plan.shards[s].dequeue(tid)?
            } else if let Some((v, pos)) = plan.shards[s].dequeue_traced(tid)? {
                slot.deq_pools |= 1 << plan.shard_pool[s];
                let i = slot.deq_pending;
                let lp = self.log_pool[tid];
                self.deq_logs[tid]
                    .record(self.topo.pool(lp), tid, i, v, plan.epoch, s, &pos, slot.deq_seq);
                // Advisory flight event; certified by the deq seal psync.
                obs::flight::record_advisory(
                    self.topo.pool(lp),
                    tid,
                    obs::flight::FlightKind::OpDeq,
                    v,
                );
                slot.deq_pending = i + 1;
                if slot.deq_pending >= self.batch_deq {
                    self.flush(tid);
                }
                Some(v)
            } else {
                None
            };
            match got {
                Some(v) => {
                    if !frozen {
                        slot.cursor = (pos_in_order + 1) % n;
                    }
                    return Ok(Some(v));
                }
                None if frozen => plan.drained[s].store(true, Ordering::Relaxed),
                None => {}
            }
        }
        Ok(None)
    }

    /// Re-shard **online** to `new_k` stripes: stage + durably record the
    /// new plan, commit `Freezing` with one psync, and flip the volatile
    /// plan set so enqueue tickets stripe over the new stripes
    /// immediately. Returns the new plan epoch. The frozen old plan
    /// drains through drain-priority dequeue scans and is retired (one
    /// psync) by whichever dequeuer witnesses it empty —
    /// [`ShardedQueue::try_retire`] — or by crash recovery. Safe under
    /// concurrent enqueuers/dequeuers/flushers; `tid` is the calling
    /// thread's exclusive slot (construction of the new stripes and the
    /// transition psyncs are charged to it).
    ///
    /// Progress: concurrent ops are never blocked by a resize — they
    /// pin, dispatch, and unpin wait-free throughout. The resize itself
    /// waits out a bounded-spin grace period after the flip (until
    /// every op that pinned the pre-flip plan set returns), so it
    /// completes as soon as in-flight ops do; only a reader stalled
    /// *inside* an operation can delay it, and it delays only the
    /// resize, never other traffic.
    ///
    /// Cost: `new_k + 3` psyncs for the whole transition (one per fresh
    /// stripe, record + freeze + retire); steady-state psyncs/op are
    /// untouched outside it.
    ///
    /// Errors: `BadConfig` for an out-of-range `new_k`, a queue built
    /// from caller-provided shards (no stripe factory), or when a
    /// previous transition is still draining (retry after consumers make
    /// progress).
    pub fn resize(&self, tid: usize, new_k: usize) -> Result<u64, QueueError> {
        if new_k == 0 || new_k > MAX_SHARDS {
            return Err(QueueError::BadConfig("shards must be in 1..=64"));
        }
        let Some(ctor) = &self.shard_ctor else {
            return Err(QueueError::BadConfig(
                "this queue was built from caller-provided shards and cannot re-shard",
            ));
        };
        let guard = self.resize_guard();
        // At most one transition in flight: the plan log holds exactly
        // one spare record slot. Try to finish a lingering drain first.
        // (Owner-side snapshot reads are safe here: flips are serialized
        // under the resize lock this thread holds.)
        let has_draining = self.plans.load_owner().draining.is_some();
        if has_draining && !self.try_retire_locked(tid) {
            return Err(QueueError::BadConfig(
                "a re-shard transition is still draining; retry once consumers drain it",
            ));
        }
        let old = Arc::clone(&self.plans.load_owner().active);
        if new_k == old.shards.len() {
            return Ok(old.epoch); // no-op
        }
        let epoch = old.epoch + 1;
        if epoch > plan::MAX_PLAN_EPOCH {
            return Err(QueueError::BadConfig("plan epoch space exhausted"));
        }
        // Stage: fresh stripes on the placement's pools, constructed on
        // the resizing thread's slot (each stripe psyncs its root once).
        let shard_pool: Vec<usize> =
            (0..new_k).map(|s| self.placement.pool_of(s, self.topo.len())).collect();
        if shard_pool.iter().any(|&p| p >= self.topo.len()) {
            return Err(QueueError::BadConfig(
                "placement names a pool outside the topology (check pinned ids vs --pools)",
            ));
        }
        let stage_start = self.topo.vtime(tid);
        let shards: Vec<Q> = {
            // Fresh-stripe root psyncs (one per stripe) are the Resize
            // half of the transition's `new_k + 3` bound.
            let _site = obs::enter_site(ObsSite::Resize);
            shard_pool.iter().map(|&p| ctor(&self.topo, p, tid)).collect()
        };
        let plan = Arc::new(Plan::new(
            epoch,
            shards,
            shard_pool,
            self.topo.len(),
            self.placement.prefers_home(),
        ));
        // Register BEFORE the durable commit: if the freeze psync lands
        // but this thread crashes unwinding out of it, recovery must be
        // able to resolve the committed epoch to these structs.
        self.history.lock().unwrap().insert(epoch, Arc::clone(&plan));
        let primary = self.topo.primary();
        let old_slot = self.cur_slot.load(Ordering::Relaxed);
        let new_slot = 1 - old_slot;
        {
            // Record + freeze commit: two of the three PlanCommit psyncs
            // (the retire in `try_retire_locked` is the third).
            let _site = obs::enter_site(ObsSite::PlanCommit);
            self.plan_log.write_record(primary, tid, new_slot, epoch, &plan.shard_pool);
            primary.psync(tid);
            obs::flight::record_sealed(
                primary,
                tid,
                obs::flight::FlightKind::PlanCommit,
                obs::flight::plan_payload(epoch, new_k, 0),
            );
            // The commit point: durably Freezing(old, new).
            self.plan_log.set_freezing(primary, tid, old_slot, epoch);
            primary.psync(tid);
            obs::flight::record_sealed(
                primary,
                tid,
                obs::flight::FlightKind::PlanCommit,
                obs::flight::plan_payload(epoch, new_k, 1),
            );
        }
        // Volatile flip — runs only if the commit psync retired, so the
        // durable and volatile views can never cross. Pointer swap, not
        // lock: ops pinned before this instant may keep using the
        // displaced snapshot (enqueues land in the now-frozen plan)
        // until the grace period below ends.
        let displaced = self
            .plans
            .swap(&self.epochs, Arc::new(PlanSet {
                active: Arc::clone(&plan),
                draining: Some(Arc::clone(&old)),
            }));
        self.cur_slot.store(new_slot, Ordering::Relaxed);
        self.epoch_hint.store(epoch, Ordering::Release);
        // Grace period (volatile-only — zero pmem traffic, so the
        // `new_k + 3` psync budget is untouched): after this, no reader
        // holds the displaced snapshot — in particular no stale enqueue
        // can land in the frozen plan anymore, which is what makes the
        // residue read and every later retirement verification sound.
        // (An unwind before this free leaks the snapshot — deliberate:
        // a stalled reader may still hold it, and recovery re-derives
        // all volatile plan state.)
        displaced.free_after_grace(&self.epochs, tid);
        let residue: u64 = old.shards.iter().map(|s| s.len_hint(tid)).sum();
        self.rstats.flips.fetch_add(1, Ordering::Relaxed);
        self.rstats.last_residue.store(residue, Ordering::Relaxed);
        self.rstats.residue_total.fetch_add(residue, Ordering::Relaxed);
        obs::trace::span(
            tid,
            stage_start,
            self.topo.vtime(tid),
            "resize_flip",
            format_args!("\"epoch\":{epoch},\"new_k\":{new_k},\"residue\":{residue}"),
        );
        // An already-empty old plan retires immediately (one psync).
        self.try_retire_locked(tid);
        drop(guard);
        Ok(epoch)
    }

    /// Attempt the one-psync retirement of a fully-drained frozen plan.
    /// Returns `true` when the queue has exactly one plan afterwards
    /// (retired now, or nothing was draining). Cheap when there is no
    /// transition; serialized with [`ShardedQueue::resize`].
    pub fn try_retire(&self, tid: usize) -> bool {
        let _guard = self.resize_guard();
        self.try_retire_locked(tid)
    }

    /// Take the resize lock, tolerating poison: the guard is held across
    /// `psync`s, which can unwind with a simulated-crash signal; the plan
    /// log is the durable source of truth and recovery re-derives every
    /// volatile bit, so a poisoned transition lock carries no bad state.
    fn resize_guard(&self) -> std::sync::MutexGuard<'_, ()> {
        self.resize_lock.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn try_retire_locked(&self, tid: usize) -> bool {
        let set = self.plans.load_owner();
        let old = match &set.draining {
            None => return true,
            Some(o) => Arc::clone(o),
        };
        // Verify emptiness stripe by stripe. `len_hint` never reports 0
        // while a completed item is present, and the plan is enqueue-
        // frozen (the freezing flip's grace period elapsed before its
        // `resize` returned, so no stale pin can enqueue into it), so a
        // zero here is a permanent witness. The dequeue scans' drained
        // flags are only a fast path — retirement always re-verifies
        // against the rings themselves, and resetting a flag to `false`
        // on residue also self-corrects any witness a stale grace-window
        // enqueue invalidated (consumers resume scanning that stripe).
        for (i, s) in old.shards.iter().enumerate() {
            if s.len_hint(tid) == 0 {
                old.drained[i].store(true, Ordering::Relaxed);
            } else {
                old.drained[i].store(false, Ordering::Relaxed);
                return false;
            }
        }
        // Retire the old plan with exactly one psync (the third
        // PlanCommit psync of the transition).
        let primary = self.topo.primary();
        let epoch = self.epoch_hint.load(Ordering::Acquire);
        {
            let _site = obs::enter_site(ObsSite::PlanCommit);
            self.plan_log.set_active(primary, tid, self.cur_slot.load(Ordering::Relaxed), epoch);
            primary.psync(tid);
            obs::flight::record_sealed(
                primary,
                tid,
                obs::flight::FlightKind::PlanCommit,
                obs::flight::plan_payload(epoch, set.active.shards.len(), 2),
            );
        }
        // Drop the frozen plan out of the dispatch path: swap in a
        // draining-free snapshot, then grace-wait before freeing the
        // displaced one (readers still scanning the frozen stripes see
        // only empty rings — retirement was just verified).
        let displaced = self
            .plans
            .swap(&self.epochs, Arc::new(PlanSet { active: Arc::clone(&set.active), draining: None }));
        displaced.free_after_grace(&self.epochs, tid);
        // Reclaim the retired generation's pmem. The durable retirement
        // above is a permanent witness that every item this generation
        // ever held was returned pre-retirement, so (a) batch-log entries
        // naming its epoch are skippable at reconciliation and (b) its
        // stripes can go back to the allocator. Prune the history FIRST,
        // so a crash mid-reclaim can never make recovery walk a
        // half-freed chain (the pruned epoch is simply skipped).
        self.history.lock().unwrap().retain(|&e, _| e != old.epoch);
        for s in &old.shards {
            s.reclaim_pmem(tid);
        }
        self.rstats.retires.fetch_add(1, Ordering::Relaxed);
        obs::trace::event(
            tid,
            self.topo.vtime(tid),
            "plan_retire",
            format_args!("\"epoch\":{epoch}"),
        );
        true
    }

    /// Post-recovery batch reconciliation (single-threaded). See module
    /// docs for the soundness argument. Order matters: the dequeue logs
    /// are replayed first and feed the "was returned" set the enqueue-log
    /// verdicts depend on. Walks **all** pools: each thread's logs live
    /// on its home pool, the probed/retired cells on the shards' pools.
    /// The final drain psyncs every pool, closing the window where a
    /// crash mid-flush realized one pool's psync but not another's.
    /// Entries are **plan-epoch-qualified**: each resolves against the
    /// plan generation it was recorded under (retired generations stay in
    /// the volatile history until the logs that may reference them are
    /// cleared right here). Re-insertions always land in the *current*
    /// active plan — a frozen stripe must never regain items.
    fn reconcile(&self) {
        let tid = 0;
        let history: HashMap<u64, Arc<Plan<Q>>> = self.history.lock().unwrap().clone();
        let active = self.active_plan();

        // --- Dequeue logs: suppress redelivery of logged consumptions ---
        // Key: (plan, shard, node, ring idx, item) — a ring position is
        // consumed by exactly one dequeue, so the tuple is unique per
        // crash epoch.
        let mut consumed: std::collections::HashSet<(u64, usize, u64, u64, u64)> =
            std::collections::HashSet::new();
        if self.batch_deq > 1 {
            for t in 0..self.nthreads {
                let lpool = self.topo.pool(self.log_pool[t]);
                let (count, seq) = self.deq_logs[t].header(lpool, tid);
                if count == 0 || seq == 0 {
                    continue;
                }
                for i in 0..count.min(self.batch_deq) {
                    let e = self.deq_logs[t].entry(lpool, tid, i);
                    let Some(plan) = (e.seq == seq && e.enc_item != 0)
                        .then(|| history.get(&e.plan_epoch))
                        .flatten()
                    else {
                        continue; // torn/garbage entry or unknown plan — skip
                    };
                    if e.shard >= plan.shards.len() {
                        continue;
                    }
                    let item = e.enc_item - 1;
                    let pos = EnqPos { node: e.node, idx: e.idx };
                    consumed.insert((e.plan_epoch, e.shard, e.node.to_u64(), e.idx, item));
                    // Returned pre-crash but still durably present: clear
                    // the cell so the recovered queue cannot redeliver it.
                    let _ = plan.shards[e.shard].retire(tid, &pos, item);
                }
                self.deq_logs[t].clear(lpool, tid);
            }
        }

        // --- Enqueue logs: re-insert provably-never-returned items ---
        for t in 0..self.nthreads.min(self.logs.len()) {
            let lpool = self.topo.pool(self.log_pool[t]);
            let (count, seq) = self.logs[t].header(lpool, tid);
            if count == 0 || seq == 0 {
                continue;
            }
            for i in 0..count.min(self.batch) {
                let e = self.logs[t].entry(lpool, tid, i);
                let Some(plan) = (e.seq == seq && e.enc_item != 0)
                    .then(|| history.get(&e.plan_epoch))
                    .flatten()
                else {
                    continue; // torn/garbage entry or unknown plan — skip
                };
                if e.shard >= plan.shards.len() {
                    continue;
                }
                let item = e.enc_item - 1;
                if consumed.contains(&(e.plan_epoch, e.shard, e.node.to_u64(), e.idx, item)) {
                    continue; // durably recorded as returned — never re-insert
                }
                let pos = EnqPos { node: e.node, idx: e.idx };
                if plan.shards[e.shard].probe(tid, &pos, item) == Probe::Missing {
                    // Never returned to any caller (Head ≤ idx, no dequeue
                    // log entry) and not in NVM: re-insert — into the
                    // ACTIVE plan (the recorded stripe may be frozen or
                    // retired). Lands at a tail; the relaxed-FIFO checker
                    // absorbs the displacement.
                    let target = e.shard % active.shards.len();
                    let _ = active.shards[target].enqueue(tid, item);
                }
            }
            self.logs[t].clear(lpool, tid);
        }
        // One drain per pool realizes the log retirements, the retired
        // cells, and any deferred cell pwbs from re-insertions.
        self.topo.psync_all(tid);
    }
}

impl<Q: Shardable> ConcurrentQueue for ShardedQueue<Q> {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.enqueue_impl(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.dequeue_impl(tid)
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

impl<Q: Shardable> PersistentQueue for ShardedQueue<Q> {
    fn quiesce(&self) {
        self.flush_all();
    }

    fn attach(&self, tid: usize) {
        // Flush whatever a dead predecessor stranded in the slot, then
        // reseed the round-robin ticket from the global counter so a
        // replacement worker does not restart at the same phase and skew
        // shard pressure.
        self.flush(tid);
        let slot = self.slot(tid);
        slot.ticket = self.ticket_seed.fetch_add(1, Ordering::Relaxed);
        // A pinned read of one length — the first call site converted
        // off the old plan lock (a full lock acquisition to read a
        // `Vec::len` was the poster child for the per-op tax).
        let scan = self.epochs.pin(&self.plans, tid).active.deq_orders[self.home(tid)].len();
        slot.cursor = (slot.ticket % scan as u64) as usize;
    }

    fn detach(&self, tid: usize) {
        self.flush(tid);
    }

    /// Post-crash recovery. The `pool` argument (the trait's single-pool
    /// contract) is ignored: each shard recovers on its own pool and the
    /// batch reconciliation walks every pool of the topology.
    ///
    /// Re-sharding makes recovery **plan-directed**: the durable plan log
    /// names the committed state, and recovery always converges to
    /// exactly one plan — a crash mid-`Freezing` is rolled *forward* (the
    /// new record is durable by construction): adopt the new plan,
    /// recover + reconcile every generation the batch logs may reference,
    /// drain the frozen residue into the active stripes (single-threaded;
    /// recovery is crash-free, so the move is atomic with respect to the
    /// next crash), and retire the old plan durably.
    fn recover(&self, _pool: &PmemPool) {
        let tid = 0;
        let primary = self.topo.primary();
        // Every psync below — shard recovery, reconciliation, the forward
        // drain (whose flushes defer to this ambient scope), retirement —
        // is Recovery traffic in the site ledger.
        let _site = obs::enter_site(ObsSite::Recovery);
        let t0 = self.topo.vtime(tid);
        // Advisory flight marker: rides whatever psync recovery issues
        // next (shard recovery below psyncs on every generation).
        obs::flight::record_advisory(
            primary,
            tid,
            obs::flight::FlightKind::RecoverBegin,
            primary.epoch(),
        );
        // 1. Adopt the durably committed plan state. The volatile history
        //    covers every epoch the log can name: plans are registered
        //    before their freeze commit, and an uncommitted staged plan
        //    (crash between record write and freeze psync) is simply
        //    pruned below — no operation ever targeted it.
        let state = self.plan_log.read_state(primary, tid);
        let (active_epoch, draining_epoch) = match state {
            PlanState::Active { slot, epoch } => {
                self.cur_slot.store(slot, Ordering::Relaxed);
                (epoch, None)
            }
            PlanState::Freezing { old_slot, epoch } => {
                let (old_epoch, _) = self.plan_log.read_record(primary, tid, old_slot);
                self.cur_slot.store(1 - old_slot, Ordering::Relaxed);
                (epoch, Some(old_epoch))
            }
        };
        let history: HashMap<u64, Arc<Plan<Q>>> = self.history.lock().unwrap().clone();
        let active = Arc::clone(
            history
                .get(&active_epoch)
                .expect("plan history must cover every durably committed epoch"),
        );
        let draining = draining_epoch.map(|e| {
            Arc::clone(history.get(&e).expect("frozen plan must be in the volatile history"))
        });
        // Quiescent flip: recovery runs with every worker stopped (a
        // simulated crash unwinds through the RAII pin guards, so no
        // slot can be left pinned) — the grace sweep returns instantly.
        self.plans
            .swap(&self.epochs, Arc::new(PlanSet {
                active: Arc::clone(&active),
                draining: draining.clone(),
            }))
            .free_after_grace(&self.epochs, tid);
        self.epoch_hint.store(active_epoch, Ordering::Release);
        // 2. Recover every generation's stripes — retired plans too:
        //    sealed batch logs may still reference their positions, and
        //    probe/retire verdicts need recovered endpoints.
        for plan in history.values() {
            for (i, s) in plan.shards.iter().enumerate() {
                s.recover(self.topo.pool(plan.shard_pool[i]));
            }
        }
        let t_shards = self.topo.vtime(tid);
        obs::trace::span(
            tid,
            t0,
            t_shards,
            "recover_shards",
            format_args!("\"plans\":{},\"epoch\":{active_epoch}", history.len()),
        );
        // 3. Reconcile the plan-epoch-qualified batch logs.
        if self.batch > 1 || self.batch_deq > 1 {
            self.reconcile();
            obs::trace::span(
                tid,
                t_shards,
                self.topo.vtime(tid),
                "recover_reconcile",
                format_args!(""),
            );
        }
        // 4. Reset volatile dispatch state; bump seqs so fresh batches can
        //    never collide with stale (already reconciled) log entries.
        for t in 0..self.nthreads {
            let slot = self.slot(t);
            slot.ticket = 0;
            slot.cursor = 0;
            slot.pending = 0;
            slot.seq += 1;
            slot.enq_pools = 0;
            slot.deq_pending = 0;
            slot.deq_seq += 1;
            slot.deq_pools = 0;
        }
        // 5. Converge a mid-transition crash: forward-drain the frozen
        //    residue into the active plan and retire with one psync.
        if let Some(old) = draining {
            let t_drain = self.topo.vtime(tid);
            let mut moved = 0u64;
            for s in &old.shards {
                while let Ok(Some(v)) = s.dequeue(tid) {
                    self.enqueue_impl(tid, v)
                        .expect("re-shard recovery re-enqueue failed: size the pool");
                    moved += 1;
                }
            }
            self.rstats.drained_from_frozen.fetch_add(moved, Ordering::Relaxed);
            self.rstats.residue_total.fetch_add(moved, Ordering::Relaxed);
            // Seal + psync the migration batch, then drain every pool so
            // the frozen-side Head advances (and any stray deferred pwbs)
            // are durable BEFORE the retirement commit.
            self.flush(tid);
            self.topo.psync_all(tid);
            self.plan_log.set_active(
                primary,
                tid,
                self.cur_slot.load(Ordering::Relaxed),
                active_epoch,
            );
            primary.psync(tid);
            self.plans
                .swap(&self.epochs, Arc::new(PlanSet { active: Arc::clone(&active), draining: None }))
                .free_after_grace(&self.epochs, tid);
            self.rstats.retires.fetch_add(1, Ordering::Relaxed);
            obs::trace::span(
                tid,
                t_drain,
                self.topo.vtime(tid),
                "recover_drain",
                format_args!("\"moved\":{moved}"),
            );
        }
        // 6. Prune the plan history: the logs were cleared and every
        //    slot's seq bumped, so no entry can reference an older
        //    generation anymore — then hand the dropped generations'
        //    stripes back to the allocator tier (recovery is
        //    single-threaded and the durable plan state no longer names
        //    them; prune-before-reclaim mirrors `try_retire_locked`).
        let mut hist = self.history.lock().unwrap();
        hist.retain(|&e, _| e == active_epoch);
        drop(hist);
        for (&e, plan) in history.iter() {
            if e != active_epoch {
                for s in &plan.shards {
                    s.reclaim_pmem(tid);
                }
            }
        }
        // Certified span end: every recovery psync above has retired.
        obs::flight::record_sealed(
            primary,
            tid,
            obs::flight::FlightKind::RecoverEnd,
            primary.epoch(),
        );
    }
}

/// RAII claim on a [`ShardedQueue`] thread slot — see
/// [`ShardedQueue::attach_worker`]. Flushes the slot's filling batches on
/// drop (including unwind), so a dying worker cannot strand them.
pub struct WorkerSlot<'q, Q: Shardable> {
    q: &'q ShardedQueue<Q>,
    tid: usize,
}

impl<Q: Shardable> WorkerSlot<'_, Q> {
    /// The claimed thread id.
    pub fn tid(&self) -> usize {
        self.tid
    }
}

impl<Q: Shardable> Drop for WorkerSlot<'_, Q> {
    fn drop(&mut self) {
        // Best-effort: if the pool is mid-crash the flush itself unwinds
        // with a CrashSignal; swallow it — recovery reconciles the logs.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.q.flush(self.tid);
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(shards: usize, batch: usize) -> (Arc<PmemPool>, ShardedQueue) {
        mk_full(shards, batch, 1, 0.0, 0.0)
    }

    fn mk_deq(shards: usize, batch_deq: usize) -> (Arc<PmemPool>, ShardedQueue) {
        mk_full(shards, 1, batch_deq, 0.0, 0.0)
    }

    fn mk_full(
        shards: usize,
        batch: usize,
        batch_deq: usize,
        evict: f64,
        pending: f64,
    ) -> (Arc<PmemPool>, ShardedQueue) {
        let topo = Topology::single(PmemConfig {
            capacity_words: 1 << 22,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed: 21,
        });
        let cfg =
            QueueConfig { shards, batch, batch_deq, ring_size: 64, ..Default::default() };
        let q = ShardedQueue::new_perlcrq(&topo, 8, cfg).unwrap();
        (Arc::clone(topo.primary()), q)
    }

    /// A 2-pool topology with zero-cost metering and deterministic crash
    /// behavior (nothing unflushed ever survives).
    fn mk_topo(
        pools: usize,
        shards: usize,
        batch: usize,
        batch_deq: usize,
        placement: PlacementPolicy,
    ) -> (Topology, ShardedQueue) {
        let topo = Topology::new(
            PmemConfig {
                capacity_words: 1 << 22,
                cost: CostModel::zero(),
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 77,
            },
            pools,
        );
        let cfg = QueueConfig {
            shards,
            batch,
            batch_deq,
            ring_size: 64,
            placement,
            ..Default::default()
        };
        let q = ShardedQueue::new_perlcrq(&topo, 8, cfg).unwrap();
        (topo, q)
    }

    fn drain(q: &ShardedQueue, tid: usize) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(tid).unwrap() {
            out.push(v);
        }
        out
    }

    #[test]
    fn len_hint_is_an_upper_bound_on_occupancy() {
        // The contract every residue/depth report relies on: the hint may
        // overcount (draining windows, unflushed batches) but must never
        // report 0 while a completed item remains — and it settles to 0
        // once the queue is truly empty.
        let (_p, q) = mk(2, 4);
        assert_eq!(q.depth_hint(0), 0, "fresh queue reports empty");
        for v in 0..20u64 {
            q.enqueue(0, v).unwrap();
        }
        assert!(q.depth_hint(0) >= 20, "hint undercounted live items");
        for _ in 0..10 {
            q.dequeue(0).unwrap();
        }
        assert!(q.depth_hint(0) >= 10, "hint undercounted after partial drain");
        let rest = drain(&q, 0);
        assert_eq!(rest.len(), 10);
        assert_eq!(q.depth_hint(0), 0, "hint must settle once drained");
    }

    #[test]
    fn bad_configs_rejected_not_panicking() {
        let topo = Topology::single(PmemConfig {
            capacity_words: 1 << 16,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 1,
        });
        for cfg in [
            QueueConfig { shards: 0, ..Default::default() },
            QueueConfig { batch: 0, ..Default::default() },
            QueueConfig { batch: crate::queues::MAX_BATCH + 1, ..Default::default() },
            // Pinned placement naming a pool the topology does not have.
            QueueConfig {
                placement: PlacementPolicy::Pinned(vec![1]),
                ..Default::default()
            },
        ] {
            assert!(matches!(
                ShardedQueue::new_perlcrq(&topo, 4, cfg),
                Err(QueueError::BadConfig(_))
            ));
        }
    }

    #[test]
    fn lockstep_round_robin_is_fifo() {
        // Single thread, enqueue and dequeue cursors advance in lockstep:
        // the relaxed queue degenerates to exact FIFO.
        let (_p, q) = mk(4, 1);
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(drain(&q, 0), (0..32).collect::<Vec<u64>>());
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn all_items_survive_unbatched_crash() {
        let (p, q) = mk(4, 1);
        for v in 0..60u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..25 {
            got.push(q.dequeue(1).unwrap().expect("item"));
        }
        let mut rng = Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        q.recover(&p);
        got.extend(drain(&q, 0));
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "duplicates across crash");
        assert_eq!(got, (0..60).collect::<Vec<u64>>(), "items lost across crash");
    }

    #[test]
    fn batch_amortizes_psyncs() {
        let (p, q) = mk(2, 8);
        p.stats.reset();
        for v in 0..7u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(p.stats.total().psyncs, 0, "no psync before the batch fills");
        q.enqueue(0, 7).unwrap(); // 8th op seals + syncs
        let s = p.stats.total();
        assert_eq!(s.psyncs, 1, "exactly one psync per batch of 8");
        assert!(s.pwbs >= 8, "each op still issues its cell pwb");
        // Unbatched comparison: one psync per op.
        let (p1, q1) = mk(2, 1);
        p1.stats.reset();
        for v in 0..8u64 {
            q1.enqueue(0, v).unwrap();
        }
        assert_eq!(p1.stats.total().psyncs, 8);
    }

    #[test]
    fn flushed_batch_survives_crash() {
        let (p, q) = mk(2, 4);
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap(); // two full batches, both flushed
        }
        let mut rng = Xoshiro256::seed_from(6);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn unflushed_tail_may_vanish_without_corruption() {
        // 3 enqueues into a batch of 8, never flushed, nothing persisted
        // (evict/pending = 0): the items are lost — the buffered-durability
        // contract — but the queue recovers clean and functional.
        let (p, q) = mk(2, 8);
        for v in 0..3u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(drain(&q, 0), Vec::<u64>::new());
        q.enqueue(0, 99).unwrap();
        q.flush(0);
        assert_eq!(q.dequeue(1).unwrap(), Some(99));
    }

    #[test]
    fn explicit_flush_makes_partial_batch_durable() {
        let (p, q) = mk(2, 8);
        for v in 0..3u64 {
            q.enqueue(0, v).unwrap();
        }
        q.flush_all();
        let mut rng = Xoshiro256::seed_from(8);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn reconciliation_reinserts_lost_cells_from_sealed_log() {
        // Seal a batch durably, then wipe the items' cells in NVM
        // (simulating cell flushes that never landed while the log line
        // did): recovery must re-insert every item from the log.
        let (p, q) = mk(1, 4);
        for v in 10..14u64 {
            q.enqueue(0, v).unwrap(); // fills + flushes one batch
        }
        let plan = q.active_plan();
        let core = plan.shards[0].core();
        let first = PAddr::from_u64(p.peek(core.first));
        let ring = core.ring_of(first);
        for u in 0..4u64 {
            ring.write_cell(&p, 0, u, false, u, 0 /* BOT */);
        }
        p.persist_range(0, ring.cell_addr(0), 8);
        // Undo the durable retire so the log still claims the batch: the
        // simplest way is to crash BEFORE recovery ran — the log header was
        // sealed by the flush and is only cleared during recover().
        let mut rng = Xoshiro256::seed_from(9);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, vec![10, 11, 12, 13], "log reconciliation must re-insert");
    }

    #[test]
    fn reconciliation_never_duplicates_consumed_items() {
        // Flush a batch, consume part of it (durable head persists), crash
        // with the log still sealed: reconciliation must re-insert nothing.
        let (p, q) = mk(1, 4);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.dequeue(1).unwrap(), Some(0));
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
        let mut rng = Xoshiro256::seed_from(10);
        p.crash(&mut rng);
        q.recover(&p);
        let got = drain(&q, 0);
        assert_eq!(got, vec![2, 3], "consumed items must not reappear: {got:?}");
    }

    #[test]
    fn double_crash_after_reconciliation_is_stable() {
        let (p, q) = mk(2, 4);
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(11);
        p.crash(&mut rng);
        q.recover(&p);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "double crash produced duplicates");
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn deq_batch_amortizes_psyncs() {
        let (p, q) = mk_deq(2, 4);
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap(); // per-op persistence (batch = 1)
        }
        p.stats.reset();
        for _ in 0..3 {
            assert!(q.dequeue(0).unwrap().is_some());
        }
        assert_eq!(p.stats.total().psyncs, 0, "no psync before the dequeue batch fills");
        assert!(q.dequeue(0).unwrap().is_some()); // 4th seals + syncs
        let s = p.stats.total();
        assert_eq!(s.psyncs, 1, "exactly one psync per dequeue batch of 4");
        assert!(s.pwbs >= 4, "each dequeue still issues its Head_i pwb");
        // Per-op comparison.
        let (p1, q1) = mk_deq(2, 1);
        for v in 0..4u64 {
            q1.enqueue(0, v).unwrap();
        }
        p1.stats.reset();
        for _ in 0..4 {
            assert!(q1.dequeue(0).unwrap().is_some());
        }
        assert_eq!(p1.stats.total().psyncs, 4);
    }

    #[test]
    fn flushed_dequeues_settle_across_crash() {
        // batch_deq = 2: two dequeues flush together; after a crash the
        // recovered queue must NOT redeliver them (Head_i rode the flush).
        let (p, q) = mk_deq(1, 2);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.dequeue(1).unwrap(), Some(0));
        assert_eq!(q.dequeue(1).unwrap(), Some(1)); // seals + syncs
        let mut rng = Xoshiro256::seed_from(31);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(drain(&q, 0), vec![2, 3], "flushed consumption must be durable");
    }

    #[test]
    fn unflushed_dequeues_redeliver_but_never_lose() {
        // One dequeue inside an unflushed batch of 4: the crash rolls the
        // durable Head back, so the item is redelivered (the bounded
        // consumer-side window) — but nothing is ever lost.
        let (p, q) = mk_deq(1, 4);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.dequeue(1).unwrap(), Some(0)); // unflushed consumption
        let mut rng = Xoshiro256::seed_from(32);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(
            drain(&q, 0),
            vec![0, 1, 2, 3],
            "unflushed consumption may redeliver; enqueued items must survive"
        );
    }

    #[test]
    fn retire_clears_logged_consumption_exactly_once() {
        // Directly exercise the recovery primitive behind the dequeue log:
        // a logged position still durably occupied is cleared once.
        let (p, q) = mk(1, 1);
        for v in 0..3u64 {
            q.enqueue(0, v).unwrap();
        }
        let plan = q.active_plan();
        let core = plan.shards[0].core();
        let first = PAddr::from_u64(p.peek(core.first));
        let pos = EnqPos { node: first, idx: 0 };
        assert!(plan.shards[0].retire(0, &pos, 0), "occupied position must clear");
        p.psync(0);
        assert!(!plan.shards[0].retire(0, &pos, 0), "second retire is a no-op");
        assert_eq!(drain(&q, 0), vec![1, 2], "retired item must not be delivered");
    }

    #[test]
    fn worker_slot_flushes_on_panic_and_reseeds_ticket() {
        let (p, q) = mk_full(2, 4, 4, 0.0, 0.0);
        let q = Arc::new(q);
        // A worker that panics mid-batch: the WorkerSlot drop must flush
        // its partial enqueue batch so the items are durable.
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let slot = q2.attach_worker(3);
            q2.enqueue(slot.tid(), 100).unwrap();
            q2.enqueue(slot.tid(), 101).unwrap();
            std::panic::panic_any("worker died");
        });
        assert!(h.join().is_err());
        let mut rng = Xoshiro256::seed_from(33);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, vec![100, 101], "panicked worker's batch must have been flushed");
        // A replacement worker on the same tid gets a fresh ticket phase:
        // the global seed is monotone, so successive attachments observe
        // strictly increasing tickets (never a restart at 0), and the
        // dequeue cursor follows the ticket.
        let s1 = q.attach_worker(3);
        assert_eq!(s1.tid(), 3);
        let t1 = q.slot(3).ticket;
        drop(s1);
        let s2 = q.attach_worker(3);
        let t2 = q.slot(3).ticket;
        assert!(t2 > t1, "re-attachment must advance the ticket seed ({t1} -> {t2})");
        assert_eq!(q.slot(3).cursor, (t2 % q.shard_count() as u64) as usize);
        drop(s2);
    }

    #[test]
    fn single_pool_topology_degenerates_identically() {
        // On one pool every placement collapses to the pre-topology
        // dispatch: identical delivery order AND identical virtual time.
        let run = |placement: PlacementPolicy| -> (Vec<u64>, u64) {
            let topo = Topology::single(PmemConfig {
                capacity_words: 1 << 22,
                cost: CostModel::default(),
                evict_prob: 0.0,
                pending_flush_prob: 0.0,
                seed: 21,
            });
            let cfg = QueueConfig {
                shards: 4,
                batch: 4,
                batch_deq: 2,
                ring_size: 64,
                placement,
                ..Default::default()
            };
            let q = ShardedQueue::new_perlcrq(&topo, 8, cfg).unwrap();
            for v in 0..64u64 {
                q.enqueue(0, v).unwrap();
            }
            let mut out = Vec::new();
            while let Some(v) = q.dequeue(1).unwrap() {
                out.push(v);
            }
            (out, topo.max_vtime())
        };
        let (h_inter, t_inter) = run(PlacementPolicy::Interleave);
        let (h_coloc, t_coloc) = run(PlacementPolicy::Colocate);
        let (h_pin, t_pin) = run(PlacementPolicy::Pinned(vec![0]));
        assert_eq!(h_inter, h_coloc, "single-pool colocate must equal interleave");
        assert_eq!(h_inter, h_pin, "single-pool pinned:0 must equal interleave");
        assert_eq!(t_inter, t_coloc, "degenerate topology must charge identical costs");
        assert_eq!(t_inter, t_pin);
    }

    #[test]
    fn placement_maps_shards_onto_pools() {
        let (_topo, q) = mk_topo(2, 4, 1, 1, PlacementPolicy::Interleave);
        assert_eq!((0..4).map(|s| q.shard_pool_of(s)).collect::<Vec<_>>(), vec![0, 1, 0, 1]);
        let (_topo, q) = mk_topo(2, 3, 1, 1, PlacementPolicy::Pinned(vec![1]));
        assert_eq!((0..3).map(|s| q.shard_pool_of(s)).collect::<Vec<_>>(), vec![1, 1, 1]);
        // All items still flow (everything pinned off the home pool).
        for v in 0..12u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, (0..12).collect::<Vec<u64>>());
    }

    #[test]
    fn colocate_keeps_persistence_socket_local() {
        // Single producer/consumer homed on socket 0: under colocate its
        // cell pwbs, Head_i pwbs and FAIs all stay on pool 0 — zero
        // cross-socket ops. Under interleave half the traffic crosses.
        let (topo, q) = mk_topo(2, 4, 4, 4, PlacementPolicy::Colocate);
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        for _ in 0..32 {
            assert!(q.dequeue(0).unwrap().is_some());
        }
        q.flush_all();
        assert_eq!(
            topo.stats_total().remote_ops,
            0,
            "colocated home-socket traffic must never cross sockets"
        );
        let (topo, q) = mk_topo(2, 4, 4, 4, PlacementPolicy::Interleave);
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        q.flush_all();
        assert!(
            topo.stats_total().remote_ops > 0,
            "interleaved enqueues from socket 0 must touch pool 1"
        );
    }

    #[test]
    fn colocated_flush_is_one_psync_interleaved_spans_pools() {
        // batch = 4, 2 pools. Colocate: the 4 cells + log live on the
        // home pool — exactly 1 psync per flush. Interleave: the batch
        // touches both pools — 2 psyncs per flush.
        let (topo, q) = mk_topo(2, 4, 4, 1, PlacementPolicy::Colocate);
        topo.reset_meter();
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap(); // 4th enqueue seals + flushes
        }
        assert_eq!(topo.stats_total().psyncs, 1, "colocated flush = one psync");
        let (topo, q) = mk_topo(2, 4, 4, 1, PlacementPolicy::Interleave);
        topo.reset_meter();
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(
            topo.stats_total().psyncs,
            2,
            "interleaved batch spans 2 pools = one psync each"
        );
    }

    #[test]
    fn crash_between_cross_pool_flush_psyncs_loses_nothing() {
        // The window the multi-pool flush opens: the batch spans pools 0
        // and 1; the log seal + pool 0's psync land, the crash hits
        // before pool 1's psync. The sealed log must drive reconciliation
        // to re-insert pool 1's cells — no loss, no duplication.
        let (topo, q) = mk_topo(2, 2, 4, 1, PlacementPolicy::Interleave);
        // Thread 0 (home pool 0): shard 0 → pool 0, shard 1 → pool 1.
        q.enqueue(0, 10).unwrap(); // shard 0 (pool 0)
        q.enqueue(0, 11).unwrap(); // shard 1 (pool 1)
        q.enqueue(0, 12).unwrap(); // shard 0
        q.enqueue(0, 13).unwrap(); // shard 1 — batch of 4 full? batch=4 → flush on this enqueue
        // Re-fill a fresh batch and replay the flush by hand, stopping
        // after the first pool's psync.
        q.enqueue(0, 20).unwrap(); // shard 0 (pool 0)
        q.enqueue(0, 21).unwrap(); // shard 1 (pool 1)
        {
            let slot = q.slot(0);
            assert_eq!(slot.pending, 2, "two entries in the filling batch");
            let lp = q.log_pool[0];
            assert_eq!(lp, 0, "thread 0's log lives on its home pool");
            q.logs[0].seal(q.topo.pool(lp), 0, slot.pending, slot.seq);
            slot.pending = 0;
            slot.seq += 1;
            slot.enq_pools = 0;
            // Pool 0's psync lands (log + cell 20); pool 1's never runs.
            q.topo.pool(0).psync(0);
        }
        let mut rng = Xoshiro256::seed_from(51);
        topo.crash(&mut rng); // pending_flush_prob = 0: cell 21 dies
        q.recover(topo.primary());
        let mut got = drain(&q, 0);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "cross-pool flush crash must not duplicate");
        assert_eq!(
            got,
            vec![10, 11, 12, 13, 20, 21],
            "all flushed + logged items must survive the torn flush"
        );
    }

    #[test]
    fn crash_between_cross_pool_deq_flush_psyncs_never_redelivers() {
        // Symmetric consumer-side window: two dequeues consumed from
        // shards on different pools; the dequeue log seals and pool 0's
        // psync lands, pool 1's Head_i flush does not. The logged
        // consumption must be retired at recovery — no redelivery.
        let (topo, q) = mk_topo(2, 2, 1, 4, PlacementPolicy::Interleave);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap(); // per-op durable (batch = 1)
        }
        // Values 0, 2 sit in shard 0 (pool 0); 1, 3 in shard 1 (pool 1).
        assert_eq!(q.dequeue(1).unwrap(), Some(0)); // shard 0, pool 0
        assert_eq!(q.dequeue(1).unwrap(), Some(1)); // shard 1, pool 1
        {
            let slot = q.slot(1);
            assert_eq!(slot.deq_pending, 2);
            let lp = q.log_pool[1];
            q.deq_logs[1].seal(q.topo.pool(lp), 1, slot.deq_pending, slot.deq_seq);
            slot.deq_pending = 0;
            slot.deq_seq += 1;
            slot.deq_pools = 0;
            // Thread 1 homes on pool 1, so its log lives there; psync the
            // LOG's pool only — shard 0's Head_i flush (pool 0) is lost.
            q.topo.pool(lp).psync(1);
        }
        let mut rng = Xoshiro256::seed_from(52);
        topo.crash(&mut rng);
        q.recover(topo.primary());
        assert_eq!(
            drain(&q, 0),
            vec![2, 3],
            "logged consumptions must not redeliver even when one pool's flush died"
        );
    }

    #[test]
    fn multi_pool_randomized_crash_cycles_no_duplicates() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        for placement in [
            PlacementPolicy::Interleave,
            PlacementPolicy::Colocate,
            PlacementPolicy::Pinned(vec![1, 0]),
        ] {
            let topo = Topology::new(
                PmemConfig {
                    capacity_words: 1 << 22,
                    cost: CostModel::zero(),
                    evict_prob: 0.3,
                    pending_flush_prob: 0.5,
                    seed: 14,
                },
                2,
            );
            let cfg = QueueConfig {
                shards: 4,
                batch: 4,
                batch_deq: 4,
                ring_size: 64,
                placement: placement.clone(),
                ..Default::default()
            };
            let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4, cfg).unwrap());
            let mut rng = Xoshiro256::seed_from(15);
            let mut returned: Vec<u64> = Vec::new();
            for cycle in 0..4u64 {
                topo.arm_crash_after(1_500 + rng.next_below(1_500));
                let mut hs = Vec::new();
                for tid in 0..4usize {
                    let q = Arc::clone(&q);
                    let base = cycle * 4_000_000 + tid as u64 * 1_000_000;
                    hs.push(std::thread::spawn(move || {
                        let mut mine = Vec::new();
                        let _ = run_guarded(|| {
                            for i in 0..50_000u64 {
                                q.enqueue(tid, base + i).unwrap();
                                if let Some(v) = q.dequeue(tid).unwrap() {
                                    mine.push(v);
                                }
                            }
                        });
                        mine
                    }));
                }
                for h in hs {
                    returned.extend(h.join().unwrap());
                }
                topo.crash(&mut rng);
                q.recover(topo.primary());
            }
            while let Some(v) = q.dequeue(0).unwrap() {
                returned.push(v);
            }
            let n = returned.len();
            returned.sort_unstable();
            returned.dedup();
            assert_eq!(
                returned.len(),
                n,
                "duplicate across crash cycles under {placement} placement"
            );
        }
    }

    #[test]
    fn randomized_crash_cycles_no_duplicates() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        let topo = Topology::single(PmemConfig {
            capacity_words: 1 << 23,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed: 12,
        });
        let pool = Arc::clone(topo.primary());
        let cfg = QueueConfig { shards: 4, batch: 4, ring_size: 64, ..Default::default() };
        let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4, cfg).unwrap());
        let mut rng = Xoshiro256::seed_from(13);
        let mut returned: Vec<u64> = Vec::new();
        for cycle in 0..5u64 {
            pool.arm_crash_after(2_000 + rng.next_below(2_000));
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                let base = cycle * 4_000_000 + tid as u64 * 1_000_000;
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..100_000u64 {
                            q.enqueue(tid, base + i).unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            pool.crash(&mut rng);
            q.recover(&pool);
        }
        while let Some(v) = q.dequeue(0).unwrap() {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate item observed across crash cycles");
    }

    // ------------------------------------------------------------------
    // Elastic re-sharding
    // ------------------------------------------------------------------

    #[test]
    fn resize_grow_and_shrink_lose_nothing() {
        let (_p, q) = mk(2, 1);
        assert_eq!(q.plan_epoch(), 1);
        for v in 0..20u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.resize(0, 8), Ok(2), "grow commits epoch 2");
        assert_eq!(q.shard_count(), 8);
        for v in 20..40u64 {
            q.enqueue(0, v).unwrap(); // stripe over the NEW plan
        }
        // Old residue drains first (drain priority), then new items.
        let got = drain(&q, 1);
        assert_eq!(got.len(), 40);
        let (old_part, _new_part) = got.split_at(20);
        let mut old_sorted = old_part.to_vec();
        old_sorted.sort_unstable();
        assert_eq!(old_sorted, (0..20).collect::<Vec<u64>>(), "frozen residue delivered first");
        let mut all = got.clone();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<u64>>(), "no loss/dup across the grow");
        // Drain retired the old plan; shrink works the same way.
        assert!(q.draining_info(0).is_none(), "drained transition must retire");
        assert_eq!(q.resize(0, 3), Ok(3));
        for v in 100..120u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, (100..120).collect::<Vec<u64>>());
        assert!(q.resize_stats().retires >= 2);
    }

    #[test]
    fn resize_on_empty_queue_retires_immediately_with_bounded_psyncs() {
        let (p, q) = mk(2, 1);
        p.stats.reset();
        assert_eq!(q.resize(0, 4), Ok(2));
        assert!(q.draining_info(0).is_none(), "empty old plan retires inside resize");
        // new_k stripe-root psyncs + record + freeze + retire.
        assert_eq!(
            p.stats.total().psyncs,
            4 + 3,
            "a resize costs new_k + 3 psyncs (stripes, record, freeze, retire)"
        );
        let st = q.resize_stats();
        assert_eq!((st.flips, st.retires, st.last_residue), (1, 1, 0));
    }

    #[test]
    fn resize_waits_for_a_stalled_pinned_reader() {
        // The stalled-reader property at the queue level: a resize's
        // flip must not complete its grace period — and so must not
        // read residue, verify retirement, or free the displaced plan
        // set — while an operation is still pinned to the old snapshot.
        use std::sync::atomic::AtomicBool;
        use std::sync::mpsc;
        let (_p, q) = mk(2, 1);
        let q = Arc::new(q);
        let (ready_tx, ready_rx) = mpsc::channel();
        let (unpin_tx, unpin_rx) = mpsc::channel::<()>();
        let reader = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                // A mid-operation reader, stalled while pinned.
                let set = q.epochs.pin(&q.plans, 1);
                ready_tx.send(set.active.epoch).unwrap();
                unpin_rx.recv().unwrap();
                assert_eq!(set.active.epoch, 1, "the pinned snapshot must stay intact");
                assert!(set.draining.is_none());
            })
        };
        assert_eq!(ready_rx.recv().unwrap(), 1);
        let done = Arc::new(AtomicBool::new(false));
        let resizer = {
            let (q, done) = (Arc::clone(&q), Arc::clone(&done));
            std::thread::spawn(move || {
                assert_eq!(q.resize(0, 4), Ok(2));
                done.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        assert!(
            !done.load(Ordering::SeqCst),
            "resize must stay in its grace period while a reader is pinned"
        );
        unpin_tx.send(()).unwrap();
        reader.join().unwrap();
        resizer.join().unwrap();
        assert!(done.load(Ordering::SeqCst));
        assert_eq!(q.plan_epoch(), 2);
        assert!(q.draining_info(0).is_none(), "empty old plan still retires inside resize");
    }

    #[test]
    fn resize_rejects_bad_requests() {
        let (_p, q) = mk(2, 1);
        assert!(matches!(q.resize(0, 0), Err(QueueError::BadConfig(_))));
        assert!(matches!(
            q.resize(0, crate::queues::MAX_SHARDS + 1),
            Err(QueueError::BadConfig(_))
        ));
        assert_eq!(q.resize(0, 2), Ok(1), "same-k resize is a no-op at the current epoch");
        // A transition with residue blocks the next resize until drained.
        q.enqueue(0, 7).unwrap();
        assert_eq!(q.resize(0, 4), Ok(2));
        assert!(matches!(q.resize(0, 6), Err(QueueError::BadConfig(_))));
        assert_eq!(q.dequeue(1).unwrap(), Some(7));
        assert!(q.try_retire(1));
        assert_eq!(q.resize(0, 6), Ok(3), "drained transition unblocks the next resize");
    }

    #[test]
    fn resize_crash_mid_drain_converges_to_one_plan() {
        // Freeze with residue, crash before any consumer drains it:
        // recovery must adopt the new plan, move the residue over, retire
        // the old plan, and deliver everything exactly once.
        let (p, q) = mk_full(2, 4, 4, 0.0, 0.0);
        for v in 0..10u64 {
            q.enqueue(0, v).unwrap();
        }
        q.flush_all();
        assert_eq!(q.resize(0, 6), Ok(2));
        assert!(q.draining_info(0).is_some(), "residue keeps the transition open");
        let mut rng = Xoshiro256::seed_from(61);
        p.crash(&mut rng);
        q.recover(&p);
        assert!(q.draining_info(0).is_none(), "recovery must converge to one plan");
        assert_eq!(q.plan_epoch(), 2, "durably frozen transitions roll FORWARD");
        let mut got = drain(&q, 0);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "recovery drain duplicated items");
        assert_eq!(got, (0..10).collect::<Vec<u64>>(), "recovery drain lost items");
        // Stability: another crash after convergence changes nothing.
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.plan_epoch(), 2);
        assert_eq!(drain(&q, 0), Vec::<u64>::new());
    }

    #[test]
    fn resize_crash_before_commit_keeps_old_plan() {
        // The staged record is written but the freeze never psyncs: the
        // crash lands on Active(old); recovery prunes the staged plan.
        let (p, q) = mk(2, 1);
        for v in 0..6u64 {
            q.enqueue(0, v).unwrap();
        }
        // Replay resize's staging by hand, stopping before the commit.
        {
            let _g = q.resize_guard();
            q.plan_log.write_record(&p, 0, 1, 2, &[0, 0, 0]);
            p.psync(0);
            q.plan_log.set_freezing(&p, 0, 0, 2); // pwb queued, psync never runs
        }
        let mut rng = Xoshiro256::seed_from(62);
        p.crash(&mut rng);
        q.recover(&p);
        // Either outcome of the torn commit is a single coherent plan;
        // with no registered epoch-2 structs the state must be Active(1)
        // (pending_flush_prob = 0 drops the unsynced state line).
        assert_eq!(q.plan_epoch(), 1, "uncommitted freeze must roll back");
        assert_eq!(q.shard_count(), 2);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, (0..6).collect::<Vec<u64>>());
    }

    #[test]
    fn mixed_epoch_batch_log_reconciles_across_resize() {
        // A sealed enqueue batch spanning two plan generations: entries
        // must reconcile against the generation they were recorded under.
        let (p, q) = mk(2, 4);
        q.enqueue(0, 0).unwrap();
        q.enqueue(0, 1).unwrap(); // two epoch-1 entries in the filling batch
        assert_eq!(q.resize(0, 4), Ok(2));
        q.enqueue(0, 2).unwrap();
        q.enqueue(0, 3).unwrap(); // batch of 4 full -> sealed + psynced (mixed epochs)
        let mut rng = Xoshiro256::seed_from(63);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "mixed-epoch reconciliation duplicated items");
        assert_eq!(got, vec![0, 1, 2, 3], "mixed-epoch reconciliation lost items");
    }

    #[test]
    fn resize_under_concurrent_traffic_and_crashes() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        let topo = Topology::new(
            PmemConfig {
                capacity_words: 1 << 22,
                cost: CostModel::zero(),
                evict_prob: 0.3,
                pending_flush_prob: 0.5,
                seed: 24,
            },
            2,
        );
        let cfg = QueueConfig {
            shards: 4,
            batch: 4,
            batch_deq: 4,
            ring_size: 64,
            ..Default::default()
        };
        let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4, cfg).unwrap());
        let mut rng = Xoshiro256::seed_from(25);
        let mut returned: Vec<u64> = Vec::new();
        for cycle in 0..4u64 {
            topo.arm_crash_after(2_000 + rng.next_below(2_000));
            let resize_at = 300 + rng.next_below(20_000);
            let target_k = [2usize, 6, 8, 3][cycle as usize];
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                let base = cycle * 4_000_000 + tid as u64 * 1_000_000;
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..30_000u64 {
                            // Thread 0 triggers an online resize mid-run.
                            if tid == 0 && i == resize_at {
                                let _ = q.resize(tid, target_k);
                            }
                            q.enqueue(tid, base + i).unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            topo.crash(&mut rng);
            q.recover(topo.primary());
            assert!(
                q.draining_info(0).is_none(),
                "every recovery must converge to exactly one plan"
            );
        }
        while let Some(v) = q.dequeue(0).unwrap() {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate across resize + crash cycles");
    }
}
