//! `ShardedQueue` — a K-way striped, optionally batch-persisted FIFO layer
//! over the paper's persistent queues (PerLCRQ by default).
//!
//! The paper's core insight is that persistence cost is governed by *where*
//! the `pwb`+`psync` pair lands: low-contention locations scale, hot spots
//! do not. A single PerLCRQ still funnels every thread through one
//! `Head`/`Tail` FAI pair. This subsystem takes the next step the related
//! work points at (BlockFIFO/MultiFIFO's relaxed sharded designs, and the
//! *Durable Queues: The Second Amendment* batching idea):
//!
//! * **Sharding** — operations stripe across `K = QueueConfig::shards`
//!   inner persistent queues via a per-thread round-robin ticket, dividing
//!   the FAI serialization chains (and the hot `Tail` flush traffic) by
//!   `K`. FIFO becomes *relaxed*: a dequeue may overtake items that sit in
//!   sibling shards, bounded by the shard skew. Histories are checked with
//!   [`crate::verify::check_relaxed`], which accepts at most `k`
//!   out-of-order dequeues per operation.
//! * **Batching** — with `QueueConfig::batch = B > 1`, enqueues run in
//!   group-commit mode: each op issues its cell `pwb` but *defers* the
//!   `psync` ([`crate::queues::crq::PersistCfg::defer_enqueue_sync`]); every
//!   `B`-th enqueue seals the thread's persistent [`batch`] log and issues
//!   **one `psync`** that realizes the whole batch (log lines + all
//!   deferred cell flushes) in a single drain. Amortized persistence:
//!   `1/B` psyncs per enqueue. Dequeues keep their per-op pair — an item
//!   must be durably consumed before it is returned.
//!
//! ## Durability contract under batching
//!
//! A batched enqueue is durably linearized **at the flush**, not at its
//! return ("buffered durable linearizability" — the same contract as group
//! commit in databases). A crash can therefore lose at most the last
//! `B − 1` *unflushed* enqueues of each thread; the checker accounts for
//! exactly that window via `CheckOptions::trailing_loss_per_thread`.
//!
//! ## Crash recovery and batch reconciliation
//!
//! [`ShardedQueue::recover`] re-runs each shard's recovery, then reconciles
//! in-flight batches from the per-thread logs. For every entry of a sealed
//! log (`item`, shard, node, ring index, seq) it decides:
//!
//! * ring `Head > idx` → **settled**: the position was durably consumed or
//!   passed. Crucially, a dequeue only *returns* an item after its
//!   `persist_head` pair completes, so `Head ≤ idx` proves the item was
//!   never handed to any caller — re-inserting it cannot duplicate.
//! * cell at `idx` still holds `item` → **present**: nothing to do.
//! * otherwise → **missing**: the cell flush never landed; the item is
//!   re-enqueued (it lands at the tail — a bounded relaxation the relaxed
//!   checker absorbs).
//!
//! Logs are retired durably after reconciliation so a later crash cannot
//! replay them; batch sequence numbers stored in every entry detect torn
//! logs (header and entry lines realized independently at a crash).

pub mod batch;

use std::cell::UnsafeCell;
use std::sync::Arc;

use crossbeam_utils::CachePadded;

use super::perlcrq::PerLcrq;
use super::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError};
use crate::pmem::{PAddr, PmemPool};

use self::batch::BatchLog;

/// Where a traced enqueue landed: the LCRQ node and the ring index within
/// it. Stable across crashes (node addresses are arena offsets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EnqPos {
    pub node: PAddr,
    pub idx: u64,
}

/// Reconciliation verdict for a logged batch entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Probe {
    /// The position was durably consumed or passed — do not re-insert.
    Settled,
    /// The item is still durably present at its logged position.
    Present,
    /// The item is gone and provably was never returned to a caller:
    /// re-insertion is safe.
    Missing,
}

/// An inner queue the sharded layer can stripe over: a persistent queue
/// that can additionally report *where* an enqueue landed and answer
/// recovery probes about logged positions.
pub trait Shardable: PersistentQueue {
    /// Enqueue and report the landing position.
    fn enqueue_traced(&self, tid: usize, item: u64) -> Result<EnqPos, QueueError>;

    /// Post-crash, post-recovery: classify a logged `(pos, item)` pair.
    /// Single-threaded (recovery context).
    fn probe(&self, tid: usize, pos: &EnqPos, item: u64) -> Probe;

    /// Cheap, non-linearizable emptiness hint used by the dequeue scan to
    /// skip shards that currently look empty. Must never report `false`
    /// while an item whose enqueue completed before the call started is
    /// still in the queue (reads of live state satisfy this). Defaults to
    /// "always probe".
    fn maybe_nonempty(&self, _tid: usize) -> bool {
        true
    }
}

impl Shardable for PerLcrq {
    fn enqueue_traced(&self, tid: usize, item: u64) -> Result<EnqPos, QueueError> {
        let (node, idx) = self.core().enqueue_at(tid, item)?;
        Ok(EnqPos { node, idx })
    }

    fn probe(&self, tid: usize, pos: &EnqPos, item: u64) -> Probe {
        let core = self.core();
        let pool = &core.pool;
        let ring = core.ring_of(pos.node);
        let (head, _tail) = ring.endpoints(pool, tid);
        if head > pos.idx {
            // A dequeue returns only after its persist_head pair, so a
            // durable Head past idx means the position is accounted for.
            return Probe::Settled;
        }
        let u = pos.idx % ring.ring_size as u64;
        let (_uns, idx, val) = ring.read_cell(pool, tid, u);
        if idx == pos.idx && val == item + 1 {
            Probe::Present
        } else {
            Probe::Missing
        }
    }

    fn maybe_nonempty(&self, tid: usize) -> bool {
        let core = self.core();
        let pool = &core.pool;
        let first = PAddr::from_u64(pool.load(tid, core.first));
        if first.is_null() {
            return true; // defensive: always probe
        }
        let (head, tail) = core.ring_of(first).endpoints(pool, tid);
        // Items in the first ring, or a successor node (next ptr at node+0).
        tail > head || pool.load(tid, first) != 0
    }
}

/// Per-thread volatile dispatch state. Slot `tid` is touched only by the
/// thread running as `tid` while workers are live, and by the single
/// coordinator thread (recovery, `flush_all`) after all workers have
/// stopped — the same exclusive-logical-owner pattern as the pool's
/// pending-flush slots.
#[derive(Default)]
struct SlotState {
    /// Round-robin enqueue ticket.
    ticket: u64,
    /// Dequeue scan start.
    cursor: usize,
    /// Entries recorded in the filling batch.
    pending: usize,
    /// Current batch sequence number (starts at 1; 0 is "never sealed").
    seq: u64,
}

struct Slot(UnsafeCell<SlotState>);

unsafe impl Sync for Slot {}

/// The sharded (and optionally batched) persistent queue. See module docs.
pub struct ShardedQueue<Q: Shardable = PerLcrq> {
    pool: Arc<PmemPool>,
    shards: Vec<Q>,
    nshards: usize,
    batch: usize,
    nthreads: usize,
    slots: Vec<CachePadded<Slot>>,
    /// Per-thread persistent batch logs (empty when `batch == 1`).
    logs: Vec<BatchLog>,
    name: &'static str,
}

impl ShardedQueue<PerLcrq> {
    /// The default construction: `cfg.shards` PerLCRQ shards, batched when
    /// `cfg.batch > 1`. Fails with [`QueueError::BadConfig`] on zero
    /// shards/batch (and the other `QueueConfig::validate` rules) instead
    /// of panicking.
    pub fn new_perlcrq(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: QueueConfig,
    ) -> Result<Self, QueueError> {
        cfg.validate()?;
        let mut shard_cfg = cfg.clone();
        // Batched mode defers the enqueue-cell psync to the flush; plain
        // sharding keeps the paper's per-op pair.
        shard_cfg.defer_enqueue_sync = cfg.batch > 1;
        let shards: Vec<PerLcrq> = (0..cfg.shards)
            .map(|_| PerLcrq::new(pool, nthreads, shard_cfg.clone()))
            .collect();
        Self::from_shards(pool, nthreads, &cfg, shards, "sharded-perlcrq")
    }
}

impl<Q: Shardable> ShardedQueue<Q> {
    /// Generic construction over caller-built shards. The shards must
    /// already be configured consistently with `cfg` (in particular,
    /// `defer_enqueue_sync` iff `cfg.batch > 1`).
    pub fn from_shards(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: &QueueConfig,
        shards: Vec<Q>,
        name: &'static str,
    ) -> Result<Self, QueueError> {
        cfg.validate()?;
        if shards.is_empty() {
            return Err(QueueError::BadConfig("at least one shard is required"));
        }
        let nshards = shards.len();
        let logs = if cfg.batch > 1 {
            (0..nthreads).map(|_| BatchLog::alloc(pool, cfg.batch)).collect()
        } else {
            Vec::new()
        };
        Ok(Self {
            pool: Arc::clone(pool),
            shards,
            nshards,
            batch: cfg.batch,
            nthreads,
            slots: (0..nthreads)
                .map(|_| {
                    CachePadded::new(Slot(UnsafeCell::new(SlotState {
                        seq: 1,
                        ..Default::default()
                    })))
                })
                .collect(),
            logs,
            name,
        })
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.nshards
    }

    /// Configured batch size (1 = per-op persistence).
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    #[allow(clippy::mut_from_ref)]
    fn slot(&self, tid: usize) -> &mut SlotState {
        // SAFETY: exclusive-logical-owner — see SlotState docs.
        unsafe { &mut *self.slots[tid].0.get() }
    }

    fn enqueue_impl(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        let slot = self.slot(tid);
        let shard = (slot.ticket % self.nshards as u64) as usize;
        slot.ticket += 1;
        if self.batch <= 1 {
            return self.shards[shard].enqueue(tid, item);
        }
        let pos = self.shards[shard].enqueue_traced(tid, item)?;
        let i = slot.pending;
        self.logs[tid].record(&self.pool, tid, i, item, shard, &pos, slot.seq);
        slot.pending = i + 1;
        if slot.pending >= self.batch {
            self.flush(tid);
        }
        Ok(())
    }

    /// Flush thread `tid`'s filling batch: seal the log and issue the
    /// batch's single `psync` (draining the log lines and every deferred
    /// cell `pwb` at once). No-op when nothing is pending or batching is
    /// off.
    pub fn flush(&self, tid: usize) {
        if self.batch <= 1 {
            return;
        }
        let slot = self.slot(tid);
        if slot.pending == 0 {
            return;
        }
        self.logs[tid].seal(&self.pool, tid, slot.pending, slot.seq);
        self.pool.psync(tid);
        slot.pending = 0;
        slot.seq += 1;
    }

    /// Flush every thread's pending batch. **Quiescent contexts only**
    /// (all workers stopped): the caller acts as each thread in turn, the
    /// same contract as [`PmemPool::crash`]. Used before a final drain.
    pub fn flush_all(&self) {
        for t in 0..self.nthreads {
            self.flush(t);
        }
    }

    fn dequeue_impl(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let slot = self.slot(tid);
        let start = slot.cursor;
        for i in 0..self.nshards {
            let s = (start + i) % self.nshards;
            if !self.shards[s].maybe_nonempty(tid) {
                continue;
            }
            if let Some(v) = self.shards[s].dequeue(tid)? {
                slot.cursor = (s + 1) % self.nshards;
                return Ok(Some(v));
            }
        }
        Ok(None)
    }

    /// Post-recovery batch reconciliation (single-threaded). See module
    /// docs for the soundness argument.
    fn reconcile(&self, pool: &PmemPool) {
        let tid = 0;
        for t in 0..self.nthreads {
            let (count, seq) = self.logs[t].header(pool, tid);
            if count == 0 || seq == 0 {
                continue;
            }
            for i in 0..count.min(self.batch) {
                let e = self.logs[t].entry(pool, tid, i);
                if e.seq != seq || e.enc_item == 0 || e.shard >= self.nshards {
                    continue; // torn or garbage entry — stale seq, skip
                }
                let item = e.enc_item - 1;
                let pos = EnqPos { node: e.node, idx: e.idx };
                if self.shards[e.shard].probe(tid, &pos, item) == Probe::Missing {
                    // Never returned to any caller (Head ≤ idx) and not in
                    // NVM: re-insert. Lands at the tail; the relaxed-FIFO
                    // checker absorbs the displacement.
                    let _ = self.shards[e.shard].enqueue(tid, item);
                }
            }
            self.logs[t].clear(pool, tid);
        }
        // One drain realizes the log retirements and any deferred cell
        // pwbs from re-insertions.
        pool.psync(tid);
    }
}

impl<Q: Shardable> ConcurrentQueue for ShardedQueue<Q> {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.enqueue_impl(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.dequeue_impl(tid)
    }

    fn name(&self) -> &'static str {
        self.name
    }
}

impl<Q: Shardable> PersistentQueue for ShardedQueue<Q> {
    fn quiesce(&self) {
        self.flush_all();
    }

    fn recover(&self, pool: &PmemPool) {
        for s in &self.shards {
            s.recover(pool);
        }
        if self.batch > 1 {
            self.reconcile(pool);
        }
        // Reset volatile dispatch state; bump seq so fresh batches can
        // never collide with stale (already reconciled) log entries.
        for t in 0..self.nthreads {
            let slot = self.slot(t);
            slot.ticket = 0;
            slot.cursor = 0;
            slot.pending = 0;
            slot.seq += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(shards: usize, batch: usize) -> (Arc<PmemPool>, ShardedQueue) {
        mk_probs(shards, batch, 0.0, 0.0)
    }

    fn mk_probs(
        shards: usize,
        batch: usize,
        evict: f64,
        pending: f64,
    ) -> (Arc<PmemPool>, ShardedQueue) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 22,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed: 21,
        }));
        let cfg = QueueConfig { shards, batch, ring_size: 64, ..Default::default() };
        let q = ShardedQueue::new_perlcrq(&pool, 8, cfg).unwrap();
        (pool, q)
    }

    fn drain(q: &ShardedQueue, tid: usize) -> Vec<u64> {
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(tid).unwrap() {
            out.push(v);
        }
        out
    }

    #[test]
    fn bad_configs_rejected_not_panicking() {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 16,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 1,
        }));
        for cfg in [
            QueueConfig { shards: 0, ..Default::default() },
            QueueConfig { batch: 0, ..Default::default() },
            QueueConfig { batch: crate::queues::MAX_BATCH + 1, ..Default::default() },
        ] {
            assert!(matches!(
                ShardedQueue::new_perlcrq(&pool, 4, cfg),
                Err(QueueError::BadConfig(_))
            ));
        }
    }

    #[test]
    fn lockstep_round_robin_is_fifo() {
        // Single thread, enqueue and dequeue cursors advance in lockstep:
        // the relaxed queue degenerates to exact FIFO.
        let (_p, q) = mk(4, 1);
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(drain(&q, 0), (0..32).collect::<Vec<u64>>());
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn all_items_survive_unbatched_crash() {
        let (p, q) = mk(4, 1);
        for v in 0..60u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut got = Vec::new();
        for _ in 0..25 {
            got.push(q.dequeue(1).unwrap().expect("item"));
        }
        let mut rng = Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        q.recover(&p);
        got.extend(drain(&q, 0));
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "duplicates across crash");
        assert_eq!(got, (0..60).collect::<Vec<u64>>(), "items lost across crash");
    }

    #[test]
    fn batch_amortizes_psyncs() {
        let (p, q) = mk(2, 8);
        p.stats.reset();
        for v in 0..7u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(p.stats.total().psyncs, 0, "no psync before the batch fills");
        q.enqueue(0, 7).unwrap(); // 8th op seals + syncs
        let s = p.stats.total();
        assert_eq!(s.psyncs, 1, "exactly one psync per batch of 8");
        assert!(s.pwbs >= 8, "each op still issues its cell pwb");
        // Unbatched comparison: one psync per op.
        let (p1, q1) = mk(2, 1);
        p1.stats.reset();
        for v in 0..8u64 {
            q1.enqueue(0, v).unwrap();
        }
        assert_eq!(p1.stats.total().psyncs, 8);
    }

    #[test]
    fn flushed_batch_survives_crash() {
        let (p, q) = mk(2, 4);
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap(); // two full batches, both flushed
        }
        let mut rng = Xoshiro256::seed_from(6);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn unflushed_tail_may_vanish_without_corruption() {
        // 3 enqueues into a batch of 8, never flushed, nothing persisted
        // (evict/pending = 0): the items are lost — the buffered-durability
        // contract — but the queue recovers clean and functional.
        let (p, q) = mk(2, 8);
        for v in 0..3u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(drain(&q, 0), Vec::<u64>::new());
        q.enqueue(0, 99).unwrap();
        q.flush(0);
        assert_eq!(q.dequeue(1).unwrap(), Some(99));
    }

    #[test]
    fn explicit_flush_makes_partial_batch_durable() {
        let (p, q) = mk(2, 8);
        for v in 0..3u64 {
            q.enqueue(0, v).unwrap();
        }
        q.flush_all();
        let mut rng = Xoshiro256::seed_from(8);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2]);
    }

    #[test]
    fn reconciliation_reinserts_lost_cells_from_sealed_log() {
        // Seal a batch durably, then wipe the items' cells in NVM
        // (simulating cell flushes that never landed while the log line
        // did): recovery must re-insert every item from the log.
        let (p, q) = mk(1, 4);
        for v in 10..14u64 {
            q.enqueue(0, v).unwrap(); // fills + flushes one batch
        }
        let core = q.shards[0].core();
        let first = PAddr::from_u64(p.peek(core.first));
        let ring = core.ring_of(first);
        for u in 0..4u64 {
            ring.write_cell(&p, 0, u, false, u, 0 /* BOT */);
        }
        p.persist_range(0, ring.cell_addr(0), 8);
        // Undo the durable retire so the log still claims the batch: the
        // simplest way is to crash BEFORE recovery ran — the log header was
        // sealed by the flush and is only cleared during recover().
        let mut rng = Xoshiro256::seed_from(9);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        got.sort_unstable();
        assert_eq!(got, vec![10, 11, 12, 13], "log reconciliation must re-insert");
    }

    #[test]
    fn reconciliation_never_duplicates_consumed_items() {
        // Flush a batch, consume part of it (durable head persists), crash
        // with the log still sealed: reconciliation must re-insert nothing.
        let (p, q) = mk(1, 4);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.dequeue(1).unwrap(), Some(0));
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
        let mut rng = Xoshiro256::seed_from(10);
        p.crash(&mut rng);
        q.recover(&p);
        let got = drain(&q, 0);
        assert_eq!(got, vec![2, 3], "consumed items must not reappear: {got:?}");
    }

    #[test]
    fn double_crash_after_reconciliation_is_stable() {
        let (p, q) = mk(2, 4);
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(11);
        p.crash(&mut rng);
        q.recover(&p);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = drain(&q, 0);
        let n = got.len();
        got.sort_unstable();
        got.dedup();
        assert_eq!(got.len(), n, "double crash produced duplicates");
        assert_eq!(got, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn randomized_crash_cycles_no_duplicates() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 23,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed: 12,
        }));
        let cfg = QueueConfig { shards: 4, batch: 4, ring_size: 64, ..Default::default() };
        let q = Arc::new(ShardedQueue::new_perlcrq(&pool, 4, cfg).unwrap());
        let mut rng = Xoshiro256::seed_from(13);
        let mut returned: Vec<u64> = Vec::new();
        for cycle in 0..5u64 {
            pool.arm_crash_after(2_000 + rng.next_below(2_000));
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                let base = cycle * 4_000_000 + tid as u64 * 1_000_000;
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..100_000u64 {
                            q.enqueue(tid, base + i).unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            pool.crash(&mut rng);
            q.recover(&pool);
        }
        while let Some(v) = q.dequeue(0).unwrap() {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate item observed across crash cycles");
    }
}
