//! Epoch-pinned plan access — the lock-free replacement for the per-op
//! `RwLock<PlanSet>` read guard.
//!
//! ## Why
//!
//! Since elastic re-sharding landed, every enqueue/dequeue acquired a
//! `RwLock` read guard for the whole operation so a plan flip (the write
//! lock) would linearize against in-flight ops. Correct — but the lock
//! word itself is a single cache line every thread RMWs twice per op, a
//! straight-line scalability tax paid by 100% of operations to protect a
//! transition that happens approximately never. This module replaces it
//! with epoch-based pinning in the crossbeam-epoch idiom, hand-rolled on
//! std atomics: steady-state readers touch **only their own cache-padded
//! slot**, and the (rare, already-serialized) plan writer pays the whole
//! cost of synchronization by waiting out a grace period.
//!
//! ## The protocol
//!
//! Each reader thread owns one cache-padded slot holding a `seq` word:
//! **even = quiescent, odd = pinned**. Only the owner writes it.
//!
//! * **Pin** (outermost): `seq ← seq + 1` (now odd, `Relaxed`), then a
//!   `SeqCst` fence, then load the plan pointer (`Acquire`). Nested pins
//!   only bump an owner-local depth counter — re-entrancy is free.
//! * **Unpin** (outermost): `seq ← seq + 1` (now even, `Release`) — the
//!   release makes every access to the pinned snapshot happen-before a
//!   writer that observes the new value.
//! * **Flip** (writer): swap the [`PlanCell`] pointer (`AcqRel`), then a
//!   `SeqCst` fence, then [`EpochRegistry::wait_grace`]: for every slot,
//!   read `seq`; if odd, spin until the value *changes*. Only after the
//!   sweep may the displaced snapshot be freed
//!   ([`Retired::free_after_grace`] packages flip → grace → free).
//!
//! **Why this is safe.** The two `SeqCst` fences totally order every pin
//! against every flip. If the writer's post-swap fence precedes a pin's
//! fence, that pin's pointer load sees the *new* pointer — the old
//! snapshot gains no new readers after the sweep begins. If the pin's
//! fence came first, the writer observes the odd `seq` and waits; the
//! reader's unpin (release store) then happens-before the writer's
//! acquire re-read, so every use of the old snapshot completes before it
//! is freed. A reader that re-pins mid-sweep flips `seq` odd→even→odd:
//! the writer only waits for the value to *change*, which is exactly
//! right — the new pin's pointer load is fenced after the swap and can
//! only see the new pointer.
//!
//! **What a pin guarantees** (and what it doesn't): a pinned snapshot
//! stays *allocated* and internally consistent until unpin — it does
//! **not** stay *current*. A reader pinned across a flip keeps operating
//! on the displaced plan set; the writer's grace wait is therefore part
//! of the transition's correctness story (see `sharded/mod.rs::resize`:
//! residue accounting and retirement verification run only after the
//! sweep, when no stale reader can still enqueue into a frozen stripe).
//!
//! **Progress.** Readers are wait-free (one owned-line store + fence).
//! The writer spins — bounded rounds of `spin_loop`, then
//! `yield_now` escalation — and blocks for as long as some reader stays
//! pinned: a stalled reader stalls *retirement*, never other readers.
//! Grace waits are volatile-only (no `pwb`/`psync`), so the re-sharding
//! psync budget (`new_k + 3`) is untouched — `tests/obs_ledger.rs`
//! asserts this.
//!
//! Pin/unpin totals, plan-pointer flips and a grace-wait spin histogram
//! are exported: the per-slot counters through
//! `ShardedQueue::metric_families`, the histogram through the global
//! [`crate::obs::registry`] (cold writer path only).

use std::cell::UnsafeCell;
use std::marker::PhantomData;
use std::ops::Deref;
use std::sync::atomic::{fence, AtomicPtr, AtomicU64, Ordering};
use std::sync::Arc;

use crossbeam_utils::CachePadded;

/// Spin rounds per still-pinned slot before escalating to
/// `thread::yield_now` (the overall wait is unbounded by design: a
/// pinned snapshot must never be freed).
const SPIN_ROUNDS: u32 = 64;

/// One reader thread's epoch slot. `seq` parity is the pin flag (even =
/// quiescent, odd = pinned); only the owning thread stores to it, so
/// plain load+store (no RMW) suffices. `depth` is the owner-only nesting
/// counter — it never needs to be visible to writers, because a nested
/// pin cannot change parity.
struct ReaderSlot {
    seq: AtomicU64,
    depth: UnsafeCell<u32>,
}

// SAFETY: `depth` is accessed only by the slot's owning thread (the same
// exclusive-logical-owner contract as the queue's `SlotState`); `seq` is
// an atomic.
unsafe impl Sync for ReaderSlot {}

/// The per-thread epoch registry: `nthreads` cache-padded
/// [`ReaderSlot`]s plus writer-side flip/grace counters.
pub struct EpochRegistry {
    slots: Vec<CachePadded<ReaderSlot>>,
    /// Plan-pointer flips swept through this registry (writer-only).
    flips: AtomicU64,
    /// Cumulative spin rounds spent in grace waits (writer-only).
    grace_spins: AtomicU64,
}

impl EpochRegistry {
    pub fn new(nthreads: usize) -> Self {
        Self {
            slots: (0..nthreads.max(1))
                .map(|_| {
                    CachePadded::new(ReaderSlot {
                        seq: AtomicU64::new(0),
                        depth: UnsafeCell::new(0),
                    })
                })
                .collect(),
            flips: AtomicU64::new(0),
            grace_spins: AtomicU64::new(0),
        }
    }

    /// Pin thread `tid`'s slot and load `cell`'s current snapshot. The
    /// returned guard derefs to the snapshot and unpins on drop —
    /// including unwinds, which matters because pmem primitives can
    /// unwind with a simulated-crash signal mid-operation. Nested pins
    /// are cheap (depth bump only) and may observe a *newer* snapshot
    /// than the outer pin: both are protected, because the slot has been
    /// continuously pinned since before either could be retired.
    #[inline]
    pub fn pin<'e, T>(&'e self, cell: &'e PlanCell<T>, tid: usize) -> PlanPin<'e, T> {
        let slot = &*self.slots[tid];
        // SAFETY: owner-only access (see ReaderSlot).
        let depth = unsafe { &mut *slot.depth.get() };
        if *depth == 0 {
            let s = slot.seq.load(Ordering::Relaxed);
            debug_assert_eq!(s & 1, 0, "outermost pin from a quiescent slot");
            slot.seq.store(s + 1, Ordering::Relaxed);
            // Totally ordered against the writer's post-swap fence: see
            // the module docs' safety argument.
            fence(Ordering::SeqCst);
        }
        *depth += 1;
        let ptr = cell.ptr.load(Ordering::Acquire);
        PlanPin { slot, ptr, _life: PhantomData }
    }

    /// Pin thread `tid`'s slot without loading a [`PlanCell`] — for
    /// callers (e.g. the base LCRQ's node-recycling path) that protect a
    /// raw persistent pointer rather than a published `Arc` snapshot. The
    /// guard participates in the same grace protocol as [`Self::pin`]:
    /// memory retired while the guard is live is not recycled until the
    /// slot passes through a quiescent state.
    #[inline]
    pub fn pin_bare(&self, tid: usize) -> BarePin<'_> {
        let slot = &*self.slots[tid];
        // SAFETY: owner-only access (see ReaderSlot).
        let depth = unsafe { &mut *slot.depth.get() };
        if *depth == 0 {
            let s = slot.seq.load(Ordering::Relaxed);
            debug_assert_eq!(s & 1, 0, "outermost pin from a quiescent slot");
            slot.seq.store(s + 1, Ordering::Relaxed);
            fence(Ordering::SeqCst);
        }
        *depth += 1;
        BarePin { slot, _nosend: PhantomData }
    }

    /// Capture the current seq word of every slot — the non-blocking half
    /// of the grace protocol. A retirer that cannot afford to block (or
    /// that runs *while pinned itself*, where [`Self::wait_grace`] would
    /// self-deadlock) snapshots at retire time and later polls
    /// [`Self::has_elapsed`]: once every slot that was pinned at snapshot
    /// time has changed its seq, no reader can still hold a reference
    /// taken before the retire point.
    ///
    /// The caller must order its retirement (pointer unlink / swap) before
    /// taking the snapshot, exactly as [`PlanCell::swap`] orders its swap
    /// before the grace sweep; the `SeqCst` fence here pairs with the pin
    /// fence the same way.
    pub fn snapshot(&self) -> GraceSnapshot {
        fence(Ordering::SeqCst);
        GraceSnapshot {
            seqs: self.slots.iter().map(|s| s.seq.load(Ordering::Acquire)).collect(),
        }
    }

    /// Has a grace period elapsed since `snap` was taken? Non-blocking:
    /// a slot is clear if it was quiescent (even seq) at snapshot time or
    /// has advanced since. Safe to call from any thread, pinned or not.
    pub fn has_elapsed(&self, snap: &GraceSnapshot) -> bool {
        if snap.seqs.len() != self.slots.len() {
            return false; // foreign snapshot — never vouch for it
        }
        self.slots.iter().zip(snap.seqs.iter()).all(|(slot, &s)| {
            s & 1 == 0 || slot.seq.load(Ordering::Acquire) != s
        })
    }

    /// Writer-side grace period: returns once every slot that was pinned
    /// at some point after the caller's pointer swap has passed through
    /// a quiescent state. Volatile-only (no pmem traffic). Returns the
    /// spin rounds burned (0 on the fast path — nobody pinned).
    ///
    /// The caller must not hold a pin on `tid`'s own slot (it would wait
    /// on itself forever); `dequeue_impl` drops its pin before retiring
    /// for exactly this reason.
    pub fn wait_grace(&self, tid: usize) -> u64 {
        debug_assert_eq!(
            // SAFETY: owner-only read of the caller's own slot.
            unsafe { *self.slots[tid].depth.get() },
            0,
            "wait_grace while holding a pin would self-deadlock"
        );
        let mut rounds = 0u64;
        for slot in &self.slots {
            let s = slot.seq.load(Ordering::Acquire);
            if s & 1 == 0 {
                continue; // quiescent — the SeqCst fences order its next pin after our swap
            }
            let mut spins = 0u32;
            while slot.seq.load(Ordering::Acquire) == s {
                spins += 1;
                rounds += 1;
                if spins >= SPIN_ROUNDS {
                    std::thread::yield_now();
                } else {
                    std::hint::spin_loop();
                }
            }
        }
        if rounds > 0 {
            self.grace_spins.fetch_add(rounds, Ordering::Relaxed);
        }
        crate::obs::registry()
            .histogram(
                "persiq_epoch_grace_wait_rounds",
                "Spin rounds burned per plan-writer grace period",
            )
            .record(tid, rounds);
        rounds
    }

    /// Outermost pins taken across all slots since construction. Derived
    /// from the seq words: each full pin/unpin cycle advances a slot's
    /// seq by 2, a live pin by 1.
    pub fn pins_total(&self) -> u64 {
        self.slots.iter().map(|s| s.seq.load(Ordering::Relaxed).div_ceil(2)).sum()
    }

    /// Completed unpins across all slots (= [`Self::pins_total`] minus
    /// currently-live pins).
    pub fn unpins_total(&self) -> u64 {
        self.slots.iter().map(|s| s.seq.load(Ordering::Relaxed) / 2).sum()
    }

    /// Plan-pointer flips swept through this registry.
    pub fn flips_total(&self) -> u64 {
        self.flips.load(Ordering::Relaxed)
    }

    /// Cumulative grace-wait spin rounds (0 in steady state).
    pub fn grace_spins_total(&self) -> u64 {
        self.grace_spins.load(Ordering::Relaxed)
    }
}

/// A captured per-slot seq vector: the token for non-blocking grace
/// detection (see [`EpochRegistry::snapshot`] /
/// [`EpochRegistry::has_elapsed`]).
#[derive(Clone, Debug)]
pub struct GraceSnapshot {
    seqs: Box<[u64]>,
}

/// RAII pin on one [`EpochRegistry`] slot without an associated
/// [`PlanCell`] load (see [`EpochRegistry::pin_bare`]). `!Send` by
/// construction: the unpin must run on the pinning thread.
pub struct BarePin<'e> {
    slot: &'e ReaderSlot,
    /// `&ReaderSlot` alone would be `Send`; the raw-pointer marker pins
    /// the guard to its thread like `PlanPin`.
    _nosend: PhantomData<*const ()>,
}

impl Drop for BarePin<'_> {
    #[inline]
    fn drop(&mut self) {
        // SAFETY: owner-only access (the guard is !Send).
        let depth = unsafe { &mut *self.slot.depth.get() };
        *depth -= 1;
        if *depth == 0 {
            let s = self.slot.seq.load(Ordering::Relaxed);
            debug_assert_eq!(s & 1, 1, "outermost unpin from a pinned slot");
            self.slot.seq.store(s + 1, Ordering::Release);
        }
    }
}

/// RAII pin on one [`EpochRegistry`] slot, dereferencing to the snapshot
/// loaded from the [`PlanCell`] at pin time. `!Send` by construction
/// (raw pointer): the unpin must run on the pinning thread.
pub struct PlanPin<'e, T> {
    slot: &'e ReaderSlot,
    ptr: *const T,
    _life: PhantomData<&'e T>,
}

impl<T> Deref for PlanPin<'_, T> {
    type Target = T;

    #[inline]
    fn deref(&self) -> &T {
        // SAFETY: the slot stays pinned for this guard's lifetime, so the
        // writer's grace sweep cannot have freed the snapshot.
        unsafe { &*self.ptr }
    }
}

impl<T> Drop for PlanPin<'_, T> {
    #[inline]
    fn drop(&mut self) {
        // SAFETY: owner-only access (the guard is !Send).
        let depth = unsafe { &mut *self.slot.depth.get() };
        *depth -= 1;
        if *depth == 0 {
            let s = self.slot.seq.load(Ordering::Relaxed);
            debug_assert_eq!(s & 1, 1, "outermost unpin from a pinned slot");
            // Release: every access through this pin happens-before a
            // writer that observes the even value.
            self.slot.seq.store(s + 1, Ordering::Release);
        }
    }
}

/// The published pointer readers pin: an `AtomicPtr` holding one strong
/// `Arc` reference. Pinned readers deref the raw pointer directly — zero
/// refcount traffic on the hot path — and writers swap + wait out a
/// grace period before dropping the displaced reference.
pub struct PlanCell<T> {
    ptr: AtomicPtr<T>,
}

impl<T> PlanCell<T> {
    pub fn new(v: Arc<T>) -> Self {
        Self { ptr: AtomicPtr::new(Arc::into_raw(v) as *mut T) }
    }

    /// Clone out the current snapshot **from the serialized writer side**
    /// (or any context where no flip can be concurrent, e.g. holding the
    /// resize lock, construction, quiescent recovery): safe there because
    /// only a concurrent swap-and-free could invalidate the pointer
    /// between load and refcount bump.
    pub fn load_owner(&self) -> Arc<T> {
        let p = self.ptr.load(Ordering::Acquire);
        // SAFETY: `p` carries the cell's strong reference and cannot be
        // retired concurrently (serialized-writer contract above).
        unsafe {
            Arc::increment_strong_count(p);
            Arc::from_raw(p)
        }
    }

    /// Publish `v`, returning the displaced snapshot as a [`Retired`]
    /// token the caller must run through a grace period before freeing.
    /// Serialized-writer contract (resize lock / recovery).
    #[must_use = "the displaced snapshot must be freed via free_after_grace (dropping the token leaks it)"]
    pub fn swap(&self, reg: &EpochRegistry, v: Arc<T>) -> Retired<T> {
        let old = self.ptr.swap(Arc::into_raw(v) as *mut T, Ordering::AcqRel);
        // Totally ordered against every reader's pin fence: readers that
        // pinned before this point are caught by the grace sweep; later
        // pins load the new pointer.
        fence(Ordering::SeqCst);
        reg.flips.fetch_add(1, Ordering::Relaxed);
        Retired { ptr: old }
    }
}

impl<T> Drop for PlanCell<T> {
    fn drop(&mut self) {
        // SAFETY: dropping the cell means no readers exist; reclaim the
        // strong reference the cell holds.
        unsafe { drop(Arc::from_raw(*self.ptr.get_mut())) }
    }
}

/// A displaced [`PlanCell`] snapshot awaiting its grace period. Dropping
/// the token without [`Retired::free_after_grace`] *leaks* the snapshot —
/// deliberately: an unwind (simulated crash) between swap and grace must
/// never free memory a stalled reader may still hold, and recovery
/// re-derives every volatile plan structure anyway.
pub struct Retired<T> {
    ptr: *const T,
}

// SAFETY: the token is just an owned strong reference in raw form.
unsafe impl<T: Send + Sync> Send for Retired<T> {}

impl<T> Retired<T> {
    /// Wait out a grace period on `reg` (see
    /// [`EpochRegistry::wait_grace`]), then drop the displaced strong
    /// reference. The registry must be the one the cell's readers pin
    /// through.
    pub fn free_after_grace(self, reg: &EpochRegistry, tid: usize) {
        reg.wait_grace(tid);
        // SAFETY: the grace sweep proves no pinned reader can still hold
        // this snapshot; the pointer carries one strong reference.
        unsafe { drop(Arc::from_raw(self.ptr)) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc;
    use std::time::Duration;

    #[test]
    fn pin_reads_published_value() {
        let reg = EpochRegistry::new(2);
        let cell = PlanCell::new(Arc::new(7u64));
        assert_eq!(*reg.pin(&cell, 0), 7);
        assert_eq!(reg.pins_total(), 1);
        assert_eq!(reg.unpins_total(), 1);
    }

    #[test]
    fn pins_nest_and_seq_parity_tracks_outermost_only() {
        let reg = EpochRegistry::new(1);
        let cell = PlanCell::new(Arc::new(1u64));
        {
            let outer = reg.pin(&cell, 0);
            assert_eq!(reg.pins_total(), 1, "outermost pin flips seq odd");
            {
                let inner = reg.pin(&cell, 0);
                assert_eq!(*inner, 1);
                assert_eq!(reg.pins_total(), 1, "nested pin must not advance seq");
                assert_eq!(reg.unpins_total(), 0, "slot is still pinned");
            }
            assert_eq!(reg.unpins_total(), 0, "inner drop must not unpin the slot");
            assert_eq!(*outer, 1);
        }
        assert_eq!(reg.unpins_total(), 1, "outermost drop unpins");
        // A fresh pin works after full unwind.
        assert_eq!(*reg.pin(&cell, 0), 1);
        assert_eq!(reg.pins_total(), 2);
    }

    #[test]
    fn swap_then_grace_frees_old_and_new_pins_see_new_value() {
        let reg = EpochRegistry::new(2);
        let old = Arc::new(1u64);
        let weak_old = Arc::downgrade(&old);
        let cell = PlanCell::new(old);
        let retired = cell.swap(&reg, Arc::new(2u64));
        assert_eq!(*reg.pin(&cell, 0), 2, "post-swap pins read the new snapshot");
        assert_eq!(reg.flips_total(), 1);
        retired.free_after_grace(&reg, 0);
        assert!(weak_old.upgrade().is_none(), "grace-freed snapshot must be dropped");
    }

    #[test]
    fn unwinding_through_a_pin_unpins_the_slot() {
        let reg = EpochRegistry::new(1);
        let cell = PlanCell::new(Arc::new(9u64));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _pin = reg.pin(&cell, 0);
            panic!("simulated crash signal");
        }));
        assert!(r.is_err());
        // The slot must be quiescent again: a grace sweep returns
        // immediately instead of hanging on the unwound pin.
        assert_eq!(reg.wait_grace(0), 0);
        assert_eq!(reg.pins_total(), reg.unpins_total());
    }

    /// The stalled-reader property: a writer's grace sweep must not
    /// complete — and the displaced snapshot must not be freed — while
    /// any reader stays pinned.
    #[test]
    fn grace_blocks_on_a_stalled_pinned_reader() {
        let reg = Arc::new(EpochRegistry::new(2));
        let cell = Arc::new(PlanCell::new(Arc::new(1u64)));
        let freed = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel();
        let (unpin_tx, unpin_rx) = mpsc::channel::<()>();
        let reader = {
            let (reg, cell) = (Arc::clone(&reg), Arc::clone(&cell));
            std::thread::spawn(move || {
                let pin = reg.pin(&cell, 0); // tid 0: the stalled reader
                ready_tx.send(*pin).unwrap();
                unpin_rx.recv().unwrap(); // stall while pinned
                assert_eq!(*pin, 1, "the pinned snapshot must stay readable while stalled");
            })
        };
        assert_eq!(ready_rx.recv().unwrap(), 1);
        let writer = {
            let (reg, cell, freed) = (Arc::clone(&reg), Arc::clone(&cell), Arc::clone(&freed));
            std::thread::spawn(move || {
                let retired = cell.swap(&reg, Arc::new(2u64));
                retired.free_after_grace(&reg, 1); // blocks on tid 0's pin
                freed.store(true, Ordering::SeqCst);
            })
        };
        // The writer must still be stuck in its grace wait while the
        // reader is pinned (generous sleep: a missed wait would pass
        // spuriously only if the OS starved the writer this whole time,
        // and the locked-in ordering below catches the real bug anyway).
        std::thread::sleep(Duration::from_millis(50));
        assert!(!freed.load(Ordering::SeqCst), "grace must not elapse under a live pin");
        unpin_tx.send(()).unwrap(); // reader unpins → grace elapses
        reader.join().unwrap();
        writer.join().unwrap();
        assert!(freed.load(Ordering::SeqCst));
        assert!(reg.grace_spins_total() > 0, "the sweep must have observed the pinned slot");
    }

    #[test]
    fn snapshot_elapses_only_after_pinned_slots_move() {
        let reg = EpochRegistry::new(2);
        // Quiescent registry: grace is immediate.
        assert!(reg.has_elapsed(&reg.snapshot()));
        let pin = reg.pin_bare(0);
        let snap = reg.snapshot();
        assert!(!reg.has_elapsed(&snap), "a live pin from before the snapshot blocks grace");
        // A slot pinned *after* the snapshot does not block it.
        let _other = reg.pin_bare(1);
        drop(pin);
        assert!(reg.has_elapsed(&snap), "the pre-snapshot pin unpinned — grace elapsed");
        // Re-pinning slot 0 does not resurrect the old snapshot's claim.
        let _re = reg.pin_bare(0);
        assert!(reg.has_elapsed(&snap));
    }

    #[test]
    fn bare_pins_nest_and_block_wait_grace() {
        let reg = EpochRegistry::new(1);
        {
            let _outer = reg.pin_bare(0);
            let snap = reg.snapshot();
            {
                let _inner = reg.pin_bare(0);
                assert!(!reg.has_elapsed(&snap));
            }
            assert!(!reg.has_elapsed(&snap), "inner drop must not unpin the slot");
        }
        assert_eq!(reg.pins_total(), reg.unpins_total());
        assert_eq!(reg.wait_grace(0), 0, "fully unpinned — sweep is immediate");
    }

    #[test]
    fn foreign_snapshot_never_vouches() {
        let a = EpochRegistry::new(2);
        let b = EpochRegistry::new(3);
        let snap = b.snapshot();
        assert!(!a.has_elapsed(&snap));
    }

    #[test]
    fn concurrent_readers_never_observe_a_freed_snapshot() {
        // Hammer pin/deref against swap+grace+free: under ASAN/Miri this
        // is the use-after-free probe; under plain test it checks values
        // are always one of the published generations.
        let nreaders = 3usize;
        let reg = Arc::new(EpochRegistry::new(nreaders + 1));
        let cell = Arc::new(PlanCell::new(Arc::new(0u64)));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..nreaders)
            .map(|tid| {
                let (reg, cell, stop) = (Arc::clone(&reg), Arc::clone(&cell), Arc::clone(&stop));
                std::thread::spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let v = *reg.pin(&cell, tid);
                        assert!(v >= last, "generations are monotone");
                        last = v;
                    }
                })
            })
            .collect();
        for g in 1..=64u64 {
            let retired = cell.swap(&reg, Arc::new(g));
            retired.free_after_grace(&reg, nreaders);
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(reg.flips_total(), 64);
    }
}
