//! Per-thread persistent batch logs for the sharded queue's amortized
//! ("group-commit") persistence mode — the Second-Amendment-style batching
//! idea adapted to this framework's explicit epoch persistency model.
//!
//! ## Layout
//!
//! Each thread owns a line-aligned, single-writer (`Hotness::Private`) log:
//!
//! ```text
//! line 0, word 0     : header = (seq << 8) | count      (0 = empty/retired)
//! line 1 + i/2,
//!   words 4·(i%2)..  : entry i = [item+1][plan<<40|shard<<32|node][ring idx][seq]
//! ```
//!
//! Entries are 4 words so an entry never straddles a cache line (lines are
//! the unit of crash-time atomicity in [`crate::pmem`]); each entry carries
//! the batch sequence number so a torn log — header line and entry lines
//! realized independently at a crash — is detected per entry instead of
//! misread: an entry whose `seq` disagrees with the header's is stale and
//! skipped during reconciliation.
//!
//! Entries are **plan-epoch-qualified** (the `plan` bits of word 1): a
//! shard index alone is ambiguous once the queue can re-shard online —
//! shard 3 of plan 2 and shard 3 of plan 3 are different rings on
//! possibly different pools. Reconciliation resolves each entry against
//! the plan generation it was recorded under (see
//! [`super::plan`]).
//!
//! ## Protocol (see [`super`] for the full correctness argument)
//!
//! * `record(i, …)` — plain stores while the batch fills (cheap: private
//!   line, no flush).
//! * `seal(count, seq)` — write the header and `pwb` the touched lines; the
//!   caller then issues **one `psync`** that realizes the log *and* all the
//!   batch's deferred cell flushes together.
//! * `clear()` — recovery retires a reconciled log durably (header := 0) so
//!   a later crash cannot replay it.

use crate::pmem::{Hotness, PAddr, PmemPool, WORDS_PER_LINE};

use super::EnqPos;

/// Words per log entry (item, shard|node, ring index, batch seq).
const ENTRY_WORDS: usize = 4;
/// Entries per cache line (entries must not straddle lines).
const ENTRIES_PER_LINE: usize = WORDS_PER_LINE / ENTRY_WORDS;

/// A decoded log entry.
#[derive(Clone, Copy, Debug)]
pub(crate) struct LogEntry {
    /// `item + 1` (0 = slot never written).
    pub enc_item: u64,
    /// Plan epoch the shard index is relative to.
    pub plan_epoch: u64,
    pub shard: usize,
    pub node: PAddr,
    pub idx: u64,
    pub seq: u64,
}

/// One thread's persistent batch log.
pub(crate) struct BatchLog {
    base: PAddr,
    capacity: usize,
}

impl BatchLog {
    fn lines(capacity: usize) -> usize {
        1 + capacity.div_ceil(ENTRIES_PER_LINE)
    }

    /// Allocate a log holding up to `capacity` entries (`capacity` ≤
    /// [`crate::queues::MAX_BATCH`], enforced upstream by
    /// `QueueConfig::validate`).
    pub fn alloc(pool: &PmemPool, capacity: usize) -> Self {
        let lines = Self::lines(capacity);
        // Through the palloc tier: the log itself lives for the queue's
        // lifetime, but its generations are reused in place (seq bumps),
        // and the segment header keeps it visible to allocator accounting.
        let base = pool.palloc_alloc(0, lines).expect(
            "pmem pool exhausted allocating a batch log — raise PmemConfig::capacity_words",
        );
        pool.set_hot(base, lines * WORDS_PER_LINE, Hotness::Private);
        Self { base, capacity }
    }

    fn entry_addr(&self, i: usize) -> PAddr {
        debug_assert!(i < self.capacity);
        self.base
            .add(WORDS_PER_LINE * (1 + i / ENTRIES_PER_LINE) + ENTRY_WORDS * (i % ENTRIES_PER_LINE))
    }

    /// Record entry `i` of the filling batch (plain stores, no flush).
    /// `plan_epoch` qualifies the shard index (word-1 packing: plan in
    /// bits 40.., shard in 32..40, node below — `MAX_SHARDS` < 256 and
    /// node addresses are 32-bit arena offsets).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        pool: &PmemPool,
        tid: usize,
        i: usize,
        item: u64,
        plan_epoch: u64,
        shard: usize,
        pos: &EnqPos,
        seq: u64,
    ) {
        debug_assert!(plan_epoch <= super::plan::MAX_PLAN_EPOCH && shard < 256);
        let a = self.entry_addr(i);
        pool.store(tid, a, item + 1);
        pool.store(
            tid,
            a.add(1),
            (plan_epoch << 40) | ((shard as u64) << 32) | pos.node.to_u64(),
        );
        pool.store(tid, a.add(2), pos.idx);
        pool.store(tid, a.add(3), seq);
    }

    /// Seal the batch: publish the header and request write-back of every
    /// touched line. The caller issues the single `psync` that makes the
    /// log and the batch's deferred cell flushes durable together.
    pub fn seal(&self, pool: &PmemPool, tid: usize, count: usize, seq: u64) {
        debug_assert!(count <= self.capacity && count < 256);
        pool.store(tid, self.base, (seq << 8) | count as u64);
        pool.pwb(tid, self.base);
        for line in 0..count.div_ceil(ENTRIES_PER_LINE) {
            pool.pwb(tid, self.base.add(WORDS_PER_LINE * (1 + line)));
        }
    }

    /// Read the durable header: `(count, seq)`.
    pub fn header(&self, pool: &PmemPool, tid: usize) -> (usize, u64) {
        let h = pool.load(tid, self.base);
        ((h & 0xFF) as usize, h >> 8)
    }

    /// Decode entry `i`.
    pub fn entry(&self, pool: &PmemPool, tid: usize, i: usize) -> LogEntry {
        let a = self.entry_addr(i);
        let w1 = pool.load(tid, a.add(1));
        LogEntry {
            enc_item: pool.load(tid, a),
            plan_epoch: w1 >> 40,
            shard: ((w1 >> 32) & 0xFF) as usize,
            node: PAddr::from_u64(w1 & 0xFFFF_FFFF),
            idx: pool.load(tid, a.add(2)),
            seq: pool.load(tid, a.add(3)),
        }
    }

    /// Retire the log (recovery): header := 0, write-back requested; the
    /// caller psyncs.
    pub fn clear(&self, pool: &PmemPool, tid: usize) {
        pool.store(tid, self.base, 0);
        pool.pwb(tid, self.base);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn pool() -> PmemPool {
        PmemPool::new(PmemConfig {
            capacity_words: 1 << 14,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 1,
        })
    }

    #[test]
    fn entries_never_straddle_lines() {
        let p = pool();
        let log = BatchLog::alloc(&p, 32);
        for i in 0..32 {
            let a = log.entry_addr(i);
            assert_eq!(
                a.line(),
                a.add(ENTRY_WORDS - 1).line(),
                "entry {i} straddles a cache line"
            );
        }
    }

    #[test]
    fn record_seal_roundtrip_survives_crash() {
        let p = pool();
        let log = BatchLog::alloc(&p, 8);
        for i in 0..5usize {
            let pos = EnqPos { node: PAddr(64), idx: 10 + i as u64 };
            log.record(&p, 0, i, 100 + i as u64, 3 + i as u64, i % 3, &pos, 7);
        }
        log.seal(&p, 0, 5, 7);
        p.psync(0);
        let mut rng = crate::util::rng::Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        let (count, seq) = log.header(&p, 0);
        assert_eq!((count, seq), (5, 7));
        for i in 0..5usize {
            let e = log.entry(&p, 0, i);
            assert_eq!(e.enc_item, 101 + i as u64);
            assert_eq!(e.plan_epoch, 3 + i as u64, "plan epoch must round-trip");
            assert_eq!(e.shard, i % 3);
            assert_eq!(e.node, PAddr(64));
            assert_eq!(e.idx, 10 + i as u64);
            assert_eq!(e.seq, 7);
        }
    }

    #[test]
    fn unsealed_batch_is_lost_sealed_clear_is_durable() {
        let p = pool();
        let log = BatchLog::alloc(&p, 4);
        let pos = EnqPos { node: PAddr(8), idx: 0 };
        log.record(&p, 0, 0, 42, 1, 0, &pos, 1);
        // No seal/psync: the header must read empty after a crash.
        let mut rng = crate::util::rng::Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        assert_eq!(log.header(&p, 0).0, 0);
        // Seal + psync, then durable clear.
        log.record(&p, 0, 0, 42, 1, 0, &pos, 2);
        log.seal(&p, 0, 1, 2);
        p.psync(0);
        log.clear(&p, 0);
        p.psync(0);
        p.crash(&mut rng);
        assert_eq!(log.header(&p, 0).0, 0, "cleared log must stay cleared");
    }
}
