//! Versioned shard plans and the persistent plan log — the state behind
//! elastic re-sharding.
//!
//! A **ShardPlan** is one immutable generation of the sharded queue's
//! stripe set: `K` shards, their pool placement, and the dispatch orders
//! derived from it, stamped with a monotone **plan epoch**. The queue's
//! hot paths dispatch over a [`PlanSet`] — the active plan plus, during a
//! transition, the frozen old plan still being drained.
//!
//! ## The persistent state machine
//!
//! Re-sharding is committed through a tiny persistent log on the primary
//! pool (three cache lines):
//!
//! ```text
//! line 0, word 0 : state = (tag << 60) | (slot << 56) | epoch
//! line 1         : plan record slot 0
//! line 2         : plan record slot 1
//! ```
//!
//! with `tag ∈ {ACTIVE, FREEZING}`. A record (one line) stores
//! `(epoch << 8) | K` in word 0 and the per-shard pool placement packed
//! four bits per shard in words 1..=4 (covers [`MAX_SHARDS`] shards ×
//! [`MAX_POOLS`] pools). `resize` writes the NEW plan's record into the
//! spare slot and psyncs it, then commits the transition with a
//! single-word state write + psync:
//!
//! ```text
//! Active(old) ──record new──▶ Active(old)   [new record durable, uncommitted]
//!             ──state word──▶ Freezing(old, new)   [psync = commit point]
//!             ──drain, then state word──▶ Active(new)   [one psync retires]
//! ```
//!
//! Each arrow is one line-atomic durable step, so a crash at any point
//! lands on exactly one of the three named states and
//! [`super::ShardedQueue::recover`] can always roll the transition
//! *forward*: durably `Freezing` means the new record is durable by
//! construction, so recovery adopts the new plan, drains the frozen
//! residue single-threadedly, and retires the old plan itself.
//!
//! [`MAX_SHARDS`]: crate::queues::MAX_SHARDS
//! [`MAX_POOLS`]: crate::pmem::MAX_POOLS

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::pmem::{Hotness, PAddr, PmemPool, WORDS_PER_LINE};

/// One generation of the stripe set. Immutable once built; the queue
/// swaps `Arc<Plan>`s to transition.
pub(crate) struct Plan<Q> {
    /// Monotone plan epoch (1 = the construction-time plan).
    pub epoch: u64,
    /// The stripe set of this generation.
    pub shards: Vec<Q>,
    /// Pool (socket) each shard lives on.
    pub shard_pool: Vec<usize>,
    /// Per-home-pool enqueue dispatch order (see `ShardedQueue` docs).
    pub enq_orders: Vec<Vec<usize>>,
    /// Per-home-pool dequeue scan order.
    pub deq_orders: Vec<Vec<usize>>,
    /// Per-shard "observed linearizably empty" flags — meaningful only
    /// while this plan is the frozen (draining) side of a transition:
    /// post-freeze no enqueue can target these shards, so emptiness is
    /// monotone and a single observation is a permanent witness.
    pub drained: Vec<AtomicBool>,
}

impl<Q> Plan<Q> {
    pub fn new(
        epoch: u64,
        shards: Vec<Q>,
        shard_pool: Vec<usize>,
        npools: usize,
        prefer_home: bool,
    ) -> Plan<Q> {
        let (enq_orders, deq_orders) = dispatch_orders(&shard_pool, npools, prefer_home);
        let drained = (0..shards.len()).map(|_| AtomicBool::new(false)).collect();
        Plan { epoch, shards, shard_pool, enq_orders, deq_orders, drained }
    }

    /// Have all shards been witnessed empty (drain complete)?
    pub fn all_drained(&self) -> bool {
        self.drained.iter().all(|d| d.load(Ordering::Relaxed))
    }
}

/// The volatile plan pair the hot paths dispatch over. Since the
/// epoch-pinning refactor this is an **immutable snapshot**: every
/// transition (freeze, retire, recovery adoption) builds a fresh
/// `PlanSet` and publishes it through the queue's
/// [`super::epoch::PlanCell`] pointer swap — in-place mutation would
/// race with pinned readers. The `Arc<Plan>` members are shared across
/// snapshots (and with the recovery history), so a snapshot is two
/// refcounted pointers, not a copy of the stripes.
pub(crate) struct PlanSet<Q> {
    /// Where enqueues stripe (and dequeues fall back to).
    pub active: Arc<Plan<Q>>,
    /// The frozen old plan still holding residue — dequeues scan it
    /// first (drain priority). `None` outside a transition.
    pub draining: Option<Arc<Plan<Q>>>,
}

/// Decoded durable plan state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum PlanState {
    /// One committed plan; its record sits in `slot`.
    Active { slot: usize, epoch: u64 },
    /// Mid-transition: the old plan's record in `old_slot`, the new
    /// plan's (epoch `epoch`) in the other slot.
    Freezing { old_slot: usize, epoch: u64 },
}

const TAG_ACTIVE: u64 = 1;
const TAG_FREEZING: u64 = 2;
/// Plan epochs must fit the state word (56 bits) and the batch-log entry
/// packing (24 bits) — the tighter bound wins.
pub(crate) const MAX_PLAN_EPOCH: u64 = (1 << 24) - 1;

/// The persistent plan log (three lines on the primary pool). All writes
/// are serialized by the queue's resize lock (single logical writer).
pub(crate) struct PlanLog {
    base: PAddr,
}

impl PlanLog {
    pub fn alloc(pool: &PmemPool) -> PlanLog {
        let base = pool.palloc_alloc(0, 3).expect(
            "pmem pool exhausted allocating the plan log — raise PmemConfig::capacity_words",
        );
        pool.set_hot(base, 3 * WORDS_PER_LINE, Hotness::Private);
        PlanLog { base }
    }

    fn slot_addr(&self, slot: usize) -> PAddr {
        debug_assert!(slot < 2);
        self.base.add(WORDS_PER_LINE * (1 + slot))
    }

    /// Write (and request write-back of) a plan record; the caller issues
    /// the psync that makes it durable before committing any state that
    /// names it.
    pub fn write_record(
        &self,
        pool: &PmemPool,
        tid: usize,
        slot: usize,
        epoch: u64,
        shard_pool: &[usize],
    ) {
        debug_assert!(epoch <= MAX_PLAN_EPOCH, "plan epoch overflows the log packing");
        debug_assert!(!shard_pool.is_empty() && shard_pool.len() <= 64);
        let a = self.slot_addr(slot);
        pool.store(tid, a, (epoch << 8) | shard_pool.len() as u64);
        for w in 0..4usize {
            let mut packed = 0u64;
            for nib in 0..16usize {
                let s = w * 16 + nib;
                if s < shard_pool.len() {
                    debug_assert!(shard_pool[s] < 16);
                    packed |= (shard_pool[s] as u64 & 0xF) << (4 * nib);
                }
            }
            pool.store(tid, a.add(1 + w), packed);
        }
        pool.pwb(tid, a);
    }

    /// Decode a record slot: `(epoch, shard_pool)`.
    pub fn read_record(&self, pool: &PmemPool, tid: usize, slot: usize) -> (u64, Vec<usize>) {
        let a = self.slot_addr(slot);
        let h = pool.load(tid, a);
        let k = (h & 0xFF) as usize;
        let epoch = h >> 8;
        let mut shard_pool = Vec::with_capacity(k);
        for s in 0..k.min(64) {
            let packed = pool.load(tid, a.add(1 + s / 16));
            shard_pool.push(((packed >> (4 * (s % 16))) & 0xF) as usize);
        }
        (epoch, shard_pool)
    }

    fn set_state(&self, pool: &PmemPool, tid: usize, tag: u64, slot: usize, epoch: u64) {
        debug_assert!(epoch <= MAX_PLAN_EPOCH);
        pool.store(tid, self.base, (tag << 60) | ((slot as u64) << 56) | epoch);
        pool.pwb(tid, self.base);
    }

    /// Commit `Active(slot, epoch)` (write-back requested; caller
    /// psyncs — retirement is exactly one psync).
    pub fn set_active(&self, pool: &PmemPool, tid: usize, slot: usize, epoch: u64) {
        self.set_state(pool, tid, TAG_ACTIVE, slot, epoch);
    }

    /// Commit `Freezing(old_slot, new_epoch)` (caller psyncs — the
    /// transition's commit point).
    pub fn set_freezing(&self, pool: &PmemPool, tid: usize, old_slot: usize, new_epoch: u64) {
        self.set_state(pool, tid, TAG_FREEZING, old_slot, new_epoch);
    }

    /// Decode the durable state. Panics on an uninitialized/corrupt tag —
    /// construction durably initializes the log before any operation, so
    /// a bad tag is a framework bug, not a crash artifact.
    pub fn read_state(&self, pool: &PmemPool, tid: usize) -> PlanState {
        let w = pool.load(tid, self.base);
        let slot = ((w >> 56) & 0xF) as usize;
        let epoch = w & ((1 << 56) - 1);
        match w >> 60 {
            TAG_ACTIVE => PlanState::Active { slot, epoch },
            TAG_FREEZING => PlanState::Freezing { old_slot: slot, epoch },
            tag => panic!("plan log uninitialized or corrupt (tag {tag}, word {w:#x})"),
        }
    }
}

/// Counters exported by `ShardedQueue::resize_stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResizeStats {
    /// Plan flips installed (resize commits observed by the hot paths).
    pub flips: u64,
    /// Transitions fully retired (frozen plan drained + one-psync
    /// retirement), live or by crash recovery.
    pub retires: u64,
    /// Items observed in the frozen stripes at flip time, summed over
    /// flips — the checker's cross-plan overtake allowance derives from
    /// this (see `verify::resharding_relaxation`).
    pub residue_total: u64,
    /// Items in the frozen stripes at the most recent flip.
    pub last_residue: u64,
    /// Items actually dequeued out of frozen stripes (drain-priority
    /// scans plus recovery's forward drain).
    pub drained_from_frozen: u64,
}

/// Compute the per-home dispatch orders for a shard→pool map (see the
/// `Plan::enq_orders`/`Plan::deq_orders` fields).
pub(crate) fn dispatch_orders(
    shard_pool: &[usize],
    npools: usize,
    prefer_home: bool,
) -> (Vec<Vec<usize>>, Vec<Vec<usize>>) {
    let all: Vec<usize> = (0..shard_pool.len()).collect();
    let mut enq = Vec::with_capacity(npools);
    let mut deq = Vec::with_capacity(npools);
    for home in 0..npools {
        let local: Vec<usize> =
            all.iter().copied().filter(|&s| shard_pool[s] == home).collect();
        let remote: Vec<usize> =
            all.iter().copied().filter(|&s| shard_pool[s] != home).collect();
        if prefer_home && !local.is_empty() {
            enq.push(local.clone());
            let mut order = local;
            order.extend(remote);
            deq.push(order);
        } else {
            enq.push(all.clone());
            deq.push(all.clone());
        }
    }
    (enq, deq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn pool() -> PmemPool {
        PmemPool::new(PmemConfig {
            capacity_words: 1 << 14,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 2,
        })
    }

    #[test]
    fn record_roundtrip_survives_crash() {
        let p = pool();
        let log = PlanLog::alloc(&p);
        let placement: Vec<usize> = (0..23).map(|s| s % 3).collect();
        log.write_record(&p, 0, 1, 7, &placement);
        p.psync(0);
        log.set_active(&p, 0, 1, 7);
        p.psync(0);
        let mut rng = crate::util::rng::Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        assert_eq!(log.read_state(&p, 0), PlanState::Active { slot: 1, epoch: 7 });
        let (epoch, sp) = log.read_record(&p, 0, 1);
        assert_eq!(epoch, 7);
        assert_eq!(sp, placement);
    }

    #[test]
    fn freezing_state_roundtrip() {
        let p = pool();
        let log = PlanLog::alloc(&p);
        log.write_record(&p, 0, 0, 1, &[0, 0]);
        log.set_active(&p, 0, 0, 1);
        p.psync(0);
        log.write_record(&p, 0, 1, 2, &[0, 0, 0, 0]);
        p.psync(0);
        log.set_freezing(&p, 0, 0, 2);
        p.psync(0);
        let mut rng = crate::util::rng::Xoshiro256::seed_from(4);
        p.crash(&mut rng);
        assert_eq!(log.read_state(&p, 0), PlanState::Freezing { old_slot: 0, epoch: 2 });
        assert_eq!(log.read_record(&p, 0, 0).0, 1, "old record intact");
        assert_eq!(log.read_record(&p, 0, 1).0, 2, "new record durable before the commit");
    }

    #[test]
    fn uncommitted_state_rolls_back() {
        // The freeze's state word is written but never psynced: the crash
        // may keep the old state — whatever survives must decode to one
        // of the two named states, never garbage.
        let p = pool();
        let log = PlanLog::alloc(&p);
        log.write_record(&p, 0, 0, 1, &[0]);
        log.set_active(&p, 0, 0, 1);
        p.psync(0);
        log.write_record(&p, 0, 1, 2, &[0, 0]);
        log.set_freezing(&p, 0, 0, 2); // pwb queued, no psync
        let mut rng = crate::util::rng::Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        match log.read_state(&p, 0) {
            PlanState::Active { slot: 0, epoch: 1 } => {}
            PlanState::Freezing { old_slot: 0, epoch: 2 } => {}
            other => panic!("decoded impossible state {other:?}"),
        }
    }

    #[test]
    fn max_shards_pack_into_record() {
        let p = pool();
        let log = PlanLog::alloc(&p);
        let placement: Vec<usize> = (0..64).map(|s| s % 16).collect();
        log.write_record(&p, 0, 0, MAX_PLAN_EPOCH, &placement);
        let (epoch, sp) = log.read_record(&p, 0, 0);
        assert_eq!(epoch, MAX_PLAN_EPOCH);
        assert_eq!(sp, placement);
    }
}
