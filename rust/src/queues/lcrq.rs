//! LCRQ — a linked list of CRQ rings forming an unbounded FIFO queue
//! (paper §3, Algorithm 5 black lines; Morrison–Afek PPoPP'13), plus the
//! shared core [`LcrqCore`] that [`super::perlcrq`] reuses with the
//! persistence instructions of §4.3 switched on.
//!
//! Structure: `First`/`Last` point into a Michael–Scott-style list of
//! nodes, each holding one [`super::crq::Ring`]. When an enqueue on the
//! last ring returns CLOSED, the enqueuer appends a fresh node (created
//! with its item already at `Q\[0\]`, `Tail = 1`); when the first ring is
//! EMPTY and has a successor, dequeuers advance `First`.
//!
//! ## Node layout (arena-relative)
//!
//! ```text
//! node + 0   : next pointer (PAddr as u64; 0 = null)
//! node + 1   : closedFlag word (§4.2 optimization; monotone)
//! node + 8   : ring block (see crq.rs)
//! ```

use std::sync::Arc;

use super::crq::{DeqAt, EnqAt, PersistCfg, Ring};
use super::{ConcurrentQueue, HeadPersistMode, QueueConfig, QueueError, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};

/// The list-of-rings core shared by LCRQ (volatile, `persist = None`) and
/// PerLCRQ (`persist = Some`).
pub struct LcrqCore {
    pub pool: Arc<PmemPool>,
    /// `First` pointer word (own line).
    pub first: PAddr,
    /// `Last` pointer word (own line).
    pub last: PAddr,
    pub nthreads: usize,
    pub ring_size: usize,
    pub starvation_limit: usize,
    pub persist: Option<PersistCfg>,
}

impl LcrqCore {
    /// Words per node: header line + ring block.
    pub fn node_words(&self) -> usize {
        WORDS_PER_LINE + Ring::words(self.ring_size, self.nthreads)
    }

    fn next_addr(node: PAddr) -> PAddr {
        node
    }

    fn closed_flag_addr(node: PAddr) -> PAddr {
        node.add(1)
    }

    /// The ring embedded in `node` (also used by the sharded layer's batch
    /// reconciliation, which stores node addresses in its persistent log).
    pub fn ring_of(&self, node: PAddr) -> Ring {
        Ring::at(node.add(WORDS_PER_LINE), self.ring_size, self.nthreads)
    }

    pub fn new(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: &QueueConfig,
        persist: Option<PersistCfg>,
    ) -> Self {
        Self::new_at(pool, nthreads, cfg, persist, 0)
    }

    /// Construct charging the construction-time pmem operations to `tid`
    /// instead of thread 0 — required when a queue is built *mid-run* on
    /// a live worker thread (the sharded layer's online re-sharding
    /// allocates fresh stripes on the resizing thread's slot; charging
    /// them to tid 0 would race that thread's clocks and flush queues).
    pub fn new_at(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: &QueueConfig,
        persist: Option<PersistCfg>,
        tid: usize,
    ) -> Self {
        cfg.validate().expect("invalid QueueConfig");
        let first = pool.alloc_lines(1);
        let last = pool.alloc_lines(1);
        pool.set_hot(first, 1, crate::pmem::Hotness::Global);
        pool.set_hot(last, 1, crate::pmem::Hotness::Global);
        let core = Self {
            pool: Arc::clone(pool),
            first,
            last,
            nthreads,
            ring_size: cfg.ring_size,
            starvation_limit: cfg.starvation_limit,
            persist,
        };
        // Initial node: an empty ring (fresh zeroed allocation is a valid
        // empty, durable ring — see crq.rs encoding).
        let node = pool.alloc(core.node_words(), WORDS_PER_LINE);
        pool.set_hot(node, 1, crate::pmem::Hotness::Global);
        core.ring_of(node).declare_hotness(pool);
        pool.store(tid, first, node.to_u64());
        pool.store(tid, last, node.to_u64());
        if core.persist.is_some() {
            pool.pwb(tid, first);
            pool.pwb(tid, last);
            pool.psync(tid);
        }
        core
    }

    /// Create a node seeded with `item` at `Q\[0\]`, `Tail = 1` (Alg. 5
    /// lines 16-18). Returns its address; in persistent mode the node is
    /// durable before this returns.
    fn new_node(&self, tid: usize, item: u64) -> PAddr {
        let p = &self.pool;
        let node = p.alloc(self.node_words(), WORDS_PER_LINE);
        p.set_hot(node, 1, crate::pmem::Hotness::Global); // next ptr + closedFlag
        let ring = self.ring_of(node);
        ring.declare_hotness(p);
        // next = 0 and the whole fresh ring are already zero (and already
        // durable: fresh arena lines have live == shadow == 0). Only the
        // seeded item and Tail=1 need writing + persisting.
        ring.write_cell(p, tid, 0, false, 0, item + 1);
        p.store(tid, ring.tail_addr(), 1);
        if self.persist.is_some() {
            // Alg. 5 line 18: pwb(nd.next, nd.crq.Q[0], nd.crq.Tail);
            // psync(). (The paper co-locates these in one line; our layout
            // keeps Tail on its own line for contention isolation, so this
            // costs 2 pwbs — next's line is untouched-zero and needs none.)
            p.pwb(tid, ring.cell_addr(0));
            p.pwb(tid, ring.tail_addr());
            p.psync(tid);
        }
        node
    }

    /// Algorithm 5, Enqueue(x) (lines 16-31).
    pub fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.enqueue_at(tid, item).map(|_| ())
    }

    /// [`LcrqCore::enqueue`] that also reports where the item landed:
    /// `(node address, ring index)`. The sharded layer's batch log records
    /// this position so post-crash reconciliation can decide, per logged
    /// item, whether it is still present, already durably consumed, or
    /// lost and in need of re-insertion.
    pub fn enqueue_at(&self, tid: usize, item: u64) -> Result<(PAddr, u64), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        let mut nd: Option<PAddr> = None; // created lazily on first CLOSED
        loop {
            let l = PAddr::from_u64(p.load(tid, self.last)); // line 20
            let ring = self.ring_of(l); // line 21
            let next = p.load(tid, Self::next_addr(l));
            if next != 0 {
                // line 22-25: Last is falling behind; help.
                if self.persist.is_some() {
                    // line 23: persist the next pointer before exposing it
                    // through Last.
                    p.pwb(tid, Self::next_addr(l));
                    p.psync(tid);
                }
                let _ = p.cas(tid, self.last, l.to_u64(), next);
                continue;
            }
            // line 26: try the current ring.
            let per = self
                .persist
                .as_ref()
                .map(|pc| (pc, Self::closed_flag_addr(l)));
            if let EnqAt::Ok(idx) = ring.enqueue_at(p, tid, item, self.starvation_limit, per)
            {
                return Ok((l, idx)); // line 27
            }
            // CLOSED: append a fresh node containing the item.
            let node = *nd.get_or_insert_with(|| self.new_node(tid, item));
            if p.cas(tid, Self::next_addr(l), 0, node.to_u64()) {
                // line 28 succeeded.
                if self.persist.is_some() {
                    // line 29: the append must be durable before we return.
                    p.pwb(tid, Self::next_addr(l));
                    p.psync(tid);
                }
                let _ = p.cas(tid, self.last, l.to_u64(), node.to_u64()); // line 30
                return Ok((node, 0)); // line 31 — seeded at Q[0]
            }
            // Another thread appended first: keep our node for the next
            // attempt (the paper allocates per retry; reusing is safe — the
            // node is private until the CAS publishes it).
        }
    }

    /// Algorithm 5, Dequeue() (lines 6-15).
    pub fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        Ok(self.dequeue_at(tid).map(|(v, _, _)| v))
    }

    /// [`LcrqCore::dequeue`] that also reports where the item came from:
    /// `(value, node address, ring index)`. The sharded layer's dequeue
    /// log records this position so post-crash reconciliation can decide,
    /// per logged consumption, whether the recovered queue would otherwise
    /// redeliver an already-returned item.
    pub fn dequeue_at(&self, tid: usize) -> Option<(u64, PAddr, u64)> {
        let p = &self.pool;
        loop {
            let f = PAddr::from_u64(p.load(tid, self.first)); // line 8
            let ring = self.ring_of(f); // line 9
            match ring.dequeue_at(p, tid, self.persist.as_ref()) {
                DeqAt::Item { val, idx } => return Some((val, f, idx)), // lines 11-12
                DeqAt::Empty => {
                    let next = p.load(tid, Self::next_addr(f));
                    if next == 0 {
                        return None; // lines 13-14
                    }
                    // line 15: advance First (no persistence — §4.3: First
                    // never changes at recovery; post-crash dequeues
                    // re-traverse).
                    let _ = p.cas(tid, self.first, f.to_u64(), next);
                }
            }
        }
    }

    /// Algorithm 5, PerLCRQRecovery (lines 32-40): walk the list from the
    /// persisted `First`, recover every ring, and re-point `Last` at the
    /// true end of the list.
    pub fn recover(&self, pool: &PmemPool) {
        let tid = 0;
        let mut node = PAddr::from_u64(pool.load(tid, self.first));
        debug_assert!(!node.is_null(), "First must survive (persisted at construction)");
        loop {
            let ring = self.ring_of(node);
            super::percrq::recover_ring(pool, &ring);
            let next = pool.load(tid, Self::next_addr(node));
            if next == 0 {
                break;
            }
            node = PAddr::from_u64(next);
        }
        pool.store(tid, self.last, node.to_u64());
        // Persist the recovered endpoints (cheap; hardens double crashes).
        pool.pwb(tid, self.first);
        pool.pwb(tid, self.last);
        pool.psync(tid);
    }

    /// Number of nodes currently in the list (test observability).
    pub fn node_count(&self, tid: usize) -> usize {
        let p = &self.pool;
        let mut n = 0;
        let mut node = PAddr::from_u64(p.load(tid, self.first));
        while !node.is_null() {
            n += 1;
            node = PAddr::from_u64(p.load(tid, Self::next_addr(node)));
        }
        n
    }
}

/// The volatile LCRQ (paper §3) — state-of-the-art conventional queue.
pub struct Lcrq {
    core: LcrqCore,
}

impl Lcrq {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig) -> Self {
        Self { core: LcrqCore::new(pool, nthreads, &cfg, None) }
    }

    /// Node count (test observability).
    pub fn node_count(&self, tid: usize) -> usize {
        self.core.node_count(tid)
    }
}

impl ConcurrentQueue for Lcrq {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.core.enqueue(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.core.dequeue(tid)
    }

    fn name(&self) -> &'static str {
        "lcrq"
    }
}

// Re-export for perlcrq's use.
pub(crate) use core_access::core_persist_cfg;

mod core_access {
    use super::*;

    /// Build the persistence config for PerLCRQ from the queue config.
    pub(crate) fn core_persist_cfg(cfg: &QueueConfig) -> PersistCfg {
        PersistCfg {
            head_mode: cfg.head_mode,
            skip_tail_persist: cfg.skip_tail_persist,
            disable_closed_flag: cfg.disable_closed_flag,
            defer_enqueue_sync: cfg.defer_enqueue_sync,
            defer_dequeue_sync: cfg.defer_dequeue_sync,
        }
    }
}

// Silence unused warning: HeadPersistMode referenced in docs.
const _: fn() -> HeadPersistMode = || HeadPersistMode::Local;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(ring: usize) -> (Arc<PmemPool>, Lcrq) {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 20).with_cost(CostModel::zero()),
        ));
        let cfg = QueueConfig { ring_size: ring, ..Default::default() };
        let q = Lcrq::new(&pool, 8, cfg);
        (pool, q)
    }

    #[test]
    fn fifo_through_multiple_rings() {
        let (_p, q) = mk(8);
        // 100 items >> ring size: forces node appends.
        for v in 0..100u64 {
            q.enqueue(0, v).unwrap();
        }
        assert!(q.node_count(0) >= 2, "should have spilled into new nodes");
        for v in 0..100u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn empty_queue() {
        let (_p, q) = mk(8);
        assert_eq!(q.dequeue(0).unwrap(), None);
        q.enqueue(0, 5).unwrap();
        assert_eq!(q.dequeue(0).unwrap(), Some(5));
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn alternating_across_ring_boundary() {
        let (_p, q) = mk(4);
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn unbounded_growth_beyond_one_ring() {
        let (_p, q) = mk(4);
        for v in 0..64u64 {
            q.enqueue(0, v).unwrap();
        }
        // 64 items with R=4 → many nodes.
        assert!(q.node_count(0) >= 8);
        for v in 0..64u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
    }

    #[test]
    fn mpmc_stress() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let (_p, q) = mk(64);
        let q = Arc::new(q);
        let total = 4 * 2000u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let q = Arc::clone(&q);
            hs.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    q.enqueue(pid, pid as u64 * 10_000 + i).unwrap();
                }
            }));
        }
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(4 + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "duplicates detected");
        // Per-producer FIFO: for each producer, consumed order must be
        // increasing. (Checked via the global sorted/dedup above plus a
        // per-producer monotonicity scan on one consumer's log is not
        // possible here since logs merged; covered in verify/ tests.)
    }
}
