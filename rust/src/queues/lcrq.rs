//! LCRQ — a linked list of CRQ rings forming an unbounded FIFO queue
//! (paper §3, Algorithm 5 black lines; Morrison–Afek PPoPP'13), plus the
//! shared core [`LcrqCore`] that [`super::perlcrq`] reuses with the
//! persistence instructions of §4.3 switched on.
//!
//! Structure: `First`/`Last` point into a Michael–Scott-style list of
//! nodes, each holding one [`super::crq::Ring`]. When an enqueue on the
//! last ring returns CLOSED, the enqueuer appends a fresh node (created
//! with its item already at `Q\[0\]`, `Tail = 1`); when the first ring is
//! EMPTY and has a successor, dequeuers advance `First`.
//!
//! ## Node layout (arena-relative)
//!
//! ```text
//! node + 0   : next pointer (PAddr as u64; 0 = null)
//! node + 1   : closedFlag word (§4.2 optimization; monotone)
//! node + 8   : ring block (see crq.rs)
//! ```

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use super::crq::{DeqAt, EnqAt, PersistCfg, Ring};
use super::sharded::epoch::{EpochRegistry, GraceSnapshot};
use super::{ConcurrentQueue, HeadPersistMode, QueueConfig, QueueError, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};

/// Retired (bypassed-by-`First`) nodes awaiting recycling. FIFO in
/// retire order, which equals chain order — the release rule depends on
/// that (see [`LcrqCore::try_release`]).
#[derive(Default)]
struct Limbo {
    q: VecDeque<(u32, u64, GraceSnapshot)>,
    /// addr → retire seq for every in-limbo node (the durable-`First`
    /// horizon lookup).
    pos: HashMap<u32, u64>,
    next_seq: u64,
}

/// The list-of-rings core shared by LCRQ (volatile, `persist = None`) and
/// PerLCRQ (`persist = Some`).
pub struct LcrqCore {
    pub pool: Arc<PmemPool>,
    /// `First` pointer word (own line).
    pub first: PAddr,
    /// `Last` pointer word (own line).
    pub last: PAddr,
    pub nthreads: usize,
    pub ring_size: usize,
    pub starvation_limit: usize,
    pub persist: Option<PersistCfg>,
    /// Recycle drained nodes through the pool's palloc tier (off = the
    /// historical leak-by-design behaviour).
    recycle: bool,
    /// Grace-period registry for node reuse: every operation holds a
    /// bare pin, so a retired node is only recycled once all operations
    /// concurrent with its retirement have finished.
    reg: EpochRegistry,
    limbo: Mutex<Limbo>,
    /// Durable-chain membership as of the last [`LcrqCore::recover`]
    /// (`None` = never recovered). Feeds [`LcrqCore::node_settled`].
    chain_nodes: Mutex<Option<HashSet<u32>>>,
}

impl LcrqCore {
    /// Words per node: header line + ring block.
    pub fn node_words(&self) -> usize {
        WORDS_PER_LINE + Ring::words(self.ring_size, self.nthreads)
    }

    fn next_addr(node: PAddr) -> PAddr {
        node
    }

    fn closed_flag_addr(node: PAddr) -> PAddr {
        node.add(1)
    }

    /// Lines per node (the palloc size class nodes allocate from).
    pub fn node_lines(&self) -> usize {
        self.node_words().div_ceil(WORDS_PER_LINE)
    }

    /// The ring embedded in `node` (also used by the sharded layer's batch
    /// reconciliation, which stores node addresses in its persistent log).
    pub fn ring_of(&self, node: PAddr) -> Ring {
        Ring::at(node.add(WORDS_PER_LINE), self.ring_size, self.nthreads)
    }

    pub fn new(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: &QueueConfig,
        persist: Option<PersistCfg>,
    ) -> Self {
        Self::new_at(pool, nthreads, cfg, persist, 0)
    }

    /// Construct charging the construction-time pmem operations to `tid`
    /// instead of thread 0 — required when a queue is built *mid-run* on
    /// a live worker thread (the sharded layer's online re-sharding
    /// allocates fresh stripes on the resizing thread's slot; charging
    /// them to tid 0 would race that thread's clocks and flush queues).
    pub fn new_at(
        pool: &Arc<PmemPool>,
        nthreads: usize,
        cfg: &QueueConfig,
        persist: Option<PersistCfg>,
        tid: usize,
    ) -> Self {
        cfg.validate().expect("invalid QueueConfig");
        pool.palloc().set_magazine_cap(cfg.magazine);
        pool.palloc().set_recycle(cfg.recycle);
        const EXHAUSTED: &str =
            "pmem pool exhausted during queue construction — raise PmemConfig::capacity_words";
        let first = pool.palloc_alloc(tid, 1).expect(EXHAUSTED);
        let last = pool.palloc_alloc(tid, 1).expect(EXHAUSTED);
        pool.set_hot(first, 1, crate::pmem::Hotness::Global);
        pool.set_hot(last, 1, crate::pmem::Hotness::Global);
        let core = Self {
            pool: Arc::clone(pool),
            first,
            last,
            nthreads,
            ring_size: cfg.ring_size,
            starvation_limit: cfg.starvation_limit,
            persist,
            recycle: cfg.recycle,
            reg: EpochRegistry::new(nthreads),
            limbo: Mutex::new(Limbo::default()),
            chain_nodes: Mutex::new(None),
        };
        // Initial node: an empty ring (fresh zeroed allocation is a valid
        // empty, durable ring — see crq.rs encoding; palloc scrubs recycled
        // segments back to durable zeros, so reuse is indistinguishable).
        let node = core.pool.palloc_alloc(tid, core.node_lines()).expect(EXHAUSTED);
        pool.set_hot(node, 1, crate::pmem::Hotness::Global);
        core.ring_of(node).declare_hotness(pool);
        pool.store(tid, first, node.to_u64());
        pool.store(tid, last, node.to_u64());
        if core.persist.is_some() {
            pool.pwb(tid, first);
            pool.pwb(tid, last);
            pool.psync(tid);
        }
        core
    }

    /// Create a node seeded with `item` at `Q\[0\]`, `Tail = 1` (Alg. 5
    /// lines 16-18). Returns its address; in persistent mode the node is
    /// durable before this returns. Errs with
    /// [`QueueError::CapacityExhausted`] when the arena is out of words
    /// and no retired node is eligible for reuse.
    fn new_node(&self, tid: usize, item: u64) -> Result<PAddr, QueueError> {
        let p = &self.pool;
        // Flush eligible limbo entries into the allocator first, so churn
        // workloads reuse retired nodes instead of growing the arena.
        self.try_release(tid);
        let node = p
            .palloc_alloc(tid, self.node_lines())
            .ok_or(QueueError::CapacityExhausted)?;
        p.set_hot(node, 1, crate::pmem::Hotness::Global); // next ptr + closedFlag
        let ring = self.ring_of(node);
        ring.declare_hotness(p);
        // next = 0 and the whole ring are already zero (and already
        // durable): fresh arena lines have live == shadow == 0, and palloc
        // scrubs recycled segments back to durable zeros before handing
        // them out. Only the seeded item and Tail=1 need writing +
        // persisting.
        ring.write_cell(p, tid, 0, false, 0, item + 1);
        p.store(tid, ring.tail_addr(), 1);
        if self.persist.is_some() {
            // Alg. 5 line 18: pwb(nd.next, nd.crq.Q[0], nd.crq.Tail);
            // psync(). (The paper co-locates these in one line; our layout
            // keeps Tail on its own line for contention isolation, so this
            // costs 2 pwbs — next's line is untouched-zero and needs none.)
            p.pwb(tid, ring.cell_addr(0));
            p.pwb(tid, ring.tail_addr());
            p.psync(tid);
        }
        Ok(node)
    }

    /// Algorithm 5, Enqueue(x) (lines 16-31).
    pub fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.enqueue_at(tid, item).map(|_| ())
    }

    /// [`LcrqCore::enqueue`] that also reports where the item landed:
    /// `(node address, ring index)`. The sharded layer's batch log records
    /// this position so post-crash reconciliation can decide, per logged
    /// item, whether it is still present, already durably consumed, or
    /// lost and in need of re-insertion.
    pub fn enqueue_at(&self, tid: usize, item: u64) -> Result<(PAddr, u64), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        // Pin for the whole operation: no node this op can observe is
        // recycled until the pin drops (see `retire_node`).
        let _pin = self.reg.pin_bare(tid);
        let mut nd: Option<PAddr> = None; // created lazily on first CLOSED
        loop {
            let l = PAddr::from_u64(p.load(tid, self.last)); // line 20
            let ring = self.ring_of(l); // line 21
            let next = p.load(tid, Self::next_addr(l));
            if next != 0 {
                // line 22-25: Last is falling behind; help.
                if self.persist.is_some() {
                    // line 23: persist the next pointer before exposing it
                    // through Last.
                    p.pwb(tid, Self::next_addr(l));
                    p.psync(tid);
                }
                let _ = p.cas(tid, self.last, l.to_u64(), next);
                continue;
            }
            // line 26: try the current ring.
            let per = self
                .persist
                .as_ref()
                .map(|pc| (pc, Self::closed_flag_addr(l)));
            if let EnqAt::Ok(idx) = ring.enqueue_at(p, tid, item, self.starvation_limit, per)
            {
                if self.recycle {
                    if let Some(spare) = nd.take() {
                        // A pre-created node lost its append race and an
                        // older ring then accepted the item. It was never
                        // published, so it is still private and can re-enter
                        // the allocator immediately — no grace needed.
                        p.palloc_free(tid, spare);
                    }
                }
                return Ok((l, idx)); // line 27
            }
            // CLOSED: append a fresh node containing the item.
            let node = match nd {
                Some(n) => n,
                None => {
                    let n = self.new_node(tid, item)?;
                    nd = Some(n);
                    n
                }
            };
            if p.cas(tid, Self::next_addr(l), 0, node.to_u64()) {
                // line 28 succeeded.
                if self.persist.is_some() {
                    // line 29: the append must be durable before we return.
                    p.pwb(tid, Self::next_addr(l));
                    p.psync(tid);
                }
                let _ = p.cas(tid, self.last, l.to_u64(), node.to_u64()); // line 30
                return Ok((node, 0)); // line 31 — seeded at Q[0]
            }
            // Another thread appended first: keep our node for the next
            // attempt (the paper allocates per retry; reusing is safe — the
            // node is private until the CAS publishes it).
        }
    }

    /// Algorithm 5, Dequeue() (lines 6-15).
    pub fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        Ok(self.dequeue_at(tid).map(|(v, _, _)| v))
    }

    /// [`LcrqCore::dequeue`] that also reports where the item came from:
    /// `(value, node address, ring index)`. The sharded layer's dequeue
    /// log records this position so post-crash reconciliation can decide,
    /// per logged consumption, whether the recovered queue would otherwise
    /// redeliver an already-returned item.
    pub fn dequeue_at(&self, tid: usize) -> Option<(u64, PAddr, u64)> {
        let p = &self.pool;
        // Pin for the whole operation: no node this op can observe is
        // recycled until the pin drops (see `retire_node`).
        let _pin = self.reg.pin_bare(tid);
        loop {
            let f = PAddr::from_u64(p.load(tid, self.first)); // line 8
            let ring = self.ring_of(f); // line 9
            match ring.dequeue_at(p, tid, self.persist.as_ref()) {
                DeqAt::Item { val, idx } => return Some((val, f, idx)), // lines 11-12
                DeqAt::Empty => {
                    let next = p.load(tid, Self::next_addr(f));
                    if next == 0 {
                        return None; // lines 13-14
                    }
                    // line 15: advance First (no persistence — §4.3: First
                    // never changes at recovery; post-crash dequeues
                    // re-traverse). The winning CAS is the node's unique
                    // retire point: exactly one thread pushes it to limbo.
                    if p.cas(tid, self.first, f.to_u64(), next) {
                        self.retire_node(tid, f);
                    }
                }
            }
        }
    }

    /// Retire a node that `First` just advanced past (the caller won the
    /// first-advance CAS, so it is the node's unique retirer). With
    /// recycling on, a `pwb` of `First` is queued on the caller's flush
    /// queue — it rides whatever `psync` the thread issues next (amortised
    /// 1/R extra flushes per op, zero extra psyncs), moving the durable
    /// `First` forward so retired nodes eventually clear the durability
    /// gate in [`LcrqCore::try_release`].
    fn retire_node(&self, tid: usize, node: PAddr) {
        if !self.recycle {
            return; // historical behaviour: bypassed nodes leak in the arena
        }
        if self.persist.is_some() {
            self.pool.pwb(tid, self.first);
        }
        // Snapshot AFTER the unlink: any op that could still hold a
        // pre-unlink reference to `node` is pinned in this snapshot.
        let snap = self.reg.snapshot();
        {
            let mut lb = self.limbo.lock().unwrap_or_else(|e| e.into_inner());
            let seq = lb.next_seq;
            lb.next_seq += 1;
            lb.pos.insert(node.0, seq);
            lb.q.push_back((node.0, seq, snap));
        }
        self.try_release(tid);
    }

    /// Pop the limbo front if it is safe to reuse, i.e.:
    ///
    /// * its grace snapshot elapsed (no op that could hold a reference is
    ///   still running), and
    /// * it is durably unreachable: retired strictly before the node the
    ///   durable (shadow) `First` points at. If the shadow `First` is not
    ///   in limbo it points at a live node, which every limbo entry
    ///   precedes in chain order — all are durably bypassed. The shadow
    ///   `First` only moves forward along the chain, so an entry that
    ///   clears this gate once can never become durably reachable again
    ///   (no ABA: an in-limbo address is not reallocated yet, so the map
    ///   lookup cannot alias a recycled incarnation).
    fn pop_releasable(&self, durable_first: u32) -> Option<u32> {
        let mut lb = self.limbo.lock().unwrap_or_else(|e| e.into_inner());
        let horizon = lb.pos.get(&durable_first).copied();
        let ok = match lb.q.front() {
            Some((_, seq, snap)) => {
                horizon.is_none_or(|h| *seq < h) && self.reg.has_elapsed(snap)
            }
            None => false,
        };
        if !ok {
            return None;
        }
        let (addr, _, _) = lb.q.pop_front().expect("front checked above");
        lb.pos.remove(&addr);
        Some(addr)
    }

    /// Hand every currently-releasable limbo node back to the allocator.
    /// Frees happen outside the limbo lock (palloc touches metered pmem,
    /// which may crash-unwind).
    fn try_release(&self, tid: usize) {
        if !self.recycle {
            return;
        }
        let durable_first = if self.persist.is_some() {
            PAddr::from_u64(self.pool.read_shadow(self.first)).0
        } else {
            // Volatile queue: nothing survives a crash, so the durability
            // gate is vacuous — 0 is never a node address, making the
            // horizon lookup miss and grace alone decide.
            0
        };
        while let Some(addr) = self.pop_releasable(durable_first) {
            self.pool.palloc_free(tid, PAddr(addr));
        }
    }

    /// Whether node recycling is on for this core.
    pub fn recycle_enabled(&self) -> bool {
        self.recycle
    }

    /// Pin the caller against node recycling for the duration of the
    /// returned guard. External chain walks (the sharded layer's
    /// emptiness/occupancy hints) must hold one: any node reachable from
    /// `First` after the pin cannot be recycled until the guard drops,
    /// keeping the walk's one-sided soundness contract intact.
    pub fn pin_walk(&self, tid: usize) -> super::sharded::epoch::BarePin<'_> {
        self.reg.pin_bare(tid)
    }

    /// True iff recycling is on and `node` was NOT on the durable chain
    /// at the last recovery — meaning the durable `First` had already
    /// advanced past it at crash time, so every item it ever held was
    /// durably consumed. The sharded layer's probe uses this to answer
    /// `Settled` instead of misreading a recycled (scrubbed or reused)
    /// ring. Returns false if this core has never been recovered.
    pub fn node_settled(&self, node: PAddr) -> bool {
        if !self.recycle {
            return false;
        }
        match &*self.chain_nodes.lock().unwrap_or_else(|e| e.into_inner()) {
            Some(set) => !set.contains(&node.0),
            None => false,
        }
    }

    /// Free every pmem segment this core owns back to the palloc tier:
    /// limbo nodes (unconditionally — see below), the live chain, and the
    /// endpoint lines.
    ///
    /// Caller contract: the queue is durably unreachable (e.g. its shard
    /// was dropped from a durably-committed plan) and quiescent — no
    /// thread will operate on it again, and any grace period covering
    /// historical references has already elapsed. Under that contract the
    /// per-node durability gate is irrelevant: recovery can never walk
    /// this chain again.
    pub fn reclaim_pmem(&self, tid: usize) {
        if !self.recycle {
            return;
        }
        let p = &self.pool;
        loop {
            let addr = {
                let mut lb = self.limbo.lock().unwrap_or_else(|e| e.into_inner());
                lb.q.pop_front().map(|(a, _, _)| {
                    lb.pos.remove(&a);
                    a
                })
            };
            match addr {
                Some(a) => p.palloc_free(tid, PAddr(a)),
                None => break,
            }
        }
        // Walk with unmetered peeks (maintenance path; the frees
        // themselves are metered by palloc).
        let mut node = PAddr::from_u64(p.peek(self.first));
        while !node.is_null() {
            let next = p.peek(Self::next_addr(node));
            p.palloc_free(tid, node);
            node = PAddr::from_u64(next);
        }
        p.palloc_free(tid, self.first);
        p.palloc_free(tid, self.last);
    }

    /// Algorithm 5, PerLCRQRecovery (lines 32-40): walk the list from the
    /// persisted `First`, recover every ring, and re-point `Last` at the
    /// true end of the list.
    pub fn recover(&self, pool: &PmemPool) {
        let tid = 0;
        let mut chain = HashSet::new();
        let mut node = PAddr::from_u64(pool.load(tid, self.first));
        debug_assert!(!node.is_null(), "First must survive (persisted at construction)");
        loop {
            chain.insert(node.0);
            let ring = self.ring_of(node);
            super::percrq::recover_ring(pool, &ring);
            let next = pool.load(tid, Self::next_addr(node));
            if next == 0 {
                break;
            }
            node = PAddr::from_u64(next);
        }
        pool.store(tid, self.last, node.to_u64());
        // Persist the recovered endpoints (cheap; hardens double crashes).
        pool.pwb(tid, self.first);
        pool.pwb(tid, self.last);
        pool.psync(tid);
        // Reset recycling state. Pre-crash limbo entries are void: their
        // nodes are either back on the recovered chain (the durable First
        // lagged their retirement — they must NOT be freed) or durably
        // unreachable with a non-durably-FREE header (conservatively
        // leaked; palloc's rebuild already reclaimed the durably-freed
        // ones). The chain set feeds `node_settled` probes.
        {
            let mut lb = self.limbo.lock().unwrap_or_else(|e| e.into_inner());
            lb.q.clear();
            lb.pos.clear();
        }
        *self.chain_nodes.lock().unwrap_or_else(|e| e.into_inner()) = Some(chain);
    }

    /// Number of nodes currently in the list (test observability).
    pub fn node_count(&self, tid: usize) -> usize {
        let p = &self.pool;
        let _pin = self.reg.pin_bare(tid);
        let mut n = 0;
        let mut node = PAddr::from_u64(p.load(tid, self.first));
        while !node.is_null() {
            n += 1;
            node = PAddr::from_u64(p.load(tid, Self::next_addr(node)));
        }
        n
    }
}

/// The volatile LCRQ (paper §3) — state-of-the-art conventional queue.
pub struct Lcrq {
    core: LcrqCore,
}

impl Lcrq {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig) -> Self {
        Self { core: LcrqCore::new(pool, nthreads, &cfg, None) }
    }

    /// Node count (test observability).
    pub fn node_count(&self, tid: usize) -> usize {
        self.core.node_count(tid)
    }
}

impl ConcurrentQueue for Lcrq {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.core.enqueue(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.core.dequeue(tid)
    }

    fn name(&self) -> &'static str {
        "lcrq"
    }
}

// Re-export for perlcrq's use.
pub(crate) use core_access::core_persist_cfg;

mod core_access {
    use super::*;

    /// Build the persistence config for PerLCRQ from the queue config.
    pub(crate) fn core_persist_cfg(cfg: &QueueConfig) -> PersistCfg {
        PersistCfg {
            head_mode: cfg.head_mode,
            skip_tail_persist: cfg.skip_tail_persist,
            disable_closed_flag: cfg.disable_closed_flag,
            defer_enqueue_sync: cfg.defer_enqueue_sync,
            defer_dequeue_sync: cfg.defer_dequeue_sync,
        }
    }
}

// Silence unused warning: HeadPersistMode referenced in docs.
const _: fn() -> HeadPersistMode = || HeadPersistMode::Local;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(ring: usize) -> (Arc<PmemPool>, Lcrq) {
        mk_recycle(ring, true)
    }

    fn mk_recycle(ring: usize, recycle: bool) -> (Arc<PmemPool>, Lcrq) {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 20).with_cost(CostModel::zero()),
        ));
        let cfg = QueueConfig { ring_size: ring, recycle, ..Default::default() };
        let q = Lcrq::new(&pool, 8, cfg);
        (pool, q)
    }

    #[test]
    fn fifo_through_multiple_rings() {
        let (_p, q) = mk(8);
        // 100 items >> ring size: forces node appends.
        for v in 0..100u64 {
            q.enqueue(0, v).unwrap();
        }
        assert!(q.node_count(0) >= 2, "should have spilled into new nodes");
        for v in 0..100u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn empty_queue() {
        let (_p, q) = mk(8);
        assert_eq!(q.dequeue(0).unwrap(), None);
        q.enqueue(0, 5).unwrap();
        assert_eq!(q.dequeue(0).unwrap(), Some(5));
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn alternating_across_ring_boundary() {
        let (_p, q) = mk(4);
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn unbounded_growth_beyond_one_ring() {
        let (_p, q) = mk(4);
        for v in 0..64u64 {
            q.enqueue(0, v).unwrap();
        }
        // 64 items with R=4 → many nodes.
        assert!(q.node_count(0) >= 8);
        for v in 0..64u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
    }

    /// One churn round: push `n` items through the queue (forcing node
    /// appends and retirements), asserting FIFO order.
    fn churn_round(q: &Lcrq, n: u64) {
        for v in 0..n {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..n {
            assert_eq!(q.dequeue(0).unwrap(), Some(v), "FIFO broken through recycled nodes");
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn recycling_bounds_node_memory_under_churn() {
        let (pool, q) = mk_recycle(4, true);
        // Warm up: populate the freelist/magazines with retired nodes.
        for _ in 0..5 {
            churn_round(&q, 40);
        }
        let plateau = pool.used_words();
        for _ in 0..50 {
            churn_round(&q, 40);
        }
        // Every node allocation after warm-up is served by recycling: the
        // bump cursor must not move at all.
        assert_eq!(
            pool.used_words(),
            plateau,
            "arena grew under churn despite node recycling"
        );
    }

    #[test]
    fn recycle_off_leaks_nodes_like_before() {
        let (pool, q) = mk_recycle(4, false);
        for _ in 0..5 {
            churn_round(&q, 40);
        }
        let mid = pool.used_words();
        for _ in 0..5 {
            churn_round(&q, 40);
        }
        assert!(
            pool.used_words() > mid,
            "with recycling off the arena should keep growing (historical behaviour)"
        );
    }

    #[test]
    fn enqueue_surfaces_capacity_exhausted_instead_of_panicking() {
        // Arena barely larger than the palloc directory + construction.
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(5000).with_cost(CostModel::zero()),
        ));
        let cfg = QueueConfig { ring_size: 4, ..Default::default() };
        let q = Lcrq::new(&pool, 2, cfg);
        let mut accepted = 0u64;
        let err = loop {
            match q.enqueue(0, accepted) {
                Ok(()) => accepted += 1,
                Err(e) => break e,
            }
            assert!(accepted < 1_000_000, "expected exhaustion");
        };
        assert_eq!(err, QueueError::CapacityExhausted);
        assert!(accepted > 0, "should accept some items before exhaustion");
        // Everything accepted before exhaustion is still dequeueable in order.
        for v in 0..accepted {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
    }

    #[test]
    fn mpmc_stress() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let (_p, q) = mk(64);
        let q = Arc::new(q);
        let total = 4 * 2000u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let q = Arc::clone(&q);
            hs.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    q.enqueue(pid, pid as u64 * 10_000 + i).unwrap();
                }
            }));
        }
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(4 + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "duplicates detected");
        // Per-producer FIFO: for each producer, consumed order must be
        // increasing. (Checked via the global sorted/dedup above plus a
        // per-producer monotonicity scan on one consumer's log is not
        // possible here since logs merged; covered in verify/ tests.)
    }
}
