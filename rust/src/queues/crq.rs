//! CRQ — the circular ring queue (paper §3, Algorithm 3 black lines), as a
//! reusable core shared by the volatile CRQ/LCRQ and the persistent
//! PerCRQ/PerLCRQ (which inject persistence instructions at the paper's
//! exact sites — see [`super::percrq`]).
//!
//! CRQ implements a *tantrum* queue: an enqueue may return `CLOSED` (ring
//! full or livelock-prone), and once one does, all later enqueues on the
//! same ring must too.
//!
//! ## Cell encoding
//!
//! The paper's cell is a 16-byte triplet `(s, idx, val)` where `s` is the
//! safe bit and `idx ≡ u (mod R)` for cell `u` — every index ever stored in
//! cell `u` equals `u + k·R` for a *round* `k`. We therefore store:
//!
//! * word0 (`flags`): bit 63 = **unsafe** flag (inverted safe bit), bits
//!   0..62 = round `k`  → `idx = u + k·R`;
//! * word1 (`val`): `0 = ⊥`, else `item + 1`.
//!
//! The all-zeroes fresh-NVM state thus decodes to `(safe, idx = u, ⊥)` —
//! exactly the paper's initial cell value `(1, u, ⊥)` — so newly allocated
//! rings are *born initialized and durable* with no per-cell writes. This
//! is a bijective re-encoding; every transition below cites the paper line
//! it implements.

use super::{HeadPersistMode, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};

/// Closed bit position within the `Tail` word.
pub const CLOSED_BIT: u32 = 63;
/// Mask extracting the tail index from the raw `Tail` word.
pub const IDX_MASK: u64 = (1u64 << 63) - 1;
/// Unsafe flag within a cell's `flags` word.
const UNSAFE_FLAG: u64 = 1u64 << 63;
const ROUND_MASK: u64 = UNSAFE_FLAG - 1;

/// `⊥` in the value word.
pub const BOT: u64 = 0;

#[inline]
fn enc(item: u64) -> u64 {
    debug_assert!(item < MAX_ITEM);
    item + 1
}

#[inline]
fn dec(stored: u64) -> u64 {
    debug_assert_ne!(stored, BOT);
    stored - 1
}

#[inline]
fn pack_flags(unsafe_flag: bool, round: u64) -> u64 {
    debug_assert!(round <= ROUND_MASK);
    (if unsafe_flag { UNSAFE_FLAG } else { 0 }) | round
}

#[inline]
fn unpack_flags(flags: u64) -> (bool, u64) {
    (flags & UNSAFE_FLAG != 0, flags & ROUND_MASK)
}

/// Result of a ring enqueue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqResult {
    Ok,
    Closed,
}

/// Result of a ring enqueue that reports the landing index (used by the
/// sharded queue's batch log so recovery can reconcile in-flight batches
/// by position).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnqAt {
    /// Enqueued at ring index `idx` (`idx % R` is the cell).
    Ok(u64),
    Closed,
}

/// Result of a ring dequeue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeqResult {
    Item(u64),
    Empty,
}

/// Result of a ring dequeue that also reports the claimed ring index (used
/// by the sharded queue's consumer-side dequeue log so recovery can
/// reconcile returned-but-unpersisted consumption by position).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeqAt {
    /// Dequeued `val` from ring index `idx` (`idx % R` is the cell).
    Item { val: u64, idx: u64 },
    Empty,
}

/// Persistence strategy injected into ring operations (PerCRQ sites).
#[derive(Clone, Debug)]
pub struct PersistCfg {
    pub head_mode: HeadPersistMode,
    pub skip_tail_persist: bool,
    /// Disable the closedFlag optimization (ablation: persist Tail on
    /// every CLOSED return).
    pub disable_closed_flag: bool,
    /// Batched-persistence mode (queues::sharded): the successful-enqueue
    /// site still issues its cell `pwb` but skips the `psync`; the outer
    /// batching layer issues one `psync` per batch, amortizing the drain
    /// cost.
    pub defer_enqueue_sync: bool,
    /// Consumer-side group commit (queues::sharded): `persist_head` still
    /// issues its `Head_i` `pwb` but skips the `psync`; the outer layer
    /// issues one `psync` per K dequeues (sealing its dequeue log in the
    /// same drain). A crash may then redeliver the last K−1 returned
    /// items of each thread — buffered durability on the consumer side.
    /// Never enable without an outer syncing layer.
    pub defer_dequeue_sync: bool,
}

// NOTE on the `closedFlag` optimization of §4.2: once some thread has
// durably persisted the closed bit, later CLOSED returns may skip their
// pwb. We keep this flag in a pool word (passed as `closed_flag` below)
// rather than a Rust-side volatile: the flag is *monotone* — it is only
// ever set to 1 after the psync that made the closed bit durable — so it
// is harmless whether a crash loses it (threads re-persist once) or an
// eviction persists it (the closed bit was durable first). No reset needed
// at recovery.

/// A CRQ ring living in the pool at a fixed layout:
///
/// ```text
/// base + 0                : Tail raw (closed bit | index), own line
/// base + 8                : Head, own line
/// base + 16 + 8·i         : Head_i local copies, one line per thread
/// base + 16 + 8·n         : cells, R pairs of 2 words (4 cells / line)
/// ```
#[derive(Clone, Copy, Debug)]
pub struct Ring {
    pub base: PAddr,
    pub ring_size: usize,
    pub nthreads: usize,
}

impl Ring {
    /// Words needed for a ring with `r` cells and `n` threads.
    pub fn words(r: usize, n: usize) -> usize {
        (2 + n) * WORDS_PER_LINE + 2 * r
    }

    /// Allocate a fresh ring (all-zero = initialized + durable, see module
    /// docs).
    pub fn alloc(pool: &PmemPool, r: usize, n: usize) -> Ring {
        assert!(r.is_power_of_two(), "ring size must be a power of two");
        let words = Self::words(r, n);
        let base = pool.alloc(words, WORDS_PER_LINE);
        let ring = Ring { base, ring_size: r, nthreads: n };
        ring.declare_hotness(pool);
        ring
    }

    /// Contention declarations (pmem::Hotness): Tail/Head are FAI'd by all
    /// threads; each Head_i line is SWSR (§4.2 local persistence — the
    /// whole point); cells keep the Pairwise default (one enqueuer + one
    /// dequeuer per index).
    pub fn declare_hotness(&self, pool: &PmemPool) {
        pool.set_hot(self.tail_addr(), 1, crate::pmem::Hotness::Global);
        pool.set_hot(self.head_addr(), 1, crate::pmem::Hotness::Global);
        for t in 0..self.nthreads {
            pool.set_hot(self.head_i_addr(t), 1, crate::pmem::Hotness::Private);
        }
    }

    /// Re-materialize a ring view at `base` (after recovery walks a list).
    pub fn at(base: PAddr, r: usize, n: usize) -> Ring {
        Ring { base, ring_size: r, nthreads: n }
    }

    #[inline]
    pub fn tail_addr(&self) -> PAddr {
        self.base
    }

    #[inline]
    pub fn head_addr(&self) -> PAddr {
        self.base.add(WORDS_PER_LINE)
    }

    #[inline]
    pub fn head_i_addr(&self, tid: usize) -> PAddr {
        debug_assert!(tid < self.nthreads);
        self.base.add((2 + tid) * WORDS_PER_LINE)
    }

    #[inline]
    pub fn cell_addr(&self, u: u64) -> PAddr {
        debug_assert!((u as usize) < self.ring_size);
        self.base.add((2 + self.nthreads) * WORDS_PER_LINE + 2 * u as usize)
    }

    #[inline]
    fn r(&self) -> u64 {
        self.ring_size as u64
    }

    // ------------------------------------------------------------------
    // Enqueue (Algorithm 3 lines 1–22)
    // ------------------------------------------------------------------

    /// Enqueue `item`. `persist = None` gives the volatile CRQ; `Some((cfg,
    /// closed_flag))` gives PerCRQ's persistence placement, where
    /// `closed_flag` is the pool word holding the §4.2 `closedFlag`.
    pub fn enqueue(
        &self,
        pool: &PmemPool,
        tid: usize,
        item: u64,
        starvation_limit: usize,
        persist: Option<(&PersistCfg, PAddr)>,
    ) -> EnqResult {
        match self.enqueue_at(pool, tid, item, starvation_limit, persist) {
            EnqAt::Ok(_) => EnqResult::Ok,
            EnqAt::Closed => EnqResult::Closed,
        }
    }

    /// [`Ring::enqueue`] that also reports the landing index on success.
    pub fn enqueue_at(
        &self,
        pool: &PmemPool,
        tid: usize,
        item: u64,
        starvation_limit: usize,
        persist: Option<(&PersistCfg, PAddr)>,
    ) -> EnqAt {
        let r = self.r();
        let mut attempts = 0usize;
        loop {
            // line 4: FAI on Tail (index bits; closed bit rides along).
            let raw = pool.fai(tid, self.tail_addr());
            let closed = raw & (1 << CLOSED_BIT) != 0;
            let t = raw & IDX_MASK;
            if closed {
                // lines 5-9 (PerCRQ): persist the closed bit before
                // returning CLOSED, unless some thread already has.
                if let Some((pc, flag)) = persist {
                    self.persist_closed(pool, tid, pc, flag);
                }
                return EnqAt::Closed;
            }
            let u = t % r;
            let cell = self.cell_addr(u);
            // lines 10-12: read the cell.
            let (flags, val) = pool.load_pair(tid, cell);
            let (uns, round) = unpack_flags(flags);
            let idx = round * r + u;
            if val == BOT {
                // line 14: idx ≤ t and (safe or Head ≤ t).
                if idx <= t && (!uns || pool.load(tid, self.head_addr()) <= t) {
                    let new_flags = pack_flags(false, t / r); // (1, t, x)
                    if pool.cas2(tid, cell, (flags, BOT), (new_flags, enc(item))) {
                        // line 15 (PerCRQ): the operation's only
                        // persistence pair (psync deferred to the batching
                        // layer in defer_enqueue_sync mode).
                        if let Some((pc, _)) = persist {
                            pool.pwb(tid, cell);
                            if !pc.defer_enqueue_sync {
                                pool.psync(tid);
                            }
                        }
                        return EnqAt::Ok(t);
                    }
                }
            }
            // lines 17-22: full or starving → close the ring.
            let h = pool.load(tid, self.head_addr());
            attempts += 1;
            if (t >= h && t - h >= r) || attempts > starvation_limit {
                let _ = pool.tas_bit(tid, self.tail_addr(), CLOSED_BIT); // line 19
                if let Some((pc, flag)) = persist {
                    // line 20: persist the closed Tail.
                    self.persist_closed(pool, tid, pc, flag);
                }
                return EnqAt::Closed;
            }
        }
    }

    /// §4.2 closedFlag technique: persist `Tail`'s closed bit once, then
    /// let every thread skip the pwb. The flag word is set *after* the
    /// psync completes, so observing 1 implies the closed bit is durable
    /// (see the module-level note on why no crash-time reset is needed).
    fn persist_closed(&self, pool: &PmemPool, tid: usize, pc: &PersistCfg, flag: PAddr) {
        if pc.skip_tail_persist {
            return; // Fig. 3 "no tail" ablation
        }
        if !pc.disable_closed_flag && pool.load(tid, flag) != 0 {
            return;
        }
        pool.pwb(tid, self.tail_addr());
        pool.psync(tid);
        pool.store(tid, flag, 1);
    }

    // ------------------------------------------------------------------
    // Dequeue (Algorithm 3 lines 23–47)
    // ------------------------------------------------------------------

    /// Dequeue. `persist = None` gives the volatile CRQ.
    pub fn dequeue(
        &self,
        pool: &PmemPool,
        tid: usize,
        persist: Option<&PersistCfg>,
    ) -> DeqResult {
        match self.dequeue_at(pool, tid, persist) {
            DeqAt::Item { val, .. } => DeqResult::Item(val),
            DeqAt::Empty => DeqResult::Empty,
        }
    }

    /// [`Ring::dequeue`] that also reports the claimed index on success.
    pub fn dequeue_at(
        &self,
        pool: &PmemPool,
        tid: usize,
        persist: Option<&PersistCfg>,
    ) -> DeqAt {
        let r = self.r();
        loop {
            // line 25: FAI on Head.
            let h = pool.fai(tid, self.head_addr());
            // line 26 (PerCRQ/Local): maintain the local copy Head_i.
            if let Some(pc) = persist {
                if pc.head_mode == HeadPersistMode::Local {
                    pool.store(tid, self.head_i_addr(tid), h + 1);
                }
            }
            let u = h % r;
            let cell = self.cell_addr(u);
            // lines 28-42: transition loop on the claimed cell.
            loop {
                let (flags, val) = pool.load_pair(tid, cell);
                let (uns, round) = unpack_flags(flags);
                let idx = round * r + u;
                if idx > h {
                    break; // line 31 → empty check
                }
                if val != BOT {
                    if idx == h {
                        // line 34: dequeue transition (s, h, v)→(s, h+R, ⊥).
                        if pool.cas2(tid, cell, (flags, val), (pack_flags(uns, round + 1), BOT))
                        {
                            // line 35 (PerCRQ): persist head knowledge.
                            if let Some(pc) = persist {
                                self.persist_head(pool, tid, pc);
                            }
                            return DeqAt::Item { val: dec(val), idx: h };
                        }
                    } else {
                        // line 38: unsafe transition (s,i,v)→(0,i,v).
                        if pool.cas2(tid, cell, (flags, val), (pack_flags(true, round), val)) {
                            break;
                        }
                    }
                } else {
                    // line 41: empty transition (s,i,⊥)→(s, h+R, ⊥).
                    if pool.cas2(tid, cell, (flags, BOT), (pack_flags(uns, h / r + 1), BOT)) {
                        break;
                    }
                }
            }
            // line 43: is the ring empty?
            let traw = pool.load(tid, self.tail_addr());
            let t = traw & IDX_MASK;
            if t <= h + 1 {
                // line 45 (PerCRQ): persist head before returning EMPTY.
                if let Some(pc) = persist {
                    self.persist_head(pool, tid, pc);
                }
                self.fix_state(pool, tid); // line 46
                return DeqAt::Empty;
            }
        }
    }

    /// PerCRQ head persistence (§4.2 Local Persistence): flush the local
    /// SWSR copy instead of the contended shared `Head`. In
    /// `defer_dequeue_sync` mode the `pwb` is issued but its `psync` is
    /// left to the outer batching layer (one drain per K dequeues).
    fn persist_head(&self, pool: &PmemPool, tid: usize, pc: &PersistCfg) {
        match pc.head_mode {
            HeadPersistMode::Local => {
                pool.pwb(tid, self.head_i_addr(tid));
            }
            HeadPersistMode::Shared => {
                pool.pwb(tid, self.head_addr());
            }
            HeadPersistMode::None => return,
        }
        if !pc.defer_dequeue_sync {
            pool.psync(tid);
        }
    }

    // ------------------------------------------------------------------
    // FixState (Algorithm 3 lines 48–57)
    // ------------------------------------------------------------------

    /// Repair `Tail < Head` after an over-draining dequeue burst.
    pub fn fix_state(&self, pool: &PmemPool, tid: usize) {
        loop {
            let h = pool.fetch_add(tid, self.head_addr(), 0); // line 50
            let traw = pool.fetch_add(tid, self.tail_addr(), 0); // line 51
            // line 52: retry if tail moved under us.
            if pool.load(tid, self.tail_addr()) != traw {
                continue;
            }
            let t = traw & IDX_MASK;
            if h <= t {
                return; // line 54-55
            }
            // line 56: set tail := head, preserving the closed bit.
            let new = (traw & (1 << CLOSED_BIT)) | h;
            if pool.cas(tid, self.tail_addr(), traw, new) {
                return;
            }
        }
    }

    // ------------------------------------------------------------------
    // Observability helpers
    // ------------------------------------------------------------------

    /// Is the ring closed?
    pub fn is_closed(&self, pool: &PmemPool, tid: usize) -> bool {
        pool.load(tid, self.tail_addr()) & (1 << CLOSED_BIT) != 0
    }

    /// (head, tail-index) snapshot.
    pub fn endpoints(&self, pool: &PmemPool, tid: usize) -> (u64, u64) {
        (
            pool.load(tid, self.head_addr()),
            pool.load(tid, self.tail_addr()) & IDX_MASK,
        )
    }

    /// Decode cell `u` (testing / recovery): `(unsafe, idx, val_or_bot)`.
    pub fn read_cell(&self, pool: &PmemPool, tid: usize, u: u64) -> (bool, u64, u64) {
        let (flags, val) = pool.load_pair(tid, self.cell_addr(u));
        let (uns, round) = unpack_flags(flags);
        (uns, round * self.r() + u, val)
    }

    /// Write cell `u` non-transactionally (recovery only — single-threaded).
    pub fn write_cell(&self, pool: &PmemPool, tid: usize, u: u64, uns: bool, idx: u64, val: u64) {
        debug_assert_eq!(idx % self.r(), u % self.r());
        pool.store(tid, self.cell_addr(u), pack_flags(uns, idx / self.r()));
        pool.store(tid, self.cell_addr(u).add(1), val);
    }

    /// Number of words this ring occupies (for persist_range in recovery).
    pub fn footprint_words(&self) -> usize {
        Self::words(self.ring_size, self.nthreads)
    }
}

/// Standalone volatile CRQ (tantrum queue) — mostly a test/bench vehicle;
/// LCRQ composes rings directly.
pub struct Crq {
    pub ring: Ring,
    pub starvation_limit: usize,
}

impl Crq {
    pub fn new(pool: &PmemPool, r: usize, nthreads: usize, starvation_limit: usize) -> Self {
        Self { ring: Ring::alloc(pool, r, nthreads), starvation_limit }
    }

    pub fn enqueue(&self, pool: &PmemPool, tid: usize, item: u64) -> EnqResult {
        self.ring.enqueue(pool, tid, item, self.starvation_limit, None)
    }

    pub fn dequeue(&self, pool: &PmemPool, tid: usize) -> DeqResult {
        self.ring.dequeue(pool, tid, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use std::sync::Arc;

    fn pool() -> Arc<PmemPool> {
        Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 18).with_cost(CostModel::zero()),
        ))
    }

    #[test]
    fn flags_packing_roundtrip() {
        for (uns, round) in [(false, 0u64), (true, 0), (false, 12345), (true, ROUND_MASK)] {
            let f = pack_flags(uns, round);
            assert_eq!(unpack_flags(f), (uns, round));
        }
    }

    #[test]
    fn fresh_cell_decodes_to_paper_initial_value() {
        // All-zero cell == (safe=1, idx=u, ⊥) — the paper's (1, u, ⊥).
        let p = pool();
        let q = Crq::new(&p, 8, 2, 100);
        for u in 0..8u64 {
            let (uns, idx, val) = q.ring.read_cell(&p, 0, u);
            assert!(!uns);
            assert_eq!(idx, u);
            assert_eq!(val, BOT);
        }
    }

    #[test]
    fn fifo_within_ring() {
        let p = pool();
        let q = Crq::new(&p, 64, 2, 1000);
        for v in 0..40u64 {
            assert_eq!(q.enqueue(&p, 0, v), EnqResult::Ok);
        }
        for v in 0..40u64 {
            assert_eq!(q.dequeue(&p, 1), DeqResult::Item(v));
        }
        assert_eq!(q.dequeue(&p, 1), DeqResult::Empty);
    }

    #[test]
    fn wraps_around_ring_multiple_rounds() {
        let p = pool();
        let q = Crq::new(&p, 8, 2, 1000);
        for round in 0..10u64 {
            for v in 0..6u64 {
                assert_eq!(q.enqueue(&p, 0, round * 10 + v), EnqResult::Ok);
            }
            for v in 0..6u64 {
                assert_eq!(q.dequeue(&p, 1), DeqResult::Item(round * 10 + v));
            }
        }
        assert_eq!(q.dequeue(&p, 1), DeqResult::Empty);
    }

    #[test]
    fn closes_when_full() {
        let p = pool();
        let q = Crq::new(&p, 8, 1, 1_000_000);
        for v in 0..8u64 {
            assert_eq!(q.enqueue(&p, 0, v), EnqResult::Ok);
        }
        assert_eq!(q.enqueue(&p, 0, 99), EnqResult::Closed);
        assert!(q.ring.is_closed(&p, 0));
        // Tantrum semantics: every later enqueue is CLOSED too.
        assert_eq!(q.enqueue(&p, 0, 100), EnqResult::Closed);
        // But dequeues still drain the ring.
        for v in 0..8u64 {
            assert_eq!(q.dequeue(&p, 0), DeqResult::Item(v));
        }
        assert_eq!(q.dequeue(&p, 0), DeqResult::Empty);
    }

    #[test]
    fn starvation_limit_closes() {
        let p = pool();
        // Limit 0 → first failed attempt closes.
        let q = Crq::new(&p, 8, 1, 0);
        // Burn index 0 with a dequeuer so the enqueuer's first try fails.
        assert_eq!(q.dequeue(&p, 0), DeqResult::Empty);
        // Enqueue at idx 1 succeeds immediately (cell 1 fresh) — no close.
        assert_eq!(q.enqueue(&p, 0, 1), EnqResult::Ok);
    }

    #[test]
    fn empty_transition_blocks_late_enqueuer() {
        let p = pool();
        let q = Crq::new(&p, 8, 2, 1000);
        // Dequeuer arrives first at index 0: empty transition bumps the
        // cell's idx to 0+R so the enqueue that reads t=0 must not use it.
        assert_eq!(q.dequeue(&p, 1), DeqResult::Empty);
        let (_, idx, val) = q.ring.read_cell(&p, 0, 0);
        assert_eq!(val, BOT);
        assert_eq!(idx, 8, "empty transition must set idx = h + R");
        // The enqueue that gets t=0 re-FAIs and lands at t=1.
        assert_eq!(q.enqueue(&p, 0, 42), EnqResult::Ok);
        let (_, _, v1) = q.ring.read_cell(&p, 0, 1);
        assert_eq!(v1, enc(42));
        assert_eq!(q.dequeue(&p, 1), DeqResult::Item(42));
    }

    #[test]
    fn unsafe_transition_marks_cell() {
        let p = pool();
        let q = Crq::new(&p, 4, 2, 1000);
        // Fill a round and drain it so indices advance past R.
        for v in 0..4u64 {
            q.enqueue(&p, 0, v);
        }
        // Manually construct the unsafe scenario: a dequeuer with index
        // h = 4 (round 1) finds cell 0 still occupied with idx 0 < h.
        // Force head to 4 (as if 4 dequeues got indices 0-3 but haven't
        // executed their transitions — we emulate the interleaving).
        p.poke(q.ring.head_addr(), 4);
        let res = q.dequeue(&p, 1);
        // Dequeuer h=4 hits cell 0 (occupied, idx 0 < 4): unsafe
        // transition, then h=5 hits cell 1 (idx 1 < 5): unsafe, ... until
        // tail (=4) ≤ h+1 → EMPTY.
        assert_eq!(res, DeqResult::Empty);
        let (uns, idx, val) = q.ring.read_cell(&p, 0, 0);
        assert!(uns, "cell must be marked unsafe");
        assert_eq!(idx, 0);
        assert_eq!(val, enc(0), "unsafe transition must not remove the value");
    }

    #[test]
    fn fix_state_repairs_tail_behind_head() {
        let p = pool();
        let q = Crq::new(&p, 8, 1, 1000);
        // EMPTY dequeues advance Head past Tail...
        for _ in 0..5 {
            assert_eq!(q.dequeue(&p, 0), DeqResult::Empty);
        }
        // ...and FixState (called on the EMPTY path) repairs Tail ≥ Head.
        let (h, t) = q.ring.endpoints(&p, 0);
        assert!(t >= h, "fix_state must ensure tail {t} >= head {h}");
        // Queue still works.
        assert_eq!(q.enqueue(&p, 0, 7), EnqResult::Ok);
        assert_eq!(q.dequeue(&p, 0), DeqResult::Item(7));
    }

    #[test]
    fn mpmc_ring_stress() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let p = pool();
        // Ring sized well above the total item count: CRQ is a tantrum
        // queue and closes permanently when full, which would fail this
        // volatile stress (LCRQ handles closure; tested there).
        let q = Arc::new(Crq::new(&p, 8192, 8, usize::MAX));
        let total = 4 * 1000u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let (p, q) = (Arc::clone(&p), Arc::clone(&q));
            hs.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    // Ring can fill transiently: spin until accepted (the
                    // starvation limit is effectively off).
                    loop {
                        match q.enqueue(&p, pid, pid as u64 * 1000 + i) {
                            EnqResult::Ok => break,
                            EnqResult::Closed => panic!("must not close"),
                        }
                    }
                }
            }));
        }
        for cid in 0..4usize {
            let (p, q) = (Arc::clone(&p), Arc::clone(&q));
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(&p, 4 + cid) {
                        DeqResult::Item(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        DeqResult::Empty => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total, "duplicate dequeues detected");
    }

    #[test]
    fn enqueue_full_check_handles_tail_behind_head() {
        // After fix_state the sign of t-h can flip; the full check must not
        // underflow.
        let p = pool();
        let q = Crq::new(&p, 8, 1, 1000);
        for _ in 0..20 {
            let _ = q.dequeue(&p, 0);
        }
        assert_eq!(q.enqueue(&p, 0, 3), EnqResult::Ok);
        assert_eq!(q.dequeue(&p, 0), DeqResult::Item(3));
    }
}
