//! The queue algorithm family.
//!
//! Conventional (volatile) algorithms from the literature the paper builds
//! on, and the paper's persistent algorithms:
//!
//! | module | algorithm | paper source |
//! |---|---|---|
//! | [`iq`] | IQ — infinite-array queue | §3, Alg. 1 (black) |
//! | [`periq`] | **PerIQ** (+ periodic-persist variant) | §4.1, Alg. 1 + Alg. 6 |
//! | [`crq`] | CRQ — circular ring queue (tantrum) | §3, Alg. 3 (black) |
//! | [`percrq`] | **PerCRQ** (+ local persistence) | §4.2, Alg. 3 |
//! | [`lcrq`] | LCRQ — list of CRQs | §3, Alg. 5 (black) |
//! | [`perlcrq`] | **PerLCRQ** (+ PHead/NoHead/NoTail ablations) | §4.3, Alg. 5 |
//! | [`msq`] | Michael–Scott queue (volatile baseline) | \[19\] |
//! | [`durable_msq`] | persist-everything durable MS queue | \[11\]-style baseline |
//! | [`combining`] | CC-Synch combining; PBQueue, PWFQueue | \[6\], \[9\] |
//! | [`sharded`] | **ShardedQueue** — K-way striped PerLCRQs + batched persistence | beyond the paper (BlockFIFO / Second-Amendment directions) |
//! | [`asyncq`] | **AsyncQueue** — futures over the sharded queue, completion gated on the group-commit psync | beyond the paper (flat-combining / durability-point completion) |
//! | [`blockfifo`] | **BlockFIFO / MultiFIFO** — block-granular claiming (one FAI + one psync per block of `B` ops), d-choice consumer stealing | beyond the paper (arXiv 2507.22764, made persistent) |
//!
//! ## Value encoding
//!
//! Queues store `u64` *items* strictly less than [`MAX_ITEM`]. Internally a
//! cell holds `item + 1` so that the all-zeroes state of freshly allocated
//! (or recovered) NVM is a valid "unoccupied" (`⊥ = 0`) cell — this removes
//! any need to initialize/persist fresh ring segments cell-by-cell and is a
//! bijective re-encoding of the paper's `(s, idx, val)` triplets (see
//! [`crq`] docs for the exact layout).

pub mod asyncq;
pub mod blockfifo;
pub mod combining;
pub mod crq;
pub mod durable_msq;
pub mod iq;
pub mod lcrq;
pub mod msq;
pub mod percrq;
pub mod perlcrq;
pub mod periq;
pub mod sharded;

use std::sync::Arc;

use crate::pmem::{PlacementPolicy, PmemPool, Topology};

/// Maximum enqueueable item value (exclusive). Items occupy 62 bits; the
/// framework reserves the top bits for sentinels.
pub const MAX_ITEM: u64 = 1 << 62;

/// Errors surfaced by queue operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueueError {
    /// The item value is out of the supported range (`>= MAX_ITEM`).
    ItemOutOfRange(u64),
    /// The backing structure is out of capacity (IQ's "infinite" array is a
    /// finite arena in this simulator; size it to the workload).
    CapacityExhausted,
    /// The [`QueueConfig`] is invalid for the requested construction (e.g.
    /// zero shards, zero batch size, non-power-of-two ring). Returned by
    /// [`QueueConfig::validate`] and by constructors that take a `Result`
    /// path (notably [`sharded::ShardedQueue`]); infallible constructors
    /// panic with the same message if handed a config that was never
    /// validated.
    BadConfig(&'static str),
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::ItemOutOfRange(v) => write!(f, "item {v} out of range (>= 2^62)"),
            QueueError::CapacityExhausted => write!(f, "queue capacity exhausted"),
            QueueError::BadConfig(msg) => write!(f, "invalid queue config: {msg}"),
        }
    }
}

impl std::error::Error for QueueError {}

/// A concurrent multi-producer multi-consumer FIFO queue.
///
/// `tid` identifies the calling thread (`< nthreads` passed at
/// construction); the same `tid` must not be used by two live threads.
pub trait ConcurrentQueue: Send + Sync {
    /// Enqueue `item` (must be `< MAX_ITEM`).
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError>;

    /// Dequeue the oldest item; `None` means EMPTY.
    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError>;

    /// Algorithm name (stable; used by the bench registry and reports).
    fn name(&self) -> &'static str;
}

/// A durably linearizable queue: after [`crate::pmem::PmemPool::crash`],
/// calling [`PersistentQueue::recover`] (single-threaded) restores a state
/// reflecting every operation completed before the crash.
pub trait PersistentQueue: ConcurrentQueue {
    /// The recovery function (paper §4). Runs single-threaded after a
    /// crash; also reinitializes any volatile bookkeeping this queue keeps
    /// outside the pool.
    ///
    /// Contract: `pool` must be the pool the queue was constructed on
    /// (for a multi-pool queue, its topology's primary). Implementations
    /// over several pools may recover from their construction-time
    /// topology and ignore the argument — callers must not use this
    /// parameter to retarget recovery at a different pool.
    fn recover(&self, pool: &PmemPool);

    /// Flush any thread-buffered state (e.g. the sharded queue's
    /// group-commit batches) to NVM. Default: no-op — per-operation
    /// persistent queues have nothing buffered. **Quiescent contexts
    /// only** (all workers stopped).
    fn quiesce(&self) {}

    /// A worker thread is about to start operating as `tid`: reclaim any
    /// per-thread state a dead predecessor left in the slot (e.g. flush
    /// its stranded group-commit batches) and re-randomize per-thread
    /// dispatch state so slot reuse does not skew load. Default: no-op —
    /// per-operation queues keep no per-thread state. The usual `tid`
    /// exclusivity contract applies.
    fn attach(&self, _tid: usize) {}

    /// The worker running as `tid` is done (normal exit): flush its
    /// thread-buffered state. Safe to call from the worker itself, unlike
    /// [`PersistentQueue::quiesce`]. Default: no-op.
    fn detach(&self, _tid: usize) {}
}

/// Construction-time knobs shared across algorithms.
#[derive(Clone, Debug)]
pub struct QueueConfig {
    /// Ring size `R` for CRQ-family algorithms (cells per ring).
    pub ring_size: usize,
    /// Capacity (cells) for IQ-family "infinite" arrays.
    pub iq_capacity: usize,
    /// Enqueue attempts on one CRQ before declaring starvation and closing
    /// it (LCRQ's anti-livelock tantrum trigger).
    pub starvation_limit: usize,
    /// PerIQ: persist `Tail` every `k` enqueues (Alg. 6 tradeoff knob).
    /// `0` = never (pure PerIQ), `1` = every operation.
    pub periq_tail_interval: usize,
    /// PerCRQ/PerLCRQ head-persistence strategy (Fig. 2/3 ablations).
    pub head_mode: HeadPersistMode,
    /// Skip persisting `Tail` on close (Fig. 3 "PerLCRQ (no tail)").
    pub skip_tail_persist: bool,
    /// Disable the §4.2 closedFlag optimization (ablation A3): every
    /// CLOSED return re-persists `Tail`.
    pub disable_closed_flag: bool,
    /// Number of inner queues a [`sharded::ShardedQueue`] stripes over
    /// (ignored by non-sharded algorithms). Must be in `1..=MAX_SHARDS`.
    pub shards: usize,
    /// Enqueue batch size for the sharded queue's amortized-persistence
    /// mode: `1` = persist every operation (plain sharding); `B > 1` =
    /// group-commit every `B` enqueues with a single `psync` (see
    /// [`sharded`] docs). Must be in `1..=MAX_BATCH`.
    pub batch: usize,
    /// Dequeue batch size for the sharded queue's consumer-side group
    /// commit: `1` = persist `Head_i` every dequeue (the paper's per-op
    /// pair); `K > 1` = defer each dequeue's `psync` and drain once per
    /// `K` dequeues, sealing a per-thread persistent dequeue log in the
    /// same drain (see [`sharded`] docs). Must be in `1..=MAX_BATCH`.
    pub batch_deq: usize,
    /// Internal (set by [`sharded::ShardedQueue`] in batched mode): issue
    /// the enqueue cell `pwb` but *defer* its `psync` to the caller, who
    /// must issue one `psync` per batch. Leaving this on without an outer
    /// syncing layer forfeits per-operation durability — never enable it
    /// directly.
    pub defer_enqueue_sync: bool,
    /// Internal (set by [`sharded::ShardedQueue`] when `batch_deq > 1`):
    /// issue the dequeue-side `Head_i` `pwb` but defer its `psync` to the
    /// outer group-commit layer. Never enable directly.
    pub defer_dequeue_sync: bool,
    /// Entries per block for [`blockfifo::BlockFifo`]: producers claim
    /// `block` slots with one FAI and seal them with one psync, so the
    /// persistence budget is `~1/block` psyncs per enqueue — and the
    /// relaxation (overtake) bound grows with it. Must be in
    /// `1..=MAX_BLOCK`; ignored by other algorithms. For blockfifo,
    /// `ring_size` is reused as the per-lane *block* count and
    /// `shards` as the lane count.
    pub block: usize,
    /// MultiFIFO d-choice width for [`blockfifo::BlockFifo`]'s `-multi`
    /// mode: each dequeue samples `dchoice` lanes by backlog hint and
    /// steals from the longest (clamped to the lane count). Must be in
    /// `1..=MAX_SHARDS`; ignored elsewhere.
    pub dchoice: usize,
    /// How a [`sharded::ShardedQueue`] maps shards (and their batch
    /// logs) onto the topology's pools, and whether threads prefer their
    /// home socket's shards (see [`crate::pmem::PlacementPolicy`]).
    /// Ignored by non-sharded algorithms and degenerate on a single-pool
    /// topology (all policies coincide there).
    pub placement: PlacementPolicy,
    /// Recycle retired structures (closed LCRQ ring nodes, retired shard
    /// stripes, drained blockfifo blocks) through the pool's `palloc`
    /// tier. Off = the pre-palloc leak-by-design arena behaviour (the
    /// ablation baseline for `benches/fig13_alloc`).
    pub recycle: bool,
    /// Per-thread palloc magazine capacity per size class (`0` = no
    /// magazines; every recycled allocation goes through the shared
    /// per-class freelist).
    pub magazine: usize,
}

/// Upper bound on [`QueueConfig::shards`].
pub const MAX_SHARDS: usize = 64;
/// Upper bound on [`QueueConfig::batch`] (keeps the per-thread batch log a
/// handful of cache lines).
pub const MAX_BATCH: usize = 32;
/// Upper bound on [`QueueConfig::block`] (keeps a blockfifo block — header
/// word plus entries — within a few cache lines and the 16-bit header
/// count field comfortably in range).
pub const MAX_BLOCK: usize = 64;

impl Default for QueueConfig {
    fn default() -> Self {
        Self {
            ring_size: 1 << 10,
            iq_capacity: 1 << 16,
            starvation_limit: 4096,
            periq_tail_interval: 0,
            head_mode: HeadPersistMode::Local,
            skip_tail_persist: false,
            disable_closed_flag: false,
            shards: 4,
            batch: 1,
            batch_deq: 1,
            defer_enqueue_sync: false,
            defer_dequeue_sync: false,
            block: 16,
            dchoice: 2,
            placement: PlacementPolicy::Interleave,
            recycle: true,
            magazine: crate::pmem::palloc::DEFAULT_MAGAZINE,
        }
    }
}

impl QueueConfig {
    /// Validate the configuration. Every queue constructor calls this (and
    /// panics on `Err` — the uniform construction contract); fallible
    /// entry points such as the CLI and [`sharded::ShardedQueue::new_perlcrq`]
    /// surface the [`QueueError::BadConfig`] instead.
    pub fn validate(&self) -> Result<(), QueueError> {
        if self.ring_size < 2 || !self.ring_size.is_power_of_two() {
            return Err(QueueError::BadConfig("ring_size must be a power of two >= 2"));
        }
        if self.iq_capacity == 0 {
            return Err(QueueError::BadConfig("iq_capacity must be nonzero"));
        }
        if self.shards == 0 || self.shards > MAX_SHARDS {
            return Err(QueueError::BadConfig("shards must be in 1..=64"));
        }
        if self.batch == 0 || self.batch > MAX_BATCH {
            return Err(QueueError::BadConfig("batch must be in 1..=32"));
        }
        if self.batch_deq == 0 || self.batch_deq > MAX_BATCH {
            return Err(QueueError::BadConfig("batch-deq must be in 1..=32"));
        }
        if self.block == 0 || self.block > MAX_BLOCK {
            return Err(QueueError::BadConfig("block must be in 1..=64"));
        }
        if self.dchoice == 0 || self.dchoice > MAX_SHARDS {
            return Err(QueueError::BadConfig("dchoice must be in 1..=64"));
        }
        if self.magazine > 1024 {
            return Err(QueueError::BadConfig("magazine must be <= 1024"));
        }
        if let PlacementPolicy::Pinned(list) = &self.placement {
            if list.is_empty() {
                return Err(QueueError::BadConfig(
                    "pinned placement needs at least one pool id",
                ));
            }
        }
        Ok(())
    }
}

/// Where dequeues persist the head index (§4.2 "Local Persistence").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadPersistMode {
    /// Paper's PerLCRQ: persist the per-thread local copy `Head_i`
    /// (single-writer line — cheap).
    Local,
    /// PerLCRQ-PHead: persist the shared `Head` (hot line — expensive;
    /// Fig. 2 shows this collapsing).
    Shared,
    /// PerLCRQ (no head): elide head pwbs entirely (Fig. 3 upper bound;
    /// NOT durably linearizable — measurement-only).
    None,
}

/// Everything needed to build a queue instance. Queues address memory
/// through the [`Topology`]: single-pool algorithms build on
/// [`QueueCtx::pool`] (the primary), the sharded layer places shards
/// across all pools per [`QueueConfig::placement`].
pub struct QueueCtx {
    pub topo: Topology,
    pub nthreads: usize,
    pub cfg: QueueConfig,
}

impl QueueCtx {
    /// Build a single-pool context (the degenerate topology) — the
    /// common case for tests and single-socket benches.
    pub fn single(pmem: crate::pmem::PmemConfig, nthreads: usize, cfg: QueueConfig) -> QueueCtx {
        QueueCtx { topo: Topology::single(pmem), nthreads, cfg }
    }

    /// The primary pool — where single-pool algorithms live.
    pub fn pool(&self) -> &Arc<PmemPool> {
        self.topo.primary()
    }
}

/// Registry of all benchmarkable algorithms: name → constructor.
/// Persistent algorithms additionally appear in [`persistent_registry`].
pub fn registry() -> Vec<(&'static str, fn(&QueueCtx) -> Arc<dyn ConcurrentQueue>)> {
    vec![
        ("msq", |c| Arc::new(msq::MsQueue::new(c.pool(), c.nthreads))),
        ("durable-msq", |c| Arc::new(durable_msq::DurableMsQueue::new(c.pool(), c.nthreads))),
        ("iq", |c| Arc::new(iq::Iq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("periq", |c| Arc::new(periq::PerIq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("lcrq", |c| Arc::new(lcrq::Lcrq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("perlcrq", |c| Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("perlcrq-phead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::Shared;
            Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, cfg))
        }),
        ("perlcrq-nohead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::None;
            Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, cfg))
        }),
        ("perlcrq-notail", |c| {
            let mut cfg = c.cfg.clone();
            cfg.skip_tail_persist = true;
            Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, cfg))
        }),
        ("pbqueue", |c| Arc::new(combining::pbqueue::PbQueue::new(c.pool(), c.nthreads))),
        ("pwfqueue", |c| Arc::new(combining::pwfqueue::PwfQueue::new(c.pool(), c.nthreads))),
        ("ccqueue", |c| Arc::new(combining::ccqueue::CcQueue::new(c.pool(), c.nthreads))),
        ("sharded-perlcrq", |c| {
            Arc::new(
                sharded::ShardedQueue::new_perlcrq(&c.topo, c.nthreads, c.cfg.clone())
                    .expect("invalid sharded config (call QueueConfig::validate first)"),
            )
        }),
        ("blockfifo", |c| {
            Arc::new(
                blockfifo::BlockFifo::new(&c.topo, c.nthreads, c.cfg.clone(), false)
                    .expect("invalid blockfifo config (call QueueConfig::validate first)"),
            )
        }),
        ("blockfifo-multi", |c| {
            Arc::new(
                blockfifo::BlockFifo::new(&c.topo, c.nthreads, c.cfg.clone(), true)
                    .expect("invalid blockfifo config (call QueueConfig::validate first)"),
            )
        }),
    ]
}

/// All algorithm names, in registry order (the single source of truth the
/// CLI derives its listings, validation and `all` expansion from).
pub fn registry_names() -> Vec<&'static str> {
    registry().iter().map(|(n, _)| *n).collect()
}

/// Names of the persistent algorithms, in registry order.
pub fn persistent_names() -> Vec<&'static str> {
    persistent_registry().iter().map(|(n, _)| *n).collect()
}

/// Persistent algorithms (those with a recovery function), for crash-cycle
/// tests and recovery benches: name → constructor.
pub fn persistent_registry() -> Vec<(&'static str, fn(&QueueCtx) -> Arc<dyn PersistentQueue>)> {
    vec![
        ("periq", |c| Arc::new(periq::PerIq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("perlcrq", |c| Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, c.cfg.clone()))),
        ("perlcrq-phead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::Shared;
            Arc::new(perlcrq::PerLcrq::new(c.pool(), c.nthreads, cfg))
        }),
        ("pbqueue", |c| Arc::new(combining::pbqueue::PbQueue::new(c.pool(), c.nthreads))),
        ("pwfqueue", |c| Arc::new(combining::pwfqueue::PwfQueue::new(c.pool(), c.nthreads))),
        ("durable-msq", |c| Arc::new(durable_msq::DurableMsQueue::new(c.pool(), c.nthreads))),
        ("sharded-perlcrq", |c| {
            Arc::new(
                sharded::ShardedQueue::new_perlcrq(&c.topo, c.nthreads, c.cfg.clone())
                    .expect("invalid sharded config (call QueueConfig::validate first)"),
            )
        }),
        ("blockfifo", |c| {
            Arc::new(
                blockfifo::BlockFifo::new(&c.topo, c.nthreads, c.cfg.clone(), false)
                    .expect("invalid blockfifo config (call QueueConfig::validate first)"),
            )
        }),
        ("blockfifo-multi", |c| {
            Arc::new(
                blockfifo::BlockFifo::new(&c.topo, c.nthreads, c.cfg.clone(), true)
                    .expect("invalid blockfifo config (call QueueConfig::validate first)"),
            )
        }),
    ]
}

/// Look up a constructor by name.
pub fn by_name(name: &str) -> Option<fn(&QueueCtx) -> Arc<dyn ConcurrentQueue>> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}

/// Look up a persistent constructor by name.
pub fn persistent_by_name(name: &str) -> Option<fn(&QueueCtx) -> Arc<dyn PersistentQueue>> {
    persistent_registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_range_guard() {
        assert!(MAX_ITEM < u64::MAX / 2);
    }

    #[test]
    fn registry_names_unique() {
        let names: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn persistent_registry_is_subset() {
        let all: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        for (n, _) in persistent_registry() {
            assert!(all.contains(&n), "{n} missing from main registry");
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("perlcrq").is_some());
        assert!(by_name("nonexistent").is_none());
        assert!(persistent_by_name("pbqueue").is_some());
        assert!(persistent_by_name("msq").is_none(), "msq is not persistent");
        assert!(by_name("sharded-perlcrq").is_some());
        assert!(persistent_by_name("sharded-perlcrq").is_some());
        assert!(by_name("blockfifo").is_some());
        assert!(persistent_by_name("blockfifo").is_some());
        assert!(persistent_by_name("blockfifo-multi").is_some());
    }

    #[test]
    fn name_helpers_match_registries() {
        assert_eq!(registry_names().len(), registry().len());
        assert_eq!(persistent_names().len(), persistent_registry().len());
        assert!(registry_names().contains(&"sharded-perlcrq"));
    }

    #[test]
    fn config_validation() {
        assert!(QueueConfig::default().validate().is_ok());
        let bad = QueueConfig { shards: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { batch: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { batch: MAX_BATCH + 1, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { batch_deq: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { batch_deq: MAX_BATCH + 1, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { block: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { block: MAX_BLOCK + 1, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { dchoice: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { dchoice: MAX_SHARDS + 1, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { ring_size: 100, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad = QueueConfig { iq_capacity: 0, ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let bad =
            QueueConfig { placement: PlacementPolicy::Pinned(vec![]), ..Default::default() };
        assert!(matches!(bad.validate(), Err(QueueError::BadConfig(_))));
        let ok = QueueConfig {
            placement: PlacementPolicy::Pinned(vec![0, 1]),
            ..Default::default()
        };
        assert!(ok.validate().is_ok());
    }
}
