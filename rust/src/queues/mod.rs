//! The queue algorithm family.
//!
//! Conventional (volatile) algorithms from the literature the paper builds
//! on, and the paper's persistent algorithms:
//!
//! | module | algorithm | paper source |
//! |---|---|---|
//! | [`iq`] | IQ — infinite-array queue | §3, Alg. 1 (black) |
//! | [`periq`] | **PerIQ** (+ periodic-persist variant) | §4.1, Alg. 1 + Alg. 6 |
//! | [`crq`] | CRQ — circular ring queue (tantrum) | §3, Alg. 3 (black) |
//! | [`percrq`] | **PerCRQ** (+ local persistence) | §4.2, Alg. 3 |
//! | [`lcrq`] | LCRQ — list of CRQs | §3, Alg. 5 (black) |
//! | [`perlcrq`] | **PerLCRQ** (+ PHead/NoHead/NoTail ablations) | §4.3, Alg. 5 |
//! | [`msq`] | Michael–Scott queue (volatile baseline) | \[19\] |
//! | [`durable_msq`] | persist-everything durable MS queue | \[11\]-style baseline |
//! | [`combining`] | CC-Synch combining; PBQueue, PWFQueue | \[6\], \[9\] |
//!
//! ## Value encoding
//!
//! Queues store `u64` *items* strictly less than [`MAX_ITEM`]. Internally a
//! cell holds `item + 1` so that the all-zeroes state of freshly allocated
//! (or recovered) NVM is a valid "unoccupied" (`⊥ = 0`) cell — this removes
//! any need to initialize/persist fresh ring segments cell-by-cell and is a
//! bijective re-encoding of the paper's `(s, idx, val)` triplets (see
//! [`crq`] docs for the exact layout).

pub mod combining;
pub mod crq;
pub mod durable_msq;
pub mod iq;
pub mod lcrq;
pub mod msq;
pub mod percrq;
pub mod perlcrq;
pub mod periq;

use std::sync::Arc;

use crate::pmem::PmemPool;

/// Maximum enqueueable item value (exclusive). Items occupy 62 bits; the
/// framework reserves the top bits for sentinels.
pub const MAX_ITEM: u64 = 1 << 62;

/// Errors surfaced by queue operations.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueueError {
    /// The item value is out of the supported range (`>= MAX_ITEM`).
    ItemOutOfRange(u64),
    /// The backing structure is out of capacity (IQ's "infinite" array is a
    /// finite arena in this simulator; size it to the workload).
    CapacityExhausted,
}

impl std::fmt::Display for QueueError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueError::ItemOutOfRange(v) => write!(f, "item {v} out of range (>= 2^62)"),
            QueueError::CapacityExhausted => write!(f, "queue capacity exhausted"),
        }
    }
}

impl std::error::Error for QueueError {}

/// A concurrent multi-producer multi-consumer FIFO queue.
///
/// `tid` identifies the calling thread (`< nthreads` passed at
/// construction); the same `tid` must not be used by two live threads.
pub trait ConcurrentQueue: Send + Sync {
    /// Enqueue `item` (must be `< MAX_ITEM`).
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError>;

    /// Dequeue the oldest item; `None` means EMPTY.
    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError>;

    /// Algorithm name (stable; used by the bench registry and reports).
    fn name(&self) -> &'static str;
}

/// A durably linearizable queue: after [`crate::pmem::PmemPool::crash`],
/// calling [`PersistentQueue::recover`] (single-threaded) restores a state
/// reflecting every operation completed before the crash.
pub trait PersistentQueue: ConcurrentQueue {
    /// The recovery function (paper §4). Runs single-threaded after a
    /// crash; also reinitializes any volatile bookkeeping this queue keeps
    /// outside the pool.
    fn recover(&self, pool: &PmemPool);
}

/// Construction-time knobs shared across algorithms.
#[derive(Clone, Debug)]
pub struct QueueConfig {
    /// Ring size `R` for CRQ-family algorithms (cells per ring).
    pub ring_size: usize,
    /// Capacity (cells) for IQ-family "infinite" arrays.
    pub iq_capacity: usize,
    /// Enqueue attempts on one CRQ before declaring starvation and closing
    /// it (LCRQ's anti-livelock tantrum trigger).
    pub starvation_limit: usize,
    /// PerIQ: persist `Tail` every `k` enqueues (Alg. 6 tradeoff knob).
    /// `0` = never (pure PerIQ), `1` = every operation.
    pub periq_tail_interval: usize,
    /// PerCRQ/PerLCRQ head-persistence strategy (Fig. 2/3 ablations).
    pub head_mode: HeadPersistMode,
    /// Skip persisting `Tail` on close (Fig. 3 "PerLCRQ (no tail)").
    pub skip_tail_persist: bool,
    /// Disable the §4.2 closedFlag optimization (ablation A3): every
    /// CLOSED return re-persists `Tail`.
    pub disable_closed_flag: bool,
}

impl Default for QueueConfig {
    fn default() -> Self {
        Self {
            ring_size: 1 << 10,
            iq_capacity: 1 << 16,
            starvation_limit: 4096,
            periq_tail_interval: 0,
            head_mode: HeadPersistMode::Local,
            skip_tail_persist: false,
            disable_closed_flag: false,
        }
    }
}

/// Where dequeues persist the head index (§4.2 "Local Persistence").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadPersistMode {
    /// Paper's PerLCRQ: persist the per-thread local copy `Head_i`
    /// (single-writer line — cheap).
    Local,
    /// PerLCRQ-PHead: persist the shared `Head` (hot line — expensive;
    /// Fig. 2 shows this collapsing).
    Shared,
    /// PerLCRQ (no head): elide head pwbs entirely (Fig. 3 upper bound;
    /// NOT durably linearizable — measurement-only).
    None,
}

/// Everything needed to build a queue instance.
pub struct QueueCtx {
    pub pool: Arc<PmemPool>,
    pub nthreads: usize,
    pub cfg: QueueConfig,
}

/// Registry of all benchmarkable algorithms: name → constructor.
/// Persistent algorithms additionally appear in [`persistent_registry`].
pub fn registry() -> Vec<(&'static str, fn(&QueueCtx) -> Arc<dyn ConcurrentQueue>)> {
    vec![
        ("msq", |c| Arc::new(msq::MsQueue::new(&c.pool, c.nthreads))),
        ("durable-msq", |c| Arc::new(durable_msq::DurableMsQueue::new(&c.pool, c.nthreads))),
        ("iq", |c| Arc::new(iq::Iq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("periq", |c| Arc::new(periq::PerIq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("lcrq", |c| Arc::new(lcrq::Lcrq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("perlcrq", |c| Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("perlcrq-phead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::Shared;
            Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, cfg))
        }),
        ("perlcrq-nohead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::None;
            Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, cfg))
        }),
        ("perlcrq-notail", |c| {
            let mut cfg = c.cfg.clone();
            cfg.skip_tail_persist = true;
            Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, cfg))
        }),
        ("pbqueue", |c| Arc::new(combining::pbqueue::PbQueue::new(&c.pool, c.nthreads))),
        ("pwfqueue", |c| Arc::new(combining::pwfqueue::PwfQueue::new(&c.pool, c.nthreads))),
        ("ccqueue", |c| Arc::new(combining::ccqueue::CcQueue::new(&c.pool, c.nthreads))),
    ]
}

/// Persistent algorithms (those with a recovery function), for crash-cycle
/// tests and recovery benches: name → constructor.
pub fn persistent_registry() -> Vec<(&'static str, fn(&QueueCtx) -> Arc<dyn PersistentQueue>)> {
    vec![
        ("periq", |c| Arc::new(periq::PerIq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("perlcrq", |c| Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, c.cfg.clone()))),
        ("perlcrq-phead", |c| {
            let mut cfg = c.cfg.clone();
            cfg.head_mode = HeadPersistMode::Shared;
            Arc::new(perlcrq::PerLcrq::new(&c.pool, c.nthreads, cfg))
        }),
        ("pbqueue", |c| Arc::new(combining::pbqueue::PbQueue::new(&c.pool, c.nthreads))),
        ("pwfqueue", |c| Arc::new(combining::pwfqueue::PwfQueue::new(&c.pool, c.nthreads))),
        ("durable-msq", |c| Arc::new(durable_msq::DurableMsQueue::new(&c.pool, c.nthreads))),
    ]
}

/// Look up a constructor by name.
pub fn by_name(name: &str) -> Option<fn(&QueueCtx) -> Arc<dyn ConcurrentQueue>> {
    registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}

/// Look up a persistent constructor by name.
pub fn persistent_by_name(name: &str) -> Option<fn(&QueueCtx) -> Arc<dyn PersistentQueue>> {
    persistent_registry().into_iter().find(|(n, _)| *n == name).map(|(_, f)| f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_range_guard() {
        assert!(MAX_ITEM < u64::MAX / 2);
    }

    #[test]
    fn registry_names_unique() {
        let names: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn persistent_registry_is_subset() {
        let all: Vec<&str> = registry().iter().map(|(n, _)| *n).collect();
        for (n, _) in persistent_registry() {
            assert!(all.contains(&n), "{n} missing from main registry");
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("perlcrq").is_some());
        assert!(by_name("nonexistent").is_none());
        assert!(persistent_by_name("pbqueue").is_some());
        assert!(persistent_by_name("msq").is_none(), "msq is not persistent");
    }
}
