//! PerLCRQ — the paper's headline algorithm (§4.3, Algorithm 5): a
//! durably linearizable unbounded FIFO queue executing **one pwb + psync
//! pair per operation** on low-contention locations.
//!
//! Composition: [`super::lcrq::LcrqCore`] (list of rings, with Algorithm
//! 5's persistence sites enabled) over [`super::crq::Ring`] (with Algorithm
//! 3's PerCRQ persistence sites enabled) on the simulated-NVM
//! [`crate::pmem::PmemPool`].
//!
//! The `HeadPersistMode`/`skip_tail_persist` knobs in [`QueueConfig`]
//! produce the paper's measured variants:
//!
//! * `Local` (default) — **PerLCRQ**: dequeues persist the per-thread
//!   `Head_i` copy (§4.2 local persistence);
//! * `Shared` — **PerLCRQ-PHead**: dequeues persist the contended shared
//!   `Head` (Fig. 2's collapsing curve);
//! * `None` — **PerLCRQ (no head)** (Fig. 3; not durably linearizable);
//! * `skip_tail_persist` — **PerLCRQ (no tail)** (Fig. 3).

use std::sync::Arc;

use super::lcrq::{core_persist_cfg, LcrqCore};
use super::{
    ConcurrentQueue, HeadPersistMode, PersistentQueue, QueueConfig, QueueError,
};
use crate::pmem::PmemPool;

/// The persistent LCRQ.
pub struct PerLcrq {
    core: LcrqCore,
    variant: &'static str,
}

impl PerLcrq {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig) -> Self {
        Self::new_at(pool, nthreads, cfg, 0)
    }

    /// Construct on a live worker thread's slot: construction-time pmem
    /// operations are charged to `tid` (see [`LcrqCore::new_at`]). Used
    /// by the sharded layer's online re-sharding to allocate fresh
    /// stripes mid-run.
    pub fn new_at(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig, tid: usize) -> Self {
        let variant = match (cfg.head_mode, cfg.skip_tail_persist) {
            (HeadPersistMode::Local, false) => "perlcrq",
            (HeadPersistMode::Shared, _) => "perlcrq-phead",
            (HeadPersistMode::None, _) => "perlcrq-nohead",
            (HeadPersistMode::Local, true) => "perlcrq-notail",
        };
        let persist = core_persist_cfg(&cfg);
        Self { core: LcrqCore::new_at(pool, nthreads, &cfg, Some(persist), tid), variant }
    }

    /// Node count (test observability).
    pub fn node_count(&self, tid: usize) -> usize {
        self.core.node_count(tid)
    }

    /// The list-of-rings core (used by [`super::sharded`] for traced
    /// enqueues and batch-log reconciliation).
    pub(crate) fn core(&self) -> &LcrqCore {
        &self.core
    }
}

impl ConcurrentQueue for PerLcrq {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        self.core.enqueue(tid, item)
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        self.core.dequeue(tid)
    }

    fn name(&self) -> &'static str {
        self.variant
    }
}

impl PersistentQueue for PerLcrq {
    fn recover(&self, pool: &PmemPool) {
        self.core.recover(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(ring: usize) -> (Arc<PmemPool>, PerLcrq) {
        mk_full(ring, HeadPersistMode::Local, 0.0, 0.0)
    }

    fn mk_full(
        ring: usize,
        mode: HeadPersistMode,
        evict: f64,
        pending: f64,
    ) -> (Arc<PmemPool>, PerLcrq) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 21,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed: 77,
        }));
        let cfg = QueueConfig { ring_size: ring, head_mode: mode, ..Default::default() };
        let q = PerLcrq::new(&pool, 8, cfg);
        (pool, q)
    }

    #[test]
    fn fifo_across_rings() {
        let (_p, q) = mk(8);
        for v in 0..200u64 {
            q.enqueue(0, v).unwrap();
        }
        assert!(q.node_count(0) >= 2);
        for v in 0..200u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn one_persistence_pair_per_op_steady_state() {
        // In steady state (no ring closure) each op must do exactly one
        // pwb+psync pair.
        let (p, q) = mk(1 << 10);
        q.enqueue(0, 1).unwrap(); // warm up
        p.stats.reset();
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
        }
        let s = p.stats.total();
        assert_eq!(s.pwbs, 50, "steady-state enqueue: one pwb each");
        assert_eq!(s.psyncs, 50);
        p.stats.reset();
        for _ in 0..20 {
            assert!(q.dequeue(1).unwrap().is_some());
        }
        let s = p.stats.total();
        assert_eq!(s.pwbs, 20, "steady-state dequeue: one pwb each");
        assert_eq!(s.psyncs, 20);
    }

    #[test]
    fn survives_crash_mid_stream() {
        let (p, q) = mk(16);
        for v in 0..60u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..25u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        let mut rng = Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 25..60u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v), "item {v} lost across crash");
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
        // Still fully operational.
        q.enqueue(2, 999).unwrap();
        assert_eq!(q.dequeue(3).unwrap(), Some(999));
    }

    #[test]
    fn recovery_walks_past_stale_last() {
        // Crash right after a node append whose Last update never happened:
        // recovery must extend Last to the true end.
        let (p, q) = mk(4);
        for v in 0..20u64 {
            q.enqueue(0, v).unwrap(); // multiple nodes
        }
        // Make Last stale in NVM: it was persisted only at construction
        // (pointing at node 1) unless evicted; recovery must walk.
        let mut rng = Xoshiro256::seed_from(4);
        p.crash(&mut rng);
        q.recover(&p);
        // All items persisted pre-crash must drain in order.
        for v in 0..20u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
        // Enqueues after recovery land at the real end (Last repaired).
        q.enqueue(0, 555).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(555));
    }

    #[test]
    fn empty_recovery() {
        let (p, q) = mk(8);
        let mut rng = Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0).unwrap(), None);
        q.enqueue(0, 1).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
    }

    #[test]
    fn double_crash_stability() {
        let (p, q) = mk(8);
        for v in 0..30u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(6);
        p.crash(&mut rng);
        q.recover(&p);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 0..30u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
    }

    #[test]
    fn phead_variant_flushes_shared_head() {
        let (p, q) = mk_full(64, HeadPersistMode::Shared, 0.0, 0.0);
        q.enqueue(0, 1).unwrap();
        let first_node = crate::pmem::PAddr::from_u64(p.peek(q.core.first));
        let ring = crate::queues::crq::Ring::at(
            first_node.add(crate::pmem::WORDS_PER_LINE),
            64,
            8,
        );
        assert_eq!(q.dequeue(1).unwrap(), Some(1));
        assert_eq!(p.read_shadow(ring.head_addr()), 1, "PHead must flush shared Head");
    }

    #[test]
    fn variant_names() {
        let (_p, q) = mk_full(8, HeadPersistMode::Shared, 0.0, 0.0);
        assert_eq!(q.name(), "perlcrq-phead");
        let (_p, q) = mk_full(8, HeadPersistMode::None, 0.0, 0.0);
        assert_eq!(q.name(), "perlcrq-nohead");
        let (_p, q) = mk(8);
        assert_eq!(q.name(), "perlcrq");
    }

    #[test]
    fn crash_cycles_under_concurrency() {
        install_quiet_crash_hook();
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 22,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed: 99,
        }));
        let cfg = QueueConfig { ring_size: 64, ..Default::default() };
        let q = Arc::new(PerLcrq::new(&pool, 4, cfg));
        let mut rng = Xoshiro256::seed_from(100);
        let mut returned: Vec<u64> = Vec::new();
        let mut enq_started: u64 = 0;
        for _cycle in 0..5 {
            pool.arm_crash_after(2_000 + rng.next_below(2_000));
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                let base = enq_started + tid as u64 * 100_000;
                hs.push(std::thread::spawn(move || {
                    let mut mine: Vec<u64> = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..100_000u64 {
                            q.enqueue(tid, base + i).unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            enq_started += 1_000_000;
            pool.crash(&mut rng);
            q.recover(&pool);
        }
        // Drain post-recovery and verify global no-duplication.
        while let Some(v) = q.dequeue(0).unwrap() {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate item observed across crash cycles");
    }
}
