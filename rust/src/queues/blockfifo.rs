//! BlockFIFO — a persistent relaxed-FIFO queue with **block-granular**
//! claiming, after "BlockFIFO & MultiFIFO: Scalable Relaxed Queues"
//! (arXiv 2507.22764), made durable on the simulated-NVM substrate.
//!
//! The paper this repo reproduces gets its win by spending exactly one
//! pwb + psync pair per *operation*. This tier moves one step further out
//! on that curve: producers claim a whole block of `B` slots with a
//! **single FAI**, fill it with plain stores, and *seal* it with one
//! header write + one `psync` — so the persistence budget is `~1/B` FAIs
//! and `~1/B` psyncs per enqueue. Consumers mirror the same shape: they
//! claim a committed block with one CAS (persisted with one psync), then
//! drain it privately with plain loads. The price is relaxation: blocks
//! still being filled are skipped by consumers, so items can overtake
//! each other by a bounded amount (see [`crate::verify::relaxation_for`]).
//!
//! ## Layout (per sub-queue "lane")
//!
//! ```text
//! alloc: one cache line   — producer block-claim FAI counter
//! blocks[nblocks], each line-aligned:
//!     word 0      header: (state << 32) | (start << 16) | count
//!     words 1..=B entries (enc(item) = item + 1; 0 = never written)
//! ```
//!
//! Header states (the all-zeroes fresh-NVM word is a valid `FREE`):
//!
//! | state | meaning |
//! |---|---|
//! | `FREE` (0) | unclaimed, or claimed and still being filled (volatile) |
//! | `COMMITTED` | sealed: entries `[start, count)` are published + durable |
//! | `DRAINING` | claimed by a consumer; `start` is the durable resume point |
//! | `CONSUMED` | fully drained (or discarded by recovery) |
//!
//! ## Crash semantics (buffered durable linearizability)
//!
//! * An unsealed block is invisible and unflushed: a crash loses at most
//!   `B - 1` *returned* enqueues per producer (the `B`-th triggers the
//!   seal before returning) — the checker's trailing-loss window.
//! * A `COMMITTED` header can land durably while some entry lines miss
//!   the crash cut (the seal's psync was interrupted): recovery
//!   *reconciles* such durably-claimed-but-unfilled blocks by compacting
//!   the surviving entries — the missing ones never had a completed
//!   psync, so they fall under the same crash-gated loss window.
//! * A `DRAINING` block rolls back to `COMMITTED` at its durable `start`:
//!   up to `B` returned dequeues per consumer may be redelivered after a
//!   crash — the checker's trailing-redelivery window.
//! * Claimed blocks that left *no* durable trace are indistinguishable
//!   from unclaimed ones and are safely reused; claimed blocks with junk
//!   entries under a `FREE` header are discarded (never published).
//!
//! ## Block recycling
//!
//! Fresh block indices are claimed monotonically, but (with
//! `QueueConfig::recycle` on, the default) fully-drained blocks re-enter
//! a per-lane volatile pool and are reused by producers, so steady-state
//! memory is bounded by the in-flight backlog instead of "capacity =
//! total enqueues ever". A retired index is reusable only once its
//! `CONSUMED` header is **durable** (checked against the NVM shadow at
//! claim time — otherwise a crash could roll the header back to a
//! pre-retirement state while new items sit in the entries), and reuse
//! starts by durably scrubbing the whole block back to all-zeroes, making
//! it byte-identical to a claimed-but-untouched fresh block: every crash
//! rule below applies to recycled blocks verbatim. Recovery rebuilds the
//! volatile pool from the durable headers. With recycling off, this is
//! the paper's IQ-style "infinite array" tier: size `ring_size` (the
//! per-lane block count) to the workload's total volume.
//!
//! ## MultiFIFO mode
//!
//! `blockfifo` stripes over `shards` lanes with round-robin producers and
//! sweeping consumers. `blockfifo-multi` keeps the producers but has each
//! consumer sample `dchoice` lanes by [`BlockFifo::len_hint`] and steal
//! from the longest (d-choice load balancing); a full sweep backstops the
//! sampling so EMPTY is only reported after every lane was scanned.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crossbeam_utils::CachePadded;

use super::iq::{dec, enc};
use super::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError, MAX_ITEM};
use crate::obs::{self, ObsSite};
use crate::pmem::{Hotness, PAddr, PmemPool, Topology, WORDS_PER_LINE};

const ST_FREE: u64 = 0;
const ST_COMMITTED: u64 = 1;
const ST_DRAINING: u64 = 2;
const ST_CONSUMED: u64 = 3;

#[inline]
fn hdr(state: u64, start: usize, count: usize) -> u64 {
    (state << 32) | ((start as u64) << 16) | count as u64
}

#[inline]
fn hdr_state(h: u64) -> u64 {
    h >> 32
}

#[inline]
fn hdr_start(h: u64) -> usize {
    ((h >> 16) & 0xFFFF) as usize
}

#[inline]
fn hdr_count(h: u64) -> usize {
    (h & 0xFFFF) as usize
}

/// One striped sub-queue: a claim counter plus a line-aligned block array
/// on the pool its placement policy chose.
struct Lane {
    pool: Arc<PmemPool>,
    /// Producer frontier (FAI target) — its own hot line.
    alloc: PAddr,
    /// Base of the block array.
    blocks: PAddr,
    nblocks: usize,
    /// Words per block slot (line-aligned: `1 + block` rounded up).
    stride: usize,
    /// Volatile consumer low-water mark: the smallest index that might
    /// not be `CONSUMED` yet. Advanced by consumer scans (fetch_max),
    /// rolled back (fetch_min) when a recycled block below it is
    /// reclaimed; rebuilt by recovery.
    cursor: CachePadded<AtomicU64>,
    /// Retired block indices eligible for producer reuse (recycling on).
    /// Volatile — rebuilt by recovery from the durable headers. An entry
    /// may be ahead of its retirement pwb; the claim path re-checks the
    /// shadow header and rotates unripe entries to the back.
    recycle: Mutex<VecDeque<usize>>,
}

/// A producer's open (claimed, still-filling, unpublished) block.
#[derive(Clone, Copy)]
struct Open {
    lane: usize,
    idx: usize,
    count: usize,
}

/// A consumer's claimed block being drained privately.
#[derive(Clone, Copy)]
struct Drain {
    lane: usize,
    idx: usize,
    pos: usize,
    count: usize,
}

/// Per-thread volatile state. Exclusive-logical-owner: only thread `tid`
/// touches slot `tid` while workers run; `quiesce`/`recover`/`attach`
/// access it only from quiescent contexts (the same contract as
/// `sharded::SlotState`).
#[derive(Default)]
struct SlotState {
    open: Option<Open>,
    draining: Option<Drain>,
    /// Producer round-robin ticket: block `t` goes to lane
    /// `(tid + t) % lanes`.
    ticket: usize,
    /// Consumer sweep rotation (fairness across lanes).
    rr: usize,
    /// d-choice sampling state (cheap LCG; no external RNG dependency).
    rng: u64,
}

struct Slot(UnsafeCell<SlotState>);

unsafe impl Sync for Slot {}

/// The block-granular persistent relaxed queue. See module docs.
pub struct BlockFifo {
    lanes: Vec<Lane>,
    block: usize,
    dchoice: usize,
    multi: bool,
    nthreads: usize,
    /// Reuse drained blocks (see module docs). Off = the historical
    /// never-recycled "infinite array" behaviour, kept for ablation.
    recycle_on: bool,
    slots: Vec<CachePadded<Slot>>,
}

impl BlockFifo {
    /// Build over `cfg.shards` lanes of `cfg.ring_size` blocks of
    /// `cfg.block` entries each, placed across `topo`'s pools by
    /// `cfg.placement`. `multi` selects d-choice consumer sampling
    /// (`cfg.dchoice` lanes per attempt).
    pub fn new(
        topo: &Topology,
        nthreads: usize,
        cfg: QueueConfig,
        multi: bool,
    ) -> Result<Self, QueueError> {
        cfg.validate()?;
        let nlanes = cfg.shards;
        let nblocks = cfg.ring_size;
        let stride_lines = (cfg.block + 1).div_ceil(WORDS_PER_LINE);
        let mut lanes = Vec::with_capacity(nlanes);
        for l in 0..nlanes {
            let pool = Arc::clone(topo.pool(cfg.placement.pool_of(l, topo.len())));
            let alloc = pool.alloc_lines(1);
            pool.set_hot(alloc, 1, Hotness::Global);
            // Fresh lines are all-zeroes == every header FREE, every entry
            // unwritten: no initialization stores (or psyncs) needed.
            let blocks = pool.alloc_lines(nblocks * stride_lines);
            lanes.push(Lane {
                pool,
                alloc,
                blocks,
                nblocks,
                stride: stride_lines * WORDS_PER_LINE,
                cursor: CachePadded::new(AtomicU64::new(0)),
                recycle: Mutex::new(VecDeque::new()),
            });
        }
        let slots = (0..nthreads)
            .map(|t| {
                CachePadded::new(Slot(UnsafeCell::new(SlotState {
                    rng: (t as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1,
                    ..Default::default()
                })))
            })
            .collect();
        Ok(Self {
            lanes,
            block: cfg.block,
            dchoice: cfg.dchoice.clamp(1, nlanes),
            multi,
            nthreads,
            recycle_on: cfg.recycle,
            slots,
        })
    }

    #[allow(clippy::mut_from_ref)]
    fn slot(&self, tid: usize) -> &mut SlotState {
        // SAFETY: exclusive-logical-owner — see SlotState docs.
        unsafe { &mut *self.slots[tid].0.get() }
    }

    #[inline]
    fn block_base(&self, lane: &Lane, idx: usize) -> PAddr {
        lane.blocks.add(idx * lane.stride)
    }

    #[inline]
    fn header_addr(&self, lane: &Lane, idx: usize) -> PAddr {
        self.block_base(lane, idx)
    }

    #[inline]
    fn entry_addr(&self, lane: &Lane, idx: usize, j: usize) -> PAddr {
        self.block_base(lane, idx).add(1 + j)
    }

    /// Record a fully-retired block index for producer reuse. The caller
    /// has already stored (at least requested write-back of) its
    /// `CONSUMED` header; the claim path re-checks durability.
    fn retire_idx(&self, lane: &Lane, idx: usize) {
        if !self.recycle_on {
            return;
        }
        lane.recycle.lock().unwrap_or_else(|e| e.into_inner()).push_back(idx);
    }

    /// Pop a reusable retired block from the lane's recycle pool. A block
    /// is reusable only once its `CONSUMED` header is durable (shadow
    /// check) — otherwise a crash could roll the header back to a
    /// pre-retirement `COMMITTED`/`DRAINING` state whose start/count
    /// describe the *previous* generation while new entries sit in the
    /// block. Unripe entries rotate to the back until a later psync
    /// drains their retirement pwb. On success the whole block is
    /// durably scrubbed to all-zeroes (simulator formatting, like the
    /// fresh arena — unmetered), making it byte-identical to a
    /// claimed-but-untouched fresh block so every recovery rule applies
    /// verbatim; in particular an unsealed crash leaves durable
    /// FREE + zero entries, which recovery retires back into the pool.
    fn claim_recycled(&self, lane: &Lane) -> Option<usize> {
        if !self.recycle_on {
            return None;
        }
        let mut rl = lane.recycle.lock().unwrap_or_else(|e| e.into_inner());
        for _ in 0..rl.len() {
            let idx = rl.pop_front().expect("len-bounded loop");
            if hdr_state(lane.pool.read_shadow(self.header_addr(lane, idx))) == ST_CONSUMED {
                let base = self.block_base(lane, idx);
                for w in 0..lane.stride {
                    lane.pool.poke_durable(base.add(w), 0);
                }
                return Some(idx);
            }
            rl.push_back(idx);
        }
        None
    }

    /// Claim a fresh block for the producer — a recycled index when one
    /// is ripe, else the single FAI that covers the next `block`
    /// enqueues.
    fn claim_open(&self, tid: usize, slot: &mut SlotState) -> Result<(), QueueError> {
        let n = self.lanes.len();
        for k in 0..n {
            let l = (tid + slot.ticket + k) % n;
            let lane = &self.lanes[l];
            if let Some(idx) = self.claim_recycled(lane) {
                // Roll the consumer low-water mark back to the reused
                // index NOW — before the block can become COMMITTED — so
                // the sealed block is always inside the scan window.
                // (Scrub already happened, so until the seal the scans
                // see FREE here and stop advancing the cursor past it.)
                lane.cursor.fetch_min(idx as u64, Ordering::Relaxed);
                slot.ticket = slot.ticket.wrapping_add(1);
                slot.open = Some(Open { lane: l, idx, count: 0 });
                return Ok(());
            }
            let b = lane.pool.fai(tid, lane.alloc) as usize;
            if b < lane.nblocks {
                slot.ticket = slot.ticket.wrapping_add(1);
                slot.open = Some(Open { lane: l, idx: b, count: 0 });
                return Ok(());
            }
            // Lane frontier exhausted (the counter keeps growing past
            // nblocks, harmlessly) — try the next lane.
        }
        Err(QueueError::CapacityExhausted)
    }

    /// Publish + persist the open block: one header store, line pwbs, one
    /// psync — covering every entry written since the claim.
    fn seal_open(&self, tid: usize, slot: &mut SlotState) {
        let Some(o) = slot.open.take() else { return };
        let lane = &self.lanes[o.lane];
        let _g = obs::enter_site(ObsSite::BatchFlush);
        if o.count == 0 {
            // Nothing landed in this claim: retire it. The pwb rides a
            // later psync — losing this to a crash is indistinguishable
            // from never claiming.
            lane.pool.store(tid, self.header_addr(lane, o.idx), hdr(ST_CONSUMED, 0, 0));
            lane.pool.pwb(tid, self.header_addr(lane, o.idx));
            self.retire_idx(lane, o.idx);
            return;
        }
        lane.pool
            .store(tid, self.header_addr(lane, o.idx), hdr(ST_COMMITTED, 0, o.count));
        lane.pool.persist_range(tid, self.block_base(lane, o.idx), 1 + o.count);
        // The block's psync retired: record the certified seal (flight
        // recorder, write-after-psync; its pwb rides this thread's next
        // block psync).
        obs::flight::record_sealed(
            &lane.pool,
            tid,
            obs::flight::FlightKind::BlockSeal,
            obs::flight::block_payload(o.lane, o.idx, o.count as u64),
        );
    }

    /// Hand a consumer's partially-drained block back to the queue,
    /// durably: `COMMITTED` at the current resume point.
    fn release_draining(&self, tid: usize, slot: &mut SlotState) {
        let Some(d) = slot.draining.take() else { return };
        let lane = &self.lanes[d.lane];
        let _g = obs::enter_site(ObsSite::DeqFlush);
        let nh = if d.pos < d.count {
            hdr(ST_COMMITTED, d.pos, d.count)
        } else {
            hdr(ST_CONSUMED, d.count, d.count)
        };
        lane.pool.store(tid, self.header_addr(lane, d.idx), nh);
        lane.pool.pwb(tid, self.header_addr(lane, d.idx));
        lane.pool.psync(tid);
        if d.pos >= d.count {
            self.retire_idx(lane, d.idx);
        }
    }

    /// Pop the next entry of the block this consumer is draining.
    fn pop_draining(&self, tid: usize, slot: &mut SlotState) -> Option<u64> {
        loop {
            let d = slot.draining?;
            let lane = &self.lanes[d.lane];
            let v = lane.pool.load(tid, self.entry_addr(lane, d.idx, d.pos));
            let next = d.pos + 1;
            if next >= d.count {
                // Retire the block. The CONSUMED pwb's psync is deferred:
                // it drains with this thread's next claim (or the crash
                // eviction race) — rolling back to DRAINING on a crash
                // only redelivers, which the checker window covers.
                let _g = obs::enter_site(ObsSite::DeqFlush);
                lane.pool.store(
                    tid,
                    self.header_addr(lane, d.idx),
                    hdr(ST_CONSUMED, d.count, d.count),
                );
                lane.pool.pwb(tid, self.header_addr(lane, d.idx));
                self.retire_idx(lane, d.idx);
                slot.draining = None;
            } else {
                slot.draining = Some(Drain { pos: next, ..d });
            }
            if v != 0 {
                return Some(dec(v));
            }
            // A zero entry inside a committed window can only survive an
            // interrupted recovery compaction; skip it defensively.
        }
    }

    /// Scan one lane from its low-water mark for a committed block and
    /// claim it (CAS → DRAINING, pwb + psync). Advances the lane cursor
    /// past the consumed prefix as a side effect.
    fn claim_in_lane(&self, tid: usize, slot: &mut SlotState, l: usize) -> bool {
        let lane = &self.lanes[l];
        let limit = (lane.pool.load(tid, lane.alloc) as usize).min(lane.nblocks);
        let mut idx = lane.cursor.load(Ordering::Relaxed) as usize;
        let mut at_front = true;
        while idx < limit {
            let ha = self.header_addr(lane, idx);
            let h = lane.pool.load(tid, ha);
            match hdr_state(h) {
                ST_CONSUMED => {
                    if at_front {
                        lane.cursor.fetch_max(idx as u64 + 1, Ordering::Relaxed);
                    }
                    idx += 1;
                }
                ST_COMMITTED => {
                    let (s, c) = (hdr_start(h), hdr_count(h));
                    if s >= c {
                        // Empty commit (abandoned claim): retire it
                        // opportunistically and re-read.
                        if lane.pool.cas(tid, ha, h, hdr(ST_CONSUMED, s, c)) {
                            self.retire_idx(lane, idx);
                        }
                    } else if lane.pool.cas(tid, ha, h, hdr(ST_DRAINING, s, c)) {
                        let _g = obs::enter_site(ObsSite::DeqFlush);
                        lane.pool.pwb(tid, ha);
                        lane.pool.psync(tid);
                        obs::flight::record_sealed(
                            &lane.pool,
                            tid,
                            obs::flight::FlightKind::BlockDrain,
                            obs::flight::block_payload(l, idx, c as u64),
                        );
                        slot.draining = Some(Drain { lane: l, idx, pos: s, count: c });
                        return true;
                    }
                    // CAS lost (another consumer claimed it): re-read —
                    // the state is now DRAINING, so the reload advances.
                }
                ST_DRAINING => {
                    at_front = false;
                    idx += 1;
                }
                _ => {
                    // FREE: a producer is still filling it. Skipping is
                    // the bounded overtake this tier trades away.
                    at_front = false;
                    idx += 1;
                }
            }
        }
        false
    }

    /// Sweep every lane (rotating start) for a claimable block. This is
    /// the correctness backstop: EMPTY is only reported after a full
    /// sweep found nothing committed.
    fn sweep_claim(&self, tid: usize, slot: &mut SlotState) -> bool {
        let n = self.lanes.len();
        let start = (tid + slot.rr) % n;
        for k in 0..n {
            if self.claim_in_lane(tid, slot, (start + k) % n) {
                slot.rr = slot.rr.wrapping_add(1);
                return true;
            }
        }
        false
    }

    #[inline]
    fn next_rand(slot: &mut SlotState) -> u64 {
        slot.rng = slot
            .rng
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        slot.rng >> 33
    }

    /// Cheap per-lane backlog estimate: unretired blocks × block size.
    /// Strictly an **upper bound** on the lane's committed-undrained
    /// items (it also counts in-fill and draining blocks), and never 0
    /// while a committed item remains — the same one-sided contract as
    /// `sharded::Shardable::len_hint`.
    fn lane_hint(&self, tid: usize, l: usize) -> u64 {
        let lane = &self.lanes[l];
        let limit = (lane.pool.load(tid, lane.alloc)).min(lane.nblocks as u64);
        let cur = lane.cursor.load(Ordering::Relaxed).min(limit);
        (limit - cur) * self.block as u64
    }

    /// Queue-wide backlog estimate (sum of lane hints). An upper bound:
    /// overcounting is allowed, undercounting to 0 while a committed item
    /// is present is not.
    pub fn len_hint(&self, tid: usize) -> u64 {
        (0..self.lanes.len()).map(|l| self.lane_hint(tid, l)).sum()
    }

    /// MultiFIFO d-choice: sample `dchoice` lanes by backlog hint, steal
    /// from the longest; fall back to the full sweep.
    fn dchoice_claim(&self, tid: usize, slot: &mut SlotState) -> bool {
        let n = self.lanes.len();
        let mut best: Option<(u64, usize)> = None;
        for _ in 0..self.dchoice {
            let l = (Self::next_rand(slot) % n as u64) as usize;
            let h = self.lane_hint(tid, l);
            if best.is_none_or(|(bh, _)| h > bh) {
                best = Some((h, l));
            }
        }
        if let Some((h, l)) = best {
            if h > 0 && self.claim_in_lane(tid, slot, l) {
                return true;
            }
        }
        self.sweep_claim(tid, slot)
    }

    fn claim_drain(&self, tid: usize, slot: &mut SlotState) -> bool {
        if self.multi {
            self.dchoice_claim(tid, slot)
        } else {
            self.sweep_claim(tid, slot)
        }
    }
}

impl ConcurrentQueue for BlockFifo {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let slot = self.slot(tid);
        if slot.open.is_none() {
            self.claim_open(tid, slot)?;
        }
        let o = slot.open.expect("claim_open populated the slot");
        let lane = &self.lanes[o.lane];
        lane.pool.store(tid, self.entry_addr(lane, o.idx, o.count), enc(item));
        slot.open = Some(Open { count: o.count + 1, ..o });
        if o.count + 1 == self.block {
            self.seal_open(tid, slot);
        }
        Ok(())
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let slot = self.slot(tid);
        if let Some(v) = self.pop_draining(tid, slot) {
            return Ok(Some(v));
        }
        if self.claim_drain(tid, slot) {
            return Ok(self.pop_draining(tid, slot));
        }
        // Nothing committed anywhere. Before reporting EMPTY, publish our
        // own open block — a thread must always be able to dequeue what
        // it enqueued itself (and this is what lets drain loops finish).
        if slot.open.is_some_and(|o| o.count > 0) {
            self.seal_open(tid, slot);
            if self.claim_drain(tid, slot) {
                return Ok(self.pop_draining(tid, slot));
            }
        }
        if self.recycle_on {
            // Recycling backstop: a cursor advance can race a recycled
            // block's scrub (the scanner read the pre-scrub CONSUMED
            // header and its fetch_max landed after the reuser's
            // fetch_min), stranding a committed block below every
            // cursor. EMPTY is only safe to report after a rescan from
            // the bottom; the scan itself re-advances the cursors past
            // the genuinely-consumed prefix.
            for lane in &self.lanes {
                lane.cursor.store(0, Ordering::Relaxed);
            }
            if self.claim_drain(tid, slot) {
                return Ok(self.pop_draining(tid, slot));
            }
        }
        Ok(None)
    }

    fn name(&self) -> &'static str {
        if self.multi {
            "blockfifo-multi"
        } else {
            "blockfifo"
        }
    }
}

impl PersistentQueue for BlockFifo {
    /// Single-threaded post-crash scan. Per lane:
    ///
    /// 1. `DRAINING` rolls back to `COMMITTED` at its durable start
    ///    (whole-tail redelivery, checker-gated) — after the same entry
    ///    reconciliation as committed blocks.
    /// 2. `COMMITTED` blocks are reconciled: surviving entries compacted,
    ///    entries that missed the crash cut dropped (their seal psync
    ///    never completed — crash-gated trailing loss).
    /// 3. `FREE` blocks with durable junk entries were claimed but never
    ///    sealed: discarded (marked `CONSUMED`).
    /// 4. The producer frontier (`alloc`) is rebuilt past the last block
    ///    with any durable trace; untouched claimed blocks below it are
    ///    retired so the consumer cursor can pass them.
    fn recover(&self, _pool: &PmemPool) {
        let _g = obs::enter_site(ObsSite::Recovery);
        obs::flight::record_advisory(
            &self.lanes[0].pool,
            0,
            obs::flight::FlightKind::RecoverBegin,
            self.lanes[0].pool.epoch(),
        );
        for tid in 0..self.nthreads {
            let slot = self.slot(tid);
            slot.open = None;
            slot.draining = None;
        }
        for lane in &self.lanes {
            let p = &lane.pool;
            let mut last_used: Option<usize> = None;
            for idx in 0..lane.nblocks {
                let h = p.load(0, self.header_addr(lane, idx));
                match hdr_state(h) {
                    ST_CONSUMED => last_used = Some(idx),
                    ST_COMMITTED | ST_DRAINING => {
                        self.reconcile_block(lane, idx, hdr_start(h), hdr_count(h));
                        last_used = Some(idx);
                    }
                    _ => {
                        let mut junk = false;
                        for j in 0..self.block {
                            if p.load(0, self.entry_addr(lane, idx, j)) != 0 {
                                junk = true;
                                break;
                            }
                        }
                        if junk {
                            // Claimed, partially evicted, never sealed:
                            // nothing here was ever published or covered
                            // by a psync — discard the claim.
                            p.store(0, self.header_addr(lane, idx), hdr(ST_CONSUMED, 0, 0));
                            p.pwb(0, self.header_addr(lane, idx));
                            last_used = Some(idx);
                        }
                    }
                }
            }
            let frontier = last_used.map_or(0, |l| l + 1);
            for idx in 0..frontier {
                let ha = self.header_addr(lane, idx);
                if hdr_state(p.load(0, ha)) == ST_FREE {
                    // Claimed-but-untouched below the frontier: its
                    // claimant died without writing anything durable.
                    p.store(0, ha, hdr(ST_CONSUMED, 0, 0));
                    p.pwb(0, ha);
                }
            }
            p.store(0, lane.alloc, frontier as u64);
            p.pwb(0, lane.alloc);
            p.psync(0);
            let mut cur = frontier;
            for idx in 0..frontier {
                if hdr_state(p.load(0, self.header_addr(lane, idx))) != ST_CONSUMED {
                    cur = idx;
                    break;
                }
            }
            lane.cursor.store(cur as u64, Ordering::Relaxed);
            // Rebuild the volatile recycle pool from the durable headers:
            // every CONSUMED block below the frontier is reusable (the
            // lane psync above made the recovery-time retirements
            // durable, so the claim-time shadow gate passes).
            let mut rl = lane.recycle.lock().unwrap_or_else(|e| e.into_inner());
            rl.clear();
            if self.recycle_on {
                for idx in 0..frontier {
                    if hdr_state(p.load(0, self.header_addr(lane, idx))) == ST_CONSUMED {
                        rl.push_back(idx);
                    }
                }
            }
        }
        // Certified span end: every lane's recovery psync has retired.
        obs::flight::record_sealed(
            &self.lanes[0].pool,
            0,
            obs::flight::FlightKind::RecoverEnd,
            self.lanes[0].pool.epoch(),
        );
    }

    fn quiesce(&self) {
        for tid in 0..self.nthreads {
            let slot = self.slot(tid);
            self.release_draining(tid, slot);
            self.seal_open(tid, slot);
        }
    }

    fn attach(&self, tid: usize) {
        // Reclaim whatever a dead predecessor left in the slot: its open
        // block holds *returned* enqueues (publish them), its draining
        // block holds undelivered items (hand them back).
        let slot = self.slot(tid);
        self.release_draining(tid, slot);
        self.seal_open(tid, slot);
        slot.ticket = 0;
        slot.rr = 0;
        slot.rng = (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    }

    fn detach(&self, tid: usize) {
        let slot = self.slot(tid);
        self.release_draining(tid, slot);
        self.seal_open(tid, slot);
    }
}

impl BlockFifo {
    /// Compact the surviving entries of a (formerly) committed block's
    /// `[start, count)` window down to `[start, start + kept)`, zero the
    /// tail, and rewrite the header (`COMMITTED` if anything survived,
    /// else `CONSUMED`). Recovery-only (single-threaded, tid 0); the
    /// per-block pwbs ride the lane's one recovery psync.
    fn reconcile_block(&self, lane: &Lane, idx: usize, start: usize, count: usize) {
        let p = &lane.pool;
        let mut w = start;
        for j in start..count {
            let v = p.load(0, self.entry_addr(lane, idx, j));
            if v != 0 {
                if w != j {
                    p.store(0, self.entry_addr(lane, idx, w), v);
                }
                w += 1;
            }
        }
        for j in w..count {
            p.store(0, self.entry_addr(lane, idx, j), 0);
        }
        let nh = if w > start {
            hdr(ST_COMMITTED, start, w)
        } else {
            hdr(ST_CONSUMED, start, start)
        };
        p.store(0, self.header_addr(lane, idx), nh);
        let words = 1 + count;
        let base = self.block_base(lane, idx);
        let mut off = 0;
        while off < words {
            p.pwb(0, base.add(off));
            off += WORDS_PER_LINE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn topo(evict: f64, pending: f64, seed: u64) -> Topology {
        Topology::single(PmemConfig {
            capacity_words: 1 << 20,
            cost: CostModel::zero(),
            evict_prob: evict,
            pending_flush_prob: pending,
            seed,
        })
    }

    fn mkq(t: &Topology, nthreads: usize, shards: usize, block: usize, nblocks: usize) -> BlockFifo {
        let cfg = QueueConfig {
            shards,
            block,
            ring_size: nblocks,
            ..Default::default()
        };
        BlockFifo::new(t, nthreads, cfg, false).unwrap()
    }

    #[test]
    fn single_lane_single_thread_is_strict_fifo() {
        let t = topo(0.0, 1.0, 1);
        let q = mkq(&t, 1, 1, 4, 64);
        for v in 0..10u64 {
            q.enqueue(0, v).unwrap();
        }
        // 2 sealed blocks + an open block of 2: the dequeue-side
        // self-seal publishes the tail when the sweep comes up empty.
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        assert_eq!(out, (0..10).collect::<Vec<u64>>());
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn one_fai_and_one_psync_per_sealed_block() {
        let t = topo(0.0, 1.0, 2);
        let q = mkq(&t, 1, 1, 8, 64);
        let before = t.stats_total();
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        let after = t.stats_total();
        // 4 sealed blocks: one claim FAI + one seal psync each.
        assert_eq!(after.rmws - before.rmws, 4, "one FAI per block");
        assert_eq!(after.psyncs - before.psyncs, 4, "one psync per sealed block");
    }

    #[test]
    fn capacity_exhausted_when_all_lanes_full() {
        let t = topo(0.0, 1.0, 3);
        let q = mkq(&t, 1, 1, 2, 2);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.enqueue(0, 99), Err(QueueError::CapacityExhausted));
    }

    #[test]
    fn mpmc_no_loss_no_duplication() {
        let t = topo(0.0, 1.0, 4);
        let nthreads = 4;
        let per = 500u64;
        let q = Arc::new(mkq(&t, nthreads, 2, 8, 512));
        t.primary().set_active_threads(nthreads);
        let mut handles = Vec::new();
        for tid in 0..nthreads {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                let base = tid as u64 * per;
                for v in base..base + per {
                    q.enqueue(tid, v).unwrap();
                }
                // Publish the tail before switching roles — a worker that
                // exits with an open block would strand its items.
                q.detach(tid);
                let mut got = Vec::new();
                while got.len() < per as usize {
                    match q.dequeue(tid).unwrap() {
                        Some(v) => got.push(v),
                        None => std::thread::yield_now(),
                    }
                }
                got
            }));
        }
        let mut all: Vec<u64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let expect: Vec<u64> = (0..nthreads as u64 * per).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn sealed_blocks_survive_a_clean_crash() {
        // evict 0 + pending 1.0: exactly the explicitly-psynced state
        // survives. 8 sealed enqueues live on; the 3-item open block is
        // the crash-gated trailing loss.
        let t = topo(0.0, 1.0, 5);
        let q = mkq(&t, 1, 1, 4, 64);
        for v in 0..11u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(7);
        t.crash(&mut rng);
        q.recover(t.primary());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        assert_eq!(out, (0..8).collect::<Vec<u64>>());
        // Queue stays usable: the frontier was rolled back past the dead
        // claim and fresh blocks commit as usual.
        for v in 100..104u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut out2 = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out2.push(v);
        }
        assert_eq!(out2, vec![100, 101, 102, 103]);
    }

    #[test]
    fn torn_unsealed_block_is_discarded_not_invented() {
        // evict 1.0: every dirty line persists, including the unsealed
        // block's entries — but its header stayed FREE, so recovery must
        // discard the junk rather than deliver unpublished items.
        let t = topo(1.0, 1.0, 6);
        let q = mkq(&t, 1, 1, 4, 64);
        for v in 0..11u64 {
            q.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(8);
        t.crash(&mut rng);
        q.recover(t.primary());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        assert_eq!(out, (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn draining_block_rolls_back_and_redelivers() {
        let t = topo(0.0, 1.0, 9);
        let q = mkq(&t, 1, 1, 4, 64);
        for v in 0..4u64 {
            q.enqueue(0, v).unwrap();
        }
        // Claim the block and consume one item; the DRAINING header was
        // psynced at claim time, the progress (pos=1) is volatile.
        assert_eq!(q.dequeue(0).unwrap(), Some(0));
        let mut rng = Xoshiro256::seed_from(11);
        t.crash(&mut rng);
        q.recover(t.primary());
        // Rollback to the durable start: the whole block redelivers,
        // including the already-returned item 0 (checker-gated).
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn quiesce_publishes_open_blocks_durably() {
        let t = topo(0.0, 1.0, 12);
        let q = mkq(&t, 2, 2, 8, 64);
        for v in 0..5u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 5..9u64 {
            q.enqueue(1, v).unwrap();
        }
        q.quiesce();
        let mut rng = Xoshiro256::seed_from(13);
        t.crash(&mut rng);
        q.recover(t.primary());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        out.sort_unstable();
        assert_eq!(out, (0..9).collect::<Vec<u64>>());
    }

    #[test]
    fn len_hint_is_an_upper_bound_and_settles_to_zero() {
        let t = topo(0.0, 1.0, 14);
        let q = mkq(&t, 1, 2, 4, 64);
        for v in 0..16u64 {
            q.enqueue(0, v).unwrap();
        }
        assert!(q.len_hint(0) >= 16, "hint must never undercount live items");
        let mut n = 0;
        while q.dequeue(0).unwrap().is_some() {
            n += 1;
        }
        assert_eq!(n, 16);
        // The final empty sweep advanced every cursor past the consumed
        // prefix: the estimate settles to exactly zero.
        assert_eq!(q.len_hint(0), 0);
    }

    #[test]
    fn multi_mode_delivers_everything() {
        let t = topo(0.0, 1.0, 15);
        let cfg = QueueConfig {
            shards: 4,
            block: 8,
            ring_size: 64,
            dchoice: 2,
            ..Default::default()
        };
        let q = Arc::new(BlockFifo::new(&t, 2, cfg, true).unwrap());
        assert_eq!(q.name(), "blockfifo-multi");
        t.primary().set_active_threads(2);
        let prod = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for v in 0..400u64 {
                    q.enqueue(0, v).unwrap();
                }
                q.detach(0);
            })
        };
        let cons = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while got.len() < 400 {
                    match q.dequeue(1).unwrap() {
                        Some(v) => got.push(v),
                        None => std::thread::yield_now(),
                    }
                }
                got
            })
        };
        prod.join().unwrap();
        let mut got = cons.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..400).collect::<Vec<u64>>());
    }

    #[test]
    fn recycling_runs_workload_beyond_raw_capacity() {
        // 1 lane × 8 blocks × 4 entries = 32 raw slots; push 800 items
        // through in enqueue/drain rounds. Without recycling the lane
        // frontier exhausts after 2 rounds (see
        // `capacity_exhausted_when_all_lanes_full`); with it the rounds
        // run entirely on reused blocks.
        let t = topo(0.0, 1.0, 30);
        let q = mkq(&t, 1, 1, 4, 8);
        for round in 0..50u64 {
            let base = round * 16;
            for v in base..base + 16 {
                q.enqueue(0, v).unwrap();
            }
            let mut out = Vec::new();
            while let Some(v) = q.dequeue(0).unwrap() {
                out.push(v);
            }
            // Delivery order across reused blocks is relaxed (the tier's
            // contract); conservation is not.
            out.sort_unstable();
            assert_eq!(out, (base..base + 16).collect::<Vec<u64>>(), "round {round}");
        }
    }

    #[test]
    fn recycling_survives_crash_and_recovery_rebuilds_pool() {
        // 80 items through 32 raw slots with a crash between every round:
        // recovery must rebuild the volatile recycle pool from the
        // durable CONSUMED headers, or round 3 exhausts the frontier.
        let t = topo(0.0, 1.0, 31);
        let q = mkq(&t, 1, 1, 4, 8);
        let mut rng = Xoshiro256::seed_from(32);
        for round in 0..5u64 {
            let base = round * 16;
            for v in base..base + 16 {
                q.enqueue(0, v).unwrap();
            }
            let mut out = Vec::new();
            while let Some(v) = q.dequeue(0).unwrap() {
                out.push(v);
            }
            out.sort_unstable();
            assert_eq!(out, (base..base + 16).collect::<Vec<u64>>(), "round {round}");
            q.quiesce();
            t.crash(&mut rng);
            q.recover(t.primary());
            assert_eq!(q.dequeue(0).unwrap(), None, "drained queue must recover empty");
        }
    }

    #[test]
    fn recycle_off_exhausts_at_raw_capacity() {
        let t = topo(0.0, 1.0, 33);
        let cfg = QueueConfig {
            shards: 1,
            block: 4,
            ring_size: 8,
            recycle: false,
            ..Default::default()
        };
        let q = BlockFifo::new(&t, 1, cfg, false).unwrap();
        let mut accepted = 0u64;
        let err = loop {
            for _ in 0..16 {
                match q.enqueue(0, accepted) {
                    Ok(()) => accepted += 1,
                    Err(_) => break,
                }
            }
            while q.dequeue(0).unwrap().is_some() {}
            if accepted >= 33 {
                panic!("recycle=off accepted {accepted} > raw capacity");
            }
            if let Err(e) = q.enqueue(0, accepted) {
                break e;
            }
            accepted += 1;
        };
        assert_eq!(err, QueueError::CapacityExhausted);
        assert!(accepted <= 32, "raw capacity is the ceiling without recycling");
    }

    #[test]
    fn double_recovery_is_stable() {
        let t = topo(0.3, 0.7, 21);
        let q = mkq(&t, 1, 2, 4, 64);
        for v in 0..40u64 {
            q.enqueue(0, v).unwrap();
        }
        q.quiesce();
        let mut rng = Xoshiro256::seed_from(22);
        t.crash(&mut rng);
        q.recover(t.primary());
        t.crash(&mut rng);
        q.recover(t.primary());
        let mut out = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            out.push(v);
        }
        out.sort_unstable();
        // quiesce psynced everything: exact survival, twice over.
        assert_eq!(out, (0..40).collect::<Vec<u64>>());
    }
}
