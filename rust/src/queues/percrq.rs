//! PerCRQ — the persistent circular ring queue (paper §4.2, Algorithm 3
//! blue lines), including the **local persistence** technique and the
//! ring recovery function reused by PerLCRQ.
//!
//! Persistence placement (one `pwb`+`psync` pair per operation):
//!
//! * enqueue OK → persist the written cell (line 15);
//! * enqueue CLOSED → persist `Tail`'s closed bit, once per ring thanks to
//!   the volatile `closedFlag` (lines 7, 20);
//! * dequeue item → persist the *per-thread local copy* `Head_i`, a
//!   single-writer single-reader line (line 35 — the paper's headline
//!   technique; [`crate::queues::HeadPersistMode`] switches to the
//!   expensive shared-`Head` variant PerLCRQ-PHead, or to none);
//! * dequeue EMPTY → persist `Head_i` before returning (line 45).
//!
//! The ring *operations* live in [`super::crq::Ring`]; this module adds the
//! persistent wrapper and [`recover_ring`] (Algorithm 3 lines 58–83).

use std::sync::Arc;

use super::crq::{DeqResult, EnqResult, PersistCfg, Ring, BOT, CLOSED_BIT, IDX_MASK};
use super::{HeadPersistMode, QueueConfig};
use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};

/// Recover one ring after a crash (Algorithm 3, lines 58–83).
///
/// Steps, with paper line numbers:
/// 1. `Head ← max_i Head_i` (line 60) — plus the shared `Head`'s own
///    persisted value, which safely covers the Shared/None ablation modes.
/// 2. Rebuild `Tail` from cell indices (lines 61–68): occupied cells push
///    `Tail` past their index; unoccupied cells with `idx ≥ R` witness a
///    dequeue/empty transition of index `idx − R`, pushing `Tail` past
///    `idx − R`.
/// 3. If `Head > Tail` the queue is empty: `Tail ← Head` (line 69).
/// 4. Otherwise advance `Head` past unoccupied in-range cells whose
///    transition index exceeds it (lines 71–75, Scenario 2), then clamp it
///    down to the minimum occupied in-range index (lines 76–80, Scenario 3).
/// 5. Reinitialize every cell outside `[Head, Tail)` for its next round and
///    clear all unsafe flags (lines 81–83).
/// 6. Persist the recovered ring (so a crash during the next epoch cannot
///    resurrect pre-recovery state) and reset volatile flags.
pub fn recover_ring(pool: &PmemPool, ring: &Ring) {
    let tid = 0;
    let r = ring.ring_size as u64;

    // --- (1) Head from local copies (line 60) ---
    let mut head = pool.load(tid, ring.head_addr());
    for i in 0..ring.nthreads {
        head = head.max(pool.load(tid, ring.head_i_addr(i)));
    }

    // --- (2) Tail from cell indices (lines 61-68) ---
    let traw = pool.load(tid, ring.tail_addr());
    let closed = traw & (1 << CLOSED_BIT);
    let mut tail: u64 = 0;
    for u in 0..r {
        let (_uns, idx, val) = ring.read_cell(pool, tid, u);
        if val != BOT {
            tail = tail.max(idx + 1); // lines 64-65
        } else if idx >= r {
            tail = tail.max(idx - r + 1); // lines 66-68
        }
    }

    if head > tail {
        tail = head; // line 69 — empty queue
    } else {
        // --- (4a) lines 71-75: unoccupied in-range cells advance Head ---
        let mut max_h = head;
        let mut i = head;
        let mut steps = 0u64;
        while i % r != tail % r && steps < r {
            let (_uns, idx, val) = ring.read_cell(pool, tid, i % r);
            if val == BOT && idx >= r && idx - r + 1 > max_h {
                max_h = idx - r + 1;
            }
            i += 1;
            steps += 1;
        }
        head = max_h.min(tail);
        // --- (4b) lines 76-80: clamp to the min occupied in-range index ---
        let mut min_i = tail;
        let mut i = head;
        let mut steps = 0u64;
        while i % r != tail % r && steps < r {
            let (_uns, idx, val) = ring.read_cell(pool, tid, i % r);
            if val != BOT && idx < min_i && idx >= head {
                min_i = idx;
            }
            i += 1;
            steps += 1;
        }
        if min_i < tail {
            head = min_i;
        }
    }

    // --- (5) lines 81-83: reinitialize out-of-range cells, clear unsafe ---
    for u in 0..r {
        // Smallest index ≥ head with residue u.
        let m = head + ((u + r - (head % r)) % r);
        let (_uns, idx, val) = ring.read_cell(pool, tid, u);
        if m < tail {
            // Cell is inside the live range: keep content, clear unsafe.
            ring.write_cell(pool, tid, u, false, idx, val);
        } else {
            // Outside: ready it for the enqueue that will claim index m.
            ring.write_cell(pool, tid, u, false, m, BOT);
        }
    }

    pool.store(tid, ring.head_addr(), head);
    pool.store(tid, ring.tail_addr(), closed | tail);
    for i in 0..ring.nthreads {
        pool.store(tid, ring.head_i_addr(i), head);
    }

    // --- (6) persist the recovered image ---
    // (The closedFlag word needs no reset: it is monotone — see crq.rs.)
    pool.persist_range(tid, ring.base, ring.footprint_words());
}

/// Standalone PerCRQ (persistent tantrum queue): the unit under test for
/// §4.2; PerLCRQ composes the same machinery per list node.
pub struct PerCrq {
    pool: Arc<PmemPool>,
    pub ring: Ring,
    /// Pool word holding the §4.2 closedFlag.
    pub closed_flag: PAddr,
    pub persist: PersistCfg,
    starvation_limit: usize,
}

impl PerCrq {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig) -> Self {
        cfg.validate().expect("invalid QueueConfig");
        Self {
            pool: Arc::clone(pool),
            ring: Ring::alloc(pool, cfg.ring_size, nthreads),
            closed_flag: pool.alloc_word(),
            persist: PersistCfg {
                head_mode: cfg.head_mode,
                skip_tail_persist: cfg.skip_tail_persist,
                disable_closed_flag: cfg.disable_closed_flag,
                defer_enqueue_sync: cfg.defer_enqueue_sync,
                defer_dequeue_sync: cfg.defer_dequeue_sync,
            },
            starvation_limit: cfg.starvation_limit,
        }
    }

    pub fn enqueue(&self, tid: usize, item: u64) -> EnqResult {
        self.ring.enqueue(
            &self.pool,
            tid,
            item,
            self.starvation_limit,
            Some((&self.persist, self.closed_flag)),
        )
    }

    pub fn dequeue(&self, tid: usize) -> DeqResult {
        self.ring.dequeue(&self.pool, tid, Some(&self.persist))
    }

    pub fn recover(&self, pool: &PmemPool) {
        recover_ring(pool, &self.ring);
    }

    pub fn endpoints(&self, tid: usize) -> (u64, u64) {
        self.ring.endpoints(&self.pool, tid)
    }
}

// Quiet the unused-import lint for IDX_MASK/WORDS_PER_LINE used in docs.
const _: u64 = IDX_MASK;
const _: usize = WORDS_PER_LINE;
const _: fn() -> HeadPersistMode = || HeadPersistMode::Local;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(r: usize, nthreads: usize) -> (Arc<PmemPool>, PerCrq) {
        mk_mode(r, nthreads, HeadPersistMode::Local)
    }

    fn mk_mode(r: usize, nthreads: usize, mode: HeadPersistMode) -> (Arc<PmemPool>, PerCrq) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 11,
        }));
        let cfg = QueueConfig { ring_size: r, head_mode: mode, ..Default::default() };
        let q = PerCrq::new(&pool, nthreads, cfg);
        (pool, q)
    }

    #[test]
    fn fifo_and_persistence_pair_counts() {
        let (p, q) = mk(64, 2);
        p.stats.reset();
        assert_eq!(q.enqueue(0, 7), EnqResult::Ok);
        let s = p.stats.total();
        assert_eq!((s.pwbs, s.psyncs), (1, 1), "enqueue: exactly one pwb+psync");
        p.stats.reset();
        assert_eq!(q.dequeue(1), DeqResult::Item(7));
        let s = p.stats.total();
        assert_eq!((s.pwbs, s.psyncs), (1, 1), "dequeue: exactly one pwb+psync");
        p.stats.reset();
        assert_eq!(q.dequeue(1), DeqResult::Empty);
        let s = p.stats.total();
        assert_eq!((s.pwbs, s.psyncs), (1, 1), "EMPTY dequeue: exactly one pair");
    }

    #[test]
    fn local_mode_persists_head_i_not_head() {
        let (p, q) = mk(64, 2);
        q.enqueue(0, 1);
        // Track shadow of shared Head before/after a dequeue.
        let head_shadow_before = p.read_shadow(q.ring.head_addr());
        assert_eq!(q.dequeue(1), DeqResult::Item(1));
        assert_eq!(
            p.read_shadow(q.ring.head_addr()),
            head_shadow_before,
            "Local mode must not flush shared Head"
        );
        assert_eq!(p.read_shadow(q.ring.head_i_addr(1)), 1, "Head_1 must be persisted (= h+1)");
    }

    #[test]
    fn shared_mode_persists_shared_head() {
        let (p, q) = mk_mode(64, 2, HeadPersistMode::Shared);
        q.enqueue(0, 1);
        assert_eq!(q.dequeue(1), DeqResult::Item(1));
        assert_eq!(p.read_shadow(q.ring.head_addr()), 1, "Shared mode must flush Head");
    }

    #[test]
    fn closed_flag_avoids_repeat_tail_persists() {
        let (p, q) = mk(8, 1);
        for v in 0..8u64 {
            q.enqueue(0, v);
        }
        p.stats.reset();
        assert_eq!(q.enqueue(0, 99), EnqResult::Closed); // first close: persists Tail
        let first = p.stats.total().pwbs;
        assert_eq!(first, 1);
        assert_eq!(q.enqueue(0, 100), EnqResult::Closed); // flag set: no pwb
        assert_eq!(p.stats.total().pwbs, 1, "closedFlag must suppress repeat pwbs");
    }

    #[test]
    fn recover_empty_ring() {
        let (p, q) = mk(16, 2);
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0), DeqResult::Empty);
        assert_eq!(q.enqueue(0, 5), EnqResult::Ok);
        assert_eq!(q.dequeue(1), DeqResult::Item(5));
    }

    #[test]
    fn completed_ops_survive_crash() {
        let (p, q) = mk(64, 2);
        for v in 0..20u64 {
            assert_eq!(q.enqueue(0, v), EnqResult::Ok);
        }
        for v in 0..5u64 {
            assert_eq!(q.dequeue(1), DeqResult::Item(v));
        }
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        q.recover(&p);
        let (h, t) = q.endpoints(0);
        assert!(h >= 5, "recovered head {h} must reflect the 5 persisted dequeues");
        assert_eq!(t, 20);
        for v in 5..20u64 {
            assert_eq!(q.dequeue(0), DeqResult::Item(v), "item {v} lost");
        }
        assert_eq!(q.dequeue(0), DeqResult::Empty);
    }

    #[test]
    fn scenario_2_unoccupied_cell_advances_head() {
        // Paper Scenario 2: enq_0 completes (persisting the ⊥ cell the
        // dequeuer left behind via line-15's flush of the SAME cell), the
        // dequeue deq_0's own Head_i flush never happens — recovery must
        // still set Head ≥ 1 because the cell's idx = 0 + R witnesses deq_0.
        let (p, q) = mk(4, 2);
        assert_eq!(q.enqueue(0, 42), EnqResult::Ok);
        // deq_0 executes its dequeue transition but crashes before its
        // Head_i pwb lands: emulate by poking live state only.
        let cell = q.ring.cell_addr(0);
        // Dequeue transition: (safe, round 0, enc42) -> (safe, round 1, ⊥).
        p.poke(cell, 1); // round 1 => idx = 4 = 0 + R
        p.poke(cell.add(1), BOT);
        p.poke(q.ring.head_addr(), 1);
        // enq_0 already persisted the cell? In Scenario 2 the *enqueuer's*
        // line-15 pwb happens after the dequeuer's transition, flushing the
        // (s, 4, ⊥) state. Emulate that flush:
        p.persist_range(0, cell, 2);
        let mut rng = Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        q.recover(&p);
        let (h, t) = q.endpoints(0);
        assert!(h >= 1, "recovery must linearize deq_0 (Head ≥ 1), got head {h}");
        assert!(t >= 1);
        // x_0 must NOT be dequeueable again.
        assert_eq!(q.dequeue(0), DeqResult::Empty);
    }

    #[test]
    fn scenario_3_head_clamps_to_min_occupied() {
        // Paper Scenario 3, R=4: enq_0..enq_3 complete; deq_1..deq_3
        // complete (persisting Head_i = 4 via the *last* dequeuer — here we
        // let all three run normally which persists Head_i values);
        // deq_0 only FAI'd (no transition). enq_5, enq_6 complete in round
        // 1. Recovery must set Head to 5 (min occupied in-range index),
        // skipping the stale x_0.
        let (p, q) = mk(4, 4);
        for v in 0..4u64 {
            assert_eq!(q.enqueue(0, v), EnqResult::Ok);
        }
        // deq_0: FAI Head only (thread 1 crashes mid-op). Emulate: bump
        // Head live without transition or persist.
        let h = p.fai(1, q.ring.head_addr());
        assert_eq!(h, 0);
        // deq_1..deq_3 by thread 2 — these dequeue x_1, x_2, x_3 normally
        // and persist Head_2 = 4.
        assert_eq!(q.dequeue(2), DeqResult::Item(1));
        assert_eq!(q.dequeue(2), DeqResult::Item(2));
        assert_eq!(q.dequeue(2), DeqResult::Item(3));
        // enq_4: FAI Tail only (crashes). enq_5, enq_6 complete.
        let t = p.fai(3, q.ring.tail_addr()) & IDX_MASK;
        assert_eq!(t, 4);
        assert_eq!(q.enqueue(3, 55), EnqResult::Ok); // idx 5
        assert_eq!(q.enqueue(3, 66), EnqResult::Ok); // idx 6
        let mut rng = Xoshiro256::seed_from(4);
        p.crash(&mut rng);
        q.recover(&p);
        let (h, t) = q.endpoints(0);
        assert_eq!(t, 7, "tail must cover enq_6 (idx 6)");
        assert_eq!(h, 5, "head must clamp to min occupied idx 5 (x_0 is stale)");
        assert_eq!(q.dequeue(0), DeqResult::Item(55));
        assert_eq!(q.dequeue(0), DeqResult::Item(66));
        assert_eq!(q.dequeue(0), DeqResult::Empty);
    }

    #[test]
    fn closed_bit_survives_recovery_when_persisted() {
        let (p, q) = mk(8, 1);
        for v in 0..8u64 {
            q.enqueue(0, v);
        }
        assert_eq!(q.enqueue(0, 99), EnqResult::Closed); // persists closed Tail
        let mut rng = Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        q.recover(&p);
        assert!(q.ring.is_closed(&p, 0), "persisted closed bit must survive");
        assert_eq!(q.enqueue(0, 100), EnqResult::Closed, "tantrum semantics after crash");
        // Items remain dequeueable.
        for v in 0..8u64 {
            assert_eq!(q.dequeue(0), DeqResult::Item(v));
        }
    }

    #[test]
    fn unpersisted_closed_bit_reopens() {
        // TAS executed but neither pwb landed -> after crash the ring is
        // open again, and no enqueue returned CLOSED pre-crash (emulated).
        let (p, q) = mk(8, 1);
        q.enqueue(0, 1);
        // TAS the closed bit without persisting (direct live poke).
        let cur = p.peek(q.ring.tail_addr());
        p.poke(q.ring.tail_addr(), cur | (1 << CLOSED_BIT));
        let mut rng = Xoshiro256::seed_from(6);
        p.crash(&mut rng);
        q.recover(&p);
        assert!(!q.ring.is_closed(&p, 0), "unpersisted closed bit must vanish");
        assert_eq!(q.enqueue(0, 2), EnqResult::Ok);
    }

    #[test]
    fn double_crash_recovery_idempotent() {
        let (p, q) = mk(32, 2);
        for v in 0..10u64 {
            q.enqueue(0, v);
        }
        let mut rng = Xoshiro256::seed_from(7);
        p.crash(&mut rng);
        q.recover(&p);
        // Immediately crash again before any new ops: state must be stable
        // because recovery persisted its result.
        p.crash(&mut rng);
        q.recover(&p);
        for v in 0..10u64 {
            assert_eq!(q.dequeue(1), DeqResult::Item(v), "item {v} lost after double crash");
        }
    }

    #[test]
    fn wraparound_state_recovers() {
        let (p, q) = mk(8, 2);
        // Advance several rounds.
        for round in 0..5u64 {
            for v in 0..6u64 {
                assert_eq!(q.enqueue(0, round * 10 + v), EnqResult::Ok);
            }
            for v in 0..6u64 {
                assert_eq!(q.dequeue(1), DeqResult::Item(round * 10 + v));
            }
        }
        // Leave 3 items in-flight.
        for v in 0..3u64 {
            q.enqueue(0, 100 + v);
        }
        let mut rng = Xoshiro256::seed_from(8);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 0..3u64 {
            assert_eq!(q.dequeue(0), DeqResult::Item(100 + v));
        }
        assert_eq!(q.dequeue(0), DeqResult::Empty);
        // Ring still functions for future rounds.
        q.enqueue(0, 500);
        assert_eq!(q.dequeue(1), DeqResult::Item(500));
    }
}
