//! Durable Michael–Scott queue — a persist-everything baseline in the
//! style of the specialized persistent queues the paper's §1 discusses
//! (Friedman et al. \[11\]): every link write, endpoint move and node payload
//! is flushed eagerly. It executes **three** pwb+psync pairs per enqueue
//! and **one or two** per dequeue, *all on contended locations* (head/tail
//! lines), deliberately violating both persistence principles of \[1\] —
//! the ablation `ablation_pwb_placement` quantifies the cost against
//! PerLCRQ's single low-contention pair.
//!
//! Recovery: `Head` is persisted on every dequeue, so it is authoritative;
//! `Tail` is recovered by walking `next` pointers to the end of the list
//! (every link is persisted before it becomes reachable).

use std::sync::Arc;

use super::{ConcurrentQueue, PersistentQueue, QueueError, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool};

pub struct DurableMsQueue {
    pool: Arc<PmemPool>,
    head: PAddr,
    tail: PAddr,
}

impl DurableMsQueue {
    pub fn new(pool: &Arc<PmemPool>, _nthreads: usize) -> Self {
        let head = pool.alloc_lines(1);
        let tail = pool.alloc_lines(1);
        pool.set_hot(head, 1, crate::pmem::Hotness::Global);
        pool.set_hot(tail, 1, crate::pmem::Hotness::Global);
        let sentinel = pool.alloc(2, 2);
        pool.store(0, head, sentinel.to_u64());
        pool.store(0, tail, sentinel.to_u64());
        pool.pwb(0, head);
        pool.pwb(0, tail);
        pool.psync(0);
        Self { pool: Arc::clone(pool), head, tail }
    }

    fn next_of(node: PAddr) -> PAddr {
        node
    }

    fn value_of(node: PAddr) -> PAddr {
        node.add(1)
    }
}

impl ConcurrentQueue for DurableMsQueue {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        let node = p.alloc(2, 2);
        p.store(tid, Self::value_of(node), item);
        // Pair 1: node payload durable before it becomes reachable.
        p.pwb(tid, node);
        p.psync(tid);
        loop {
            let l = PAddr::from_u64(p.load(tid, self.tail));
            let next = p.load(tid, Self::next_of(l));
            if l.to_u64() != p.load(tid, self.tail) {
                continue;
            }
            if next == 0 {
                if p.cas(tid, Self::next_of(l), 0, node.to_u64()) {
                    // Pair 2: the link that publishes the node.
                    p.pwb(tid, Self::next_of(l));
                    p.psync(tid);
                    let _ = p.cas(tid, self.tail, l.to_u64(), node.to_u64());
                    // Pair 3: the (hot!) tail pointer.
                    p.pwb(tid, self.tail);
                    p.psync(tid);
                    return Ok(());
                }
            } else {
                // Help: persist the link before advancing tail over it.
                p.pwb(tid, Self::next_of(l));
                p.psync(tid);
                let _ = p.cas(tid, self.tail, l.to_u64(), next);
            }
        }
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let p = &self.pool;
        loop {
            let h = PAddr::from_u64(p.load(tid, self.head));
            let t = p.load(tid, self.tail);
            let next = p.load(tid, Self::next_of(h));
            if h.to_u64() != p.load(tid, self.head) {
                continue;
            }
            if h.to_u64() == t {
                if next == 0 {
                    // Persist head so the EMPTY response is durable.
                    p.pwb(tid, self.head);
                    p.psync(tid);
                    return Ok(None);
                }
                let _ = p.cas(tid, self.tail, t, next);
                p.pwb(tid, self.tail);
                p.psync(tid);
            } else {
                let v = p.load(tid, Self::value_of(PAddr::from_u64(next)));
                if p.cas(tid, self.head, h.to_u64(), next) {
                    // The (hot!) head pointer must be durable before return.
                    p.pwb(tid, self.head);
                    p.psync(tid);
                    return Ok(Some(v));
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "durable-msq"
    }
}

impl PersistentQueue for DurableMsQueue {
    fn recover(&self, pool: &PmemPool) {
        let tid = 0;
        // Head is authoritative (persisted per dequeue). Walk to the end to
        // rebuild Tail (links are persisted before publication).
        let mut node = PAddr::from_u64(pool.load(tid, self.head));
        loop {
            let next = pool.load(tid, Self::next_of(node));
            if next == 0 {
                break;
            }
            node = PAddr::from_u64(next);
        }
        pool.store(tid, self.tail, node.to_u64());
        pool.pwb(tid, self.head);
        pool.pwb(tid, self.tail);
        pool.psync(tid);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk() -> (Arc<PmemPool>, DurableMsQueue) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 20,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 21,
        }));
        let q = DurableMsQueue::new(&pool, 4);
        (pool, q)
    }

    #[test]
    fn fifo_and_crash_recovery() {
        let (p, q) = mk();
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..20u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 20..50u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn persistence_instruction_count_is_high() {
        // The whole point of this baseline: 3 pairs per enqueue, ≥1 per
        // dequeue — versus PerLCRQ's 1.
        let (p, q) = mk();
        p.stats.reset();
        q.enqueue(0, 1).unwrap();
        let s = p.stats.total();
        assert_eq!(s.pwbs, 3);
        assert_eq!(s.psyncs, 3);
        p.stats.reset();
        let _ = q.dequeue(0).unwrap();
        let s = p.stats.total();
        assert!(s.pwbs >= 1);
    }

    #[test]
    fn empty_recovery() {
        let (p, q) = mk();
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0).unwrap(), None);
        q.enqueue(0, 9).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(9));
    }
}
