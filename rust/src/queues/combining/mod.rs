//! Software-combining queues — the paper's main competitors (§5).
//!
//! \[9\] ("The performance power of software combining in persistence",
//! PPoPP'22) showed that combining-based persistent queues (PBQueue,
//! PWFQueue) beat all previously published persistent queues; the paper
//! under reproduction shows PerLCRQ beating them by ≥2×. We re-implement
//! both from \[9\]'s description (the authors' artifact is not available in
//! this environment; fidelity notes inline):
//!
//! * [`ccsynch`] — the CC-Synch combining protocol \[6\]: threads enqueue
//!   request nodes onto a combining list; the head thread becomes the
//!   *combiner* and applies a batch of requests to a sequential queue.
//! * [`seqring`] — the sequential ring buffer under the combiner, with a
//!   single packed commit word making batch persistence atomic.
//! * [`ccqueue`] — volatile combining queue (CC-Queue of \[6\]).
//! * [`pbqueue`] — persistent blocking combining queue: the combiner
//!   persists modified state once per batch (one psync for items + one for
//!   the commit word), then announces results — so completed operations
//!   are always durable, at ~2 psyncs per *batch* rather than per op.
//! * [`pwfqueue`] — the announce-array (PSim-style) variant. Fidelity
//!   note: \[9\]'s PWFQueue is wait-free via bounded helping; ours is
//!   lock-free (combiner chosen by CAS, losers spin on their response).
//!   The performance-relevant structure — O(n) announce scan per round +
//!   serial application + per-batch persistence — is preserved, which is
//!   what Figures 2–3 exercise.

pub mod ccqueue;
pub mod ccsynch;
pub mod pbqueue;
pub mod pwfqueue;
pub mod seqring;

/// Operation codes passed through combining requests.
pub const OP_ENQ: u64 = 1;
pub const OP_DEQ: u64 = 2;

/// Return value signalling EMPTY.
pub const RET_EMPTY: u64 = u64::MAX;
/// Return value signalling OK (for enqueues).
pub const RET_OK: u64 = u64::MAX - 1;
