//! PWFQueue — announce-array combining queue, re-implemented from \[9\]
//! (PSim-style). Each thread publishes its request in a per-thread
//! announce slot; a combiner (CAS winner on a global coordination word)
//! scans *all* slots, applies every outstanding request to the sequential
//! ring, persists the batch, then publishes responses.
//!
//! Fidelity note (see combining/mod.rs): \[9\]'s PWFQueue is wait-free via
//! bounded helping; this implementation is lock-free (losers spin until
//! their response appears or the combiner word frees). The cost structure
//! the evaluation exercises — O(n) announce scan per round, serial
//! application, per-batch persistence — is identical.
//!
//! Layout per thread (one line each):
//! announce: `[seq][op][arg]`, response: `[seq][ret]`.

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::seqring::SeqRing;
use super::{OP_DEQ, OP_ENQ, RET_EMPTY};
use crate::pmem::{PAddr, PmemPool};
use crate::queues::{ConcurrentQueue, PersistentQueue, QueueError, MAX_ITEM};

const A_SEQ: usize = 0;
const A_OP: usize = 1;
const A_ARG: usize = 2;
const R_SEQ: usize = 0;
const R_RET: usize = 1;

pub struct PwfQueue {
    pool: Arc<PmemPool>,
    ring: SeqRing,
    /// Combiner coordination word (0 = free).
    lock: PAddr,
    /// Per-thread announce lines.
    announce: Vec<PAddr>,
    /// Per-thread response lines.
    response: Vec<PAddr>,
    /// Per-thread volatile sequence counters.
    my_seq: Vec<CachePadded<AtomicU64>>,
    nthreads: usize,
}

impl PwfQueue {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize) -> Self {
        let lock = pool.alloc_lines(1);
        pool.set_hot(lock, 1, crate::pmem::Hotness::Global);
        let announce = (0..nthreads).map(|_| pool.alloc_lines(1)).collect();
        let response = (0..nthreads).map(|_| pool.alloc_lines(1)).collect();
        Self {
            pool: Arc::clone(pool),
            ring: SeqRing::alloc(pool, 1 << 16),
            lock,
            announce,
            response,
            my_seq: (0..nthreads).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            nthreads,
        }
    }

    fn run(&self, tid: usize, op: u64, arg: u64) -> u64 {
        let p = &self.pool;
        let s = self.my_seq[tid].load(Ordering::Relaxed) + 1;
        self.my_seq[tid].store(s, Ordering::Relaxed);
        // Publish the request; seq last (TSO makes op/arg visible first).
        p.store(tid, self.announce[tid].add(A_OP), op);
        p.store(tid, self.announce[tid].add(A_ARG), arg);
        p.store(tid, self.announce[tid].add(A_SEQ), s);
        // Batch-forming yield (see ccsynch.rs): give other requesters a
        // chance to announce before a combiner scans.
        std::thread::yield_now();
        loop {
            // Served?
            if p.load(tid, self.response[tid].add(R_SEQ)) == s {
                return p.load(tid, self.response[tid].add(R_RET));
            }
            // Try to combine (test-and-test-and-set: only CAS when the
            // lock reads free, so spinning does not hammer the line).
            if p.load(tid, self.lock) == 0 && p.cas(tid, self.lock, 0, 1) {
                let mut dirty: Option<(u64, u64)> = None;
                let mut batch: Vec<(usize, u64, u64)> = Vec::with_capacity(self.nthreads);
                for t in 0..self.nthreads {
                    let a_seq = p.load(tid, self.announce[t].add(A_SEQ));
                    let r_seq = p.load(tid, self.response[t].add(R_SEQ));
                    if a_seq > r_seq {
                        let o = p.load(tid, self.announce[t].add(A_OP));
                        let a = p.load(tid, self.announce[t].add(A_ARG));
                        let ret = self.ring.apply(p, tid, o, a, &mut dirty);
                        batch.push((t, a_seq, ret));
                    }
                }
                // Durable before any response is visible.
                self.ring.commit(p, tid, dirty);
                for (t, a_seq, ret) in batch {
                    p.store(tid, self.response[t].add(R_RET), ret);
                    p.store(tid, self.response[t].add(R_SEQ), a_seq);
                }
                p.store(tid, self.lock, 0);
                // Our own request was in the scan (a_seq > r_seq held).
                debug_assert_eq!(p.load(tid, self.response[tid].add(R_SEQ)), s);
                return p.load(tid, self.response[tid].add(R_RET));
            }
            std::hint::spin_loop();
        }
    }
}

impl ConcurrentQueue for PwfQueue {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let _ = self.run(tid, OP_ENQ, item);
        Ok(())
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let r = self.run(tid, OP_DEQ, 0);
        Ok(if r == RET_EMPTY { None } else { Some(r) })
    }

    fn name(&self) -> &'static str {
        "pwfqueue"
    }
}

impl PersistentQueue for PwfQueue {
    fn recover(&self, pool: &PmemPool) {
        // Announce machinery is DRAM-modelled: wipe it.
        pool.store(0, self.lock, 0);
        for t in 0..self.nthreads {
            for f in 0..3 {
                pool.store(0, self.announce[t].add(f), 0);
            }
            for f in 0..2 {
                pool.store(0, self.response[t].add(f), 0);
            }
            self.my_seq[t].store(0, Ordering::Relaxed);
        }
        self.ring.recover(pool, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(n: usize) -> (Arc<PmemPool>, PwfQueue) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 55,
        }));
        let q = PwfQueue::new(&pool, n);
        (pool, q)
    }

    #[test]
    fn fifo_and_empty() {
        let (_p, q) = mk(2);
        for v in 0..30u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..30u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn crash_recovery_preserves_committed_state() {
        let (p, q) = mk(2);
        for v in 0..12u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..4u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 4..12u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn mpmc_no_loss_no_dup() {
        use std::sync::atomic::Ordering as O;
        let (_p, q) = mk(8);
        let q = Arc::new(q);
        let total = 4 * 600u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let q = Arc::clone(&q);
            hs.push(std::thread::spawn(move || {
                for i in 0..600u64 {
                    q.enqueue(pid, pid as u64 * 10_000 + i).unwrap();
                }
            }));
        }
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(O::Relaxed) < total {
                    match q.dequeue(4 + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, O::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total);
    }

    #[test]
    fn announce_scan_covers_all_threads() {
        // Even an idle thread's slot is scanned: publish from thread 3 and
        // let thread 0 combine it by running its own op.
        let (p, q) = mk(4);
        // Thread 3 publishes an enqueue but never spins (we emulate a slow
        // thread by writing its announce directly).
        p.store(3, q.announce[3].add(A_OP), OP_ENQ);
        p.store(3, q.announce[3].add(A_ARG), 42);
        p.store(3, q.announce[3].add(A_SEQ), 1);
        // Thread 0 runs any op — its combining round must also serve 3.
        q.enqueue(0, 7).unwrap();
        assert_eq!(p.load(0, q.response[3].add(R_SEQ)), 1, "helper must serve thread 3");
        // Ring now has two items; order depends on scan order (0 before 3
        // or 3 before 0 — scan is by tid, so 0's item first... thread 0's
        // combine scanned t=0 (its own) then t=3).
        assert_eq!(q.dequeue(1).unwrap(), Some(7));
        assert_eq!(q.dequeue(1).unwrap(), Some(42));
    }
}
