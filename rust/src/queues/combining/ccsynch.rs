//! CC-Synch combining protocol (Fatourou & Kallimanis, PPoPP'12 \[6\]).
//!
//! Threads swap a fresh node onto a shared combining list tail, publish
//! their request in the node they received, and spin. The thread whose
//! node reaches the list head becomes the **combiner**: it walks the list
//! applying up to `H` requests to the backend, then hands the combiner
//! role to the next waiter. For persistent backends the combiner applies
//! the whole batch first, persists once ([`CombinerBackend::commit`]),
//! and only then releases the batch's waiters — completed operations are
//! therefore always durable.
//!
//! All node state lives in the pool so crash simulation wipes it like the
//! DRAM it models, and so spin-waits propagate virtual time correctly.
//!
//! Node layout (one cache line):
//! `[next][wait][completed][op][arg][ret][_,_]`.

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::pmem::{PAddr, PmemPool};

/// Backend applied under combining.
pub trait CombinerBackend: Send + Sync {
    /// Apply one request; `dirty` accumulates batch flush state.
    fn apply(
        &self,
        pool: &PmemPool,
        tid: usize,
        op: u64,
        arg: u64,
        dirty: &mut Option<(u64, u64)>,
    ) -> u64;

    /// Persistence point at the end of a batch (no-op for volatile).
    fn commit(&self, pool: &PmemPool, tid: usize, dirty: Option<(u64, u64)>);
}

const F_NEXT: usize = 0;
const F_WAIT: usize = 1;
const F_DONE: usize = 2;
const F_OP: usize = 3;
const F_ARG: usize = 4;
const F_RET: usize = 5;

/// The combining lock/list.
pub struct CcSynch {
    pool: Arc<PmemPool>,
    /// List tail word.
    tail: PAddr,
    /// Each thread's spare node (volatile handle; nodes live in the pool).
    my_node: Vec<CachePadded<AtomicU64>>,
    /// All nodes ever allocated (for recovery re-init).
    nodes: Vec<PAddr>,
    /// Combining bound: max requests served per combiner stint.
    h_bound: usize,
}

impl CcSynch {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize) -> Self {
        let tail = pool.alloc_lines(1);
        pool.set_hot(tail, 1, crate::pmem::Hotness::Global);
        // One node per thread + one initial list node.
        let mut nodes = Vec::with_capacity(nthreads + 1);
        for _ in 0..=nthreads {
            nodes.push(pool.alloc_lines(1));
        }
        let me = Self {
            pool: Arc::clone(pool),
            tail,
            my_node: (0..nthreads).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
            nodes,
            h_bound: (3 * nthreads).max(8),
        };
        me.reset_volatile(nthreads);
        me
    }

    /// (Re)initialize the combining list — construction and post-crash.
    pub fn reset_volatile(&self, nthreads: usize) {
        let p = &self.pool;
        for &n in &self.nodes {
            for f in 0..8 {
                p.store(0, n.add(f), 0);
            }
        }
        // nodes[nthreads] is the initial placeholder: wait = 0 so the first
        // arriver combines immediately.
        let init = self.nodes[nthreads];
        p.store(0, self.tail, init.to_u64());
        for t in 0..nthreads {
            self.my_node[t].store(self.nodes[t].to_u64(), Ordering::Relaxed);
        }
    }

    /// Execute `(op, arg)` through the combining protocol; returns the
    /// response.
    pub fn run(&self, tid: usize, op: u64, arg: u64, backend: &dyn CombinerBackend) -> u64 {
        let p = &self.pool;
        // My spare becomes the new tail placeholder.
        let next_node = PAddr::from_u64(self.my_node[tid].load(Ordering::Relaxed));
        p.store(tid, next_node.add(F_WAIT), 1);
        p.store(tid, next_node.add(F_DONE), 0);
        p.store(tid, next_node.add(F_NEXT), 0);
        // Swap onto the list; `cur` is where my request goes.
        let cur = PAddr::from_u64(p.swap(tid, self.tail, next_node.to_u64()));
        p.store(tid, cur.add(F_OP), op);
        p.store(tid, cur.add(F_ARG), arg);
        p.store(tid, cur.add(F_NEXT), next_node.to_u64());
        self.my_node[tid].store(cur.to_u64(), Ordering::Relaxed);
        // Yield once before spinning: on few-core hosts this lets other
        // requesters publish into the same combining stint, restoring the
        // batch sizes a many-core machine gets naturally (scheduling hint
        // only — no semantic effect).
        std::thread::yield_now();
        // Spin until served or promoted to combiner.
        while p.load(tid, cur.add(F_WAIT)) == 1 {
            std::hint::spin_loop();
        }
        if p.load(tid, cur.add(F_DONE)) == 1 {
            return p.load(tid, cur.add(F_RET));
        }
        // --- Combiner ---
        let mut dirty: Option<(u64, u64)> = None;
        let mut batch: Vec<PAddr> = Vec::with_capacity(self.h_bound);
        let mut tmp = cur;
        let mut served = 0usize;
        loop {
            let next = p.load(tid, tmp.add(F_NEXT));
            if next == 0 || served >= self.h_bound {
                break;
            }
            let o = p.load(tid, tmp.add(F_OP));
            let a = p.load(tid, tmp.add(F_ARG));
            let ret = backend.apply(p, tid, o, a, &mut dirty);
            p.store(tid, tmp.add(F_RET), ret);
            batch.push(tmp);
            served += 1;
            tmp = PAddr::from_u64(next);
        }
        // Persist the whole batch BEFORE announcing any completion.
        backend.commit(p, tid, dirty);
        let mut my_ret = 0;
        for &node in &batch {
            if node == cur {
                my_ret = p.load(tid, node.add(F_RET));
                continue; // own node: no need to signal myself
            }
            p.store(tid, node.add(F_DONE), 1);
            p.store(tid, node.add(F_WAIT), 0);
        }
        // Hand the combiner role to the next waiter (or release).
        p.store(tid, tmp.add(F_WAIT), 0);
        my_ret
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use std::sync::Mutex;

    /// Trivial backend: counts applications, echoes arg+op.
    struct Echo {
        log: Mutex<Vec<(u64, u64)>>,
        commits: AtomicU64,
    }

    impl CombinerBackend for Echo {
        fn apply(
            &self,
            _pool: &PmemPool,
            _tid: usize,
            op: u64,
            arg: u64,
            _dirty: &mut Option<(u64, u64)>,
        ) -> u64 {
            self.log.lock().unwrap().push((op, arg));
            op * 1000 + arg
        }
        fn commit(&self, _pool: &PmemPool, _tid: usize, _dirty: Option<(u64, u64)>) {
            self.commits.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn mk(n: usize) -> (Arc<PmemPool>, CcSynch) {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 14).with_cost(CostModel::zero()),
        ));
        let cc = CcSynch::new(&pool, n);
        (pool, cc)
    }

    #[test]
    fn single_thread_applies_own_request() {
        let (_p, cc) = mk(2);
        let be = Echo { log: Mutex::new(Vec::new()), commits: AtomicU64::new(0) };
        let r = cc.run(0, 7, 5, &be);
        assert_eq!(r, 7005);
        assert_eq!(be.log.lock().unwrap().len(), 1);
        assert_eq!(be.commits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn sequential_requests_all_applied() {
        let (_p, cc) = mk(2);
        let be = Echo { log: Mutex::new(Vec::new()), commits: AtomicU64::new(0) };
        for i in 0..10u64 {
            assert_eq!(cc.run(i as usize % 2, 1, i, &be), 1000 + i);
        }
        assert_eq!(be.log.lock().unwrap().len(), 10);
    }

    #[test]
    fn concurrent_all_requests_served_exactly_once() {
        let (_p, cc) = mk(8);
        let cc = Arc::new(cc);
        let be = Arc::new(Echo { log: Mutex::new(Vec::new()), commits: AtomicU64::new(0) });
        let mut hs = Vec::new();
        for tid in 0..8usize {
            let cc = Arc::clone(&cc);
            let be = Arc::clone(&be);
            hs.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    let arg = tid as u64 * 1000 + i;
                    assert_eq!(cc.run(tid, 1, arg, be.as_ref()), 1000 + arg);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let log = be.log.lock().unwrap();
        assert_eq!(log.len(), 8 * 500, "every request applied exactly once");
        // Batching actually happened (fewer commits than requests) OR the
        // scheduler serialized everything (1 commit per request) — both
        // valid; just sanity-check commits ≤ requests.
        assert!(be.commits.load(Ordering::Relaxed) <= 8 * 500);
    }

    #[test]
    fn reset_volatile_reusable() {
        let (_p, cc) = mk(2);
        let be = Echo { log: Mutex::new(Vec::new()), commits: AtomicU64::new(0) };
        cc.run(0, 1, 1, &be);
        cc.reset_volatile(2);
        let r = cc.run(1, 2, 3, &be);
        assert_eq!(r, 2003);
    }
}
