//! CC-Queue — the volatile combining queue of \[6\]: CC-Synch over a
//! sequential ring, no persistence. Conventional-setting baseline.

use std::sync::Arc;

use super::ccsynch::{CcSynch, CombinerBackend};
use super::seqring::SeqRing;
use super::{OP_DEQ, OP_ENQ, RET_EMPTY};
use crate::pmem::PmemPool;
use crate::queues::{ConcurrentQueue, QueueError, MAX_ITEM};

struct VolatileRing(SeqRing);

impl CombinerBackend for VolatileRing {
    fn apply(
        &self,
        pool: &PmemPool,
        tid: usize,
        op: u64,
        arg: u64,
        dirty: &mut Option<(u64, u64)>,
    ) -> u64 {
        self.0.apply(pool, tid, op, arg, dirty)
    }

    fn commit(&self, _pool: &PmemPool, _tid: usize, _dirty: Option<(u64, u64)>) {
        // Volatile: no persistence.
    }
}

pub struct CcQueue {
    /// Keep-alive handle (operations go through `cc`'s pool).
    _pool: Arc<PmemPool>,
    cc: CcSynch,
    ring: VolatileRing,
}

impl CcQueue {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize) -> Self {
        Self {
            _pool: Arc::clone(pool),
            cc: CcSynch::new(pool, nthreads),
            ring: VolatileRing(SeqRing::alloc(pool, 1 << 16)),
        }
    }
}

impl ConcurrentQueue for CcQueue {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let _ = self.cc.run(tid, OP_ENQ, item, &self.ring);
        Ok(())
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let r = self.cc.run(tid, OP_DEQ, 0, &self.ring);
        Ok(if r == RET_EMPTY { None } else { Some(r) })
    }

    fn name(&self) -> &'static str {
        "ccqueue"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(n: usize) -> CcQueue {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 18).with_cost(CostModel::zero()),
        ));
        CcQueue::new(&pool, n)
    }

    #[test]
    fn fifo() {
        let q = mk(2);
        for v in 0..50u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..50u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn mpmc_no_loss() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let q = Arc::new(mk(8));
        let total = 4 * 800u64;
        let consumed = Arc::new(AtomicU64::new(0));
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut hs = Vec::new();
        for pid in 0..4usize {
            let q = Arc::clone(&q);
            hs.push(std::thread::spawn(move || {
                for i in 0..800u64 {
                    q.enqueue(pid, pid as u64 * 10_000 + i).unwrap();
                }
            }));
        }
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let (consumed, seen) = (Arc::clone(&consumed), Arc::clone(&seen));
            hs.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(4 + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len() as u64, total);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len() as u64, total);
    }
}
