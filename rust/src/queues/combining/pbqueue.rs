//! PBQueue — persistent blocking combining queue, re-implemented from \[9\]
//! (the paper's best competitor; Fig. 2). CC-Synch combining over a
//! sequential ring whose batches are made durable *before* results are
//! announced: per batch, one psync for touched item lines + one for the
//! packed commit word. Amortized over a full batch of `n` requests this is
//! ≪ 1 psync/op — but every request still waits for the serial combiner,
//! which is what caps its scalability against PerLCRQ.

use std::sync::Arc;

use super::ccsynch::{CcSynch, CombinerBackend};
use super::seqring::SeqRing;
use super::{OP_DEQ, OP_ENQ, RET_EMPTY};
use crate::pmem::PmemPool;
use crate::queues::{ConcurrentQueue, PersistentQueue, QueueError, MAX_ITEM};

struct PersistentRing(SeqRing);

impl CombinerBackend for PersistentRing {
    fn apply(
        &self,
        pool: &PmemPool,
        tid: usize,
        op: u64,
        arg: u64,
        dirty: &mut Option<(u64, u64)>,
    ) -> u64 {
        self.0.apply(pool, tid, op, arg, dirty)
    }

    fn commit(&self, pool: &PmemPool, tid: usize, dirty: Option<(u64, u64)>) {
        self.0.commit(pool, tid, dirty);
    }
}

pub struct PbQueue {
    /// Keep-alive handle (operations go through `cc`'s pool).
    _pool: Arc<PmemPool>,
    cc: CcSynch,
    ring: PersistentRing,
    nthreads: usize,
}

impl PbQueue {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize) -> Self {
        Self {
            _pool: Arc::clone(pool),
            cc: CcSynch::new(pool, nthreads),
            ring: PersistentRing(SeqRing::alloc(pool, 1 << 16)),
            nthreads,
        }
    }
}

impl ConcurrentQueue for PbQueue {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let _ = self.cc.run(tid, OP_ENQ, item, &self.ring);
        Ok(())
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let r = self.cc.run(tid, OP_DEQ, 0, &self.ring);
        Ok(if r == RET_EMPTY { None } else { Some(r) })
    }

    fn name(&self) -> &'static str {
        "pbqueue"
    }
}

impl PersistentQueue for PbQueue {
    fn recover(&self, pool: &PmemPool) {
        // Combining list is DRAM: rebuild it; ring state comes from the
        // last durable commit.
        self.cc.reset_volatile(self.nthreads);
        self.ring.0.recover(pool, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(n: usize) -> (Arc<PmemPool>, PbQueue) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 33,
        }));
        let q = PbQueue::new(&pool, n);
        (pool, q)
    }

    #[test]
    fn fifo_and_empty() {
        let (_p, q) = mk(2);
        for v in 0..30u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..30u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn completed_ops_survive_crash() {
        let (p, q) = mk(2);
        for v in 0..20u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..8u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        q.recover(&p);
        for v in 8..20u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v), "item {v} lost");
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn durability_is_batch_amortized() {
        // Sequential use: every op is its own batch (2 psyncs per op — the
        // blocking path). The win appears under concurrency; here we just
        // check the sequential invariant.
        let (p, q) = mk(1);
        p.stats.reset();
        q.enqueue(0, 5).unwrap();
        let s = p.stats.total();
        assert_eq!(s.psyncs, 2, "item-lines psync + commit psync");
        p.stats.reset();
        let _ = q.dequeue(0).unwrap();
        let s = p.stats.total();
        assert_eq!(s.psyncs, 1, "dequeue batch: commit psync only (no item writes)");
    }

    #[test]
    fn crash_mid_everything_recovers_consistent() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 20,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed: 44,
        }));
        let q = Arc::new(PbQueue::new(&pool, 4));
        let mut rng = Xoshiro256::seed_from(7);
        let mut returned = Vec::new();
        for cycle in 0..4u64 {
            pool.arm_crash_after(1_500 + rng.next_below(1_500));
            let mut hs = Vec::new();
            for tid in 0..4usize {
                let q = Arc::clone(&q);
                hs.push(std::thread::spawn(move || {
                    let mut mine = Vec::new();
                    let _ = run_guarded(|| {
                        for i in 0..50_000u64 {
                            // Globally unique values across cycles/threads.
                            q.enqueue(tid, cycle * 10_000_000 + tid as u64 * 1_000_000 + i)
                                .unwrap();
                            if let Some(v) = q.dequeue(tid).unwrap() {
                                mine.push(v);
                            }
                        }
                    });
                    mine
                }));
            }
            for h in hs {
                returned.extend(h.join().unwrap());
            }
            pool.crash(&mut rng);
            q.recover(&pool);
        }
        while let Some(v) = q.dequeue(0).unwrap() {
            returned.push(v);
        }
        let n = returned.len();
        returned.sort_unstable();
        returned.dedup();
        assert_eq!(returned.len(), n, "duplicate across crashes");
    }
}
