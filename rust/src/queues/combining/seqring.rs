//! The sequential ring buffer applied by combiners, with crash-atomic
//! batch commits.
//!
//! Layout:
//! ```text
//! base + 0  : commit word — packed (head:u32 | tail:u32), the durable
//!             snapshot; own line
//! base + 8  : working head (volatile-ish; rebuilt from commit at recovery)
//! base + 16 : working tail
//! base + 24…: item slots (cap words)
//! ```
//!
//! Batch protocol (PBQueue/PWFQueue): the combiner applies operations to
//! the working state, then [`SeqRing::commit`]s — flush touched item
//! lines, psync, write + flush the packed commit word, psync. Because the
//! commit word is a single 8-byte store on a single line, recovery always
//! observes a *consistent prefix*: either the whole batch (commit landed)
//! or none of it (ops not yet completed — their callers never returned).

use super::{OP_DEQ, OP_ENQ, RET_EMPTY, RET_OK};
use crate::pmem::{PAddr, PmemPool, WORDS_PER_LINE};

pub struct SeqRing {
    base: PAddr,
    cap: usize,
}

#[inline]
fn pack(head: u64, tail: u64) -> u64 {
    debug_assert!(head <= u32::MAX as u64 && tail <= u32::MAX as u64);
    (head << 32) | tail
}

#[inline]
fn unpack(w: u64) -> (u64, u64) {
    (w >> 32, w & 0xFFFF_FFFF)
}

impl SeqRing {
    pub fn alloc(pool: &PmemPool, cap: usize) -> Self {
        assert!(cap.is_power_of_two());
        let words = 3 * WORDS_PER_LINE + cap;
        let base = pool.alloc(words, WORDS_PER_LINE);
        Self { base, cap }
    }

    fn commit_addr(&self) -> PAddr {
        self.base
    }
    fn whead_addr(&self) -> PAddr {
        self.base.add(WORDS_PER_LINE)
    }
    fn wtail_addr(&self) -> PAddr {
        self.base.add(2 * WORDS_PER_LINE)
    }
    fn item_addr(&self, i: u64) -> PAddr {
        self.base.add(3 * WORDS_PER_LINE + (i as usize & (self.cap - 1)))
    }

    /// Apply one operation to the working state (combiner context only).
    /// Returns the response and, for enqueues, records the touched item
    /// index range in `dirty` (min, max) for the commit flush.
    pub fn apply(
        &self,
        pool: &PmemPool,
        tid: usize,
        op: u64,
        arg: u64,
        dirty: &mut Option<(u64, u64)>,
    ) -> u64 {
        match op {
            OP_ENQ => {
                let t = pool.load(tid, self.wtail_addr());
                let h = pool.load(tid, self.whead_addr());
                assert!(
                    t - h < self.cap as u64,
                    "seq ring overflow: size the combining ring capacity to the workload"
                );
                pool.store(tid, self.item_addr(t), arg + 1);
                pool.store(tid, self.wtail_addr(), t + 1);
                *dirty = Some(match *dirty {
                    None => (t, t),
                    Some((lo, hi)) => (lo.min(t), hi.max(t)),
                });
                RET_OK
            }
            OP_DEQ => {
                let h = pool.load(tid, self.whead_addr());
                let t = pool.load(tid, self.wtail_addr());
                if h == t {
                    RET_EMPTY
                } else {
                    let v = pool.load(tid, self.item_addr(h));
                    pool.store(tid, self.whead_addr(), h + 1);
                    v - 1
                }
            }
            _ => unreachable!("unknown combining op {op}"),
        }
    }

    /// Persist the batch: touched item lines, then the commit word.
    pub fn commit(&self, pool: &PmemPool, tid: usize, dirty: Option<(u64, u64)>) {
        if let Some((lo, hi)) = dirty {
            // Flush each touched item line once (wraparound-aware; the
            // range is ≤ one batch ≤ cap items).
            let first_line = self.item_addr(lo).line();
            let mut line = first_line;
            loop {
                pool.pwb(tid, PAddr((line * WORDS_PER_LINE) as u32));
                let last = self.item_addr(hi).line();
                if line == last {
                    break;
                }
                // Step through wrapped lines.
                line = if line
                    == self.item_addr(self.cap as u64 - 1).line()
                {
                    self.item_addr(0).line()
                } else {
                    line + 1
                };
                if line == first_line {
                    break; // full wrap guard
                }
            }
            pool.psync(tid);
        }
        let h = pool.load(tid, self.whead_addr());
        let t = pool.load(tid, self.wtail_addr());
        pool.store(tid, self.commit_addr(), pack(h, t));
        pool.pwb(tid, self.commit_addr());
        pool.psync(tid);
    }

    /// Rebuild the working state from the last durable commit.
    pub fn recover(&self, pool: &PmemPool, tid: usize) {
        let (h, t) = unpack(pool.load(tid, self.commit_addr()));
        pool.store(tid, self.whead_addr(), h);
        pool.store(tid, self.wtail_addr(), t);
        pool.pwb(tid, self.whead_addr());
        pool.pwb(tid, self.wtail_addr());
        pool.psync(tid);
    }

    /// (head, tail) of the working state.
    pub fn endpoints(&self, pool: &PmemPool, tid: usize) -> (u64, u64) {
        (pool.load(tid, self.whead_addr()), pool.load(tid, self.wtail_addr()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;
    use std::sync::Arc;

    fn mk(cap: usize) -> (Arc<PmemPool>, SeqRing) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 16,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 5,
        }));
        let r = SeqRing::alloc(&pool, cap);
        (pool, r)
    }

    #[test]
    fn fifo_sequential() {
        let (p, r) = mk(64);
        let mut dirty = None;
        for v in 0..10u64 {
            assert_eq!(r.apply(&p, 0, OP_ENQ, v, &mut dirty), RET_OK);
        }
        for v in 0..10u64 {
            assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut dirty), v);
        }
        assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut dirty), RET_EMPTY);
    }

    #[test]
    fn committed_batch_survives_crash() {
        let (p, r) = mk(64);
        let mut dirty = None;
        for v in 0..5u64 {
            r.apply(&p, 0, OP_ENQ, v, &mut dirty);
        }
        r.commit(&p, 0, dirty);
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        r.recover(&p, 0);
        let mut d2 = None;
        for v in 0..5u64 {
            assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut d2), v);
        }
        assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut d2), RET_EMPTY);
    }

    #[test]
    fn uncommitted_batch_rolls_back() {
        let (p, r) = mk(64);
        let mut dirty = None;
        r.apply(&p, 0, OP_ENQ, 1, &mut dirty);
        r.commit(&p, 0, dirty);
        // Second batch applied but NOT committed.
        let mut d2 = None;
        r.apply(&p, 0, OP_ENQ, 2, &mut d2);
        r.apply(&p, 0, OP_ENQ, 3, &mut d2);
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        r.recover(&p, 0);
        let mut d3 = None;
        assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut d3), 1);
        assert_eq!(
            r.apply(&p, 0, OP_DEQ, 0, &mut d3),
            RET_EMPTY,
            "uncommitted enqueues must roll back"
        );
    }

    #[test]
    fn wraparound() {
        let (p, r) = mk(8);
        let mut rounds = 0u64;
        for _ in 0..5 {
            let mut d = None;
            for v in 0..6u64 {
                r.apply(&p, 0, OP_ENQ, rounds * 10 + v, &mut d);
            }
            r.commit(&p, 0, d);
            let mut d = None;
            for v in 0..6u64 {
                assert_eq!(r.apply(&p, 0, OP_DEQ, 0, &mut d), rounds * 10 + v);
            }
            r.commit(&p, 0, d);
            rounds += 1;
        }
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let (p, r) = mk(8);
        let mut d = None;
        for v in 0..9u64 {
            r.apply(&p, 0, OP_ENQ, v, &mut d);
        }
    }
}
