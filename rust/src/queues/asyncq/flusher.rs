//! The background flush driver: a bounded MPMC submission ring plus the
//! combiner workers that execute queued operations and gate their
//! completion on the group-commit `psync`.
//!
//! ## Why a combiner
//!
//! The pmem model (like real hardware) drains a `psync` against the
//! *calling thread's* queued `pwb`s, and the sharded queue's batch logs
//! are single-writer per thread slot. A background thread therefore
//! cannot flush another thread's filling batch — the only sound way to
//! both batch and complete asynchronously is for the operations
//! themselves to execute on the thread that will issue the `psync`.
//! That is flat combining (Rusanovsky et al.): callers publish requests,
//! a combiner executes them against its own thread slot, and a whole
//! group of operations becomes durable — and is completed — at one
//! persist. Each [`Flusher`] worker owns one sharded-queue thread slot
//! and is simultaneously the combiner and the group-commit driver for
//! every operation it admits.
//!
//! ## Triggers
//!
//! A worker flushes its in-flight window when any of these fires:
//!
//! * **depth** — the window reached `AsyncCfg::depth` admitted,
//!   not-yet-durable operations (backpressure bound);
//! * **deadline** — the oldest admitted operation has waited
//!   `AsyncCfg::flush_us` microseconds (bounds completion latency when
//!   traffic trickles);
//! * **stop** — graceful shutdown drains the ring and flushes the rest.
//!
//! The inner queue may also auto-flush on its own batch boundary
//! (`batch`/`batch_deq`); the worker detects that via
//! [`crate::queues::sharded::ShardedQueue::pending_ops`] returning to
//! zero and completes the covered futures without issuing another
//! `psync` — the wake rule is "the op's durability point retired",
//! however it retired.
//!
//! ## Crash behavior
//!
//! Every pmem primitive can unwind with a [`crate::pmem::CrashSignal`].
//! The worker runs its loop under [`run_guarded`]; on a crash it seals
//! the layer (no new submissions), fails every parked and every queued
//! operation with [`AsyncError::Crashed`], and exits. An operation whose
//! flush never retired is thus *failed*, never *resolved* — the
//! resolved-implies-durable invariant cannot be violated by a crash at
//! any point, because the READY transition is reachable only from the
//! straight-line path `flush-returned-normally → wake`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crossbeam_utils::CachePadded;

use crate::obs::{self, ObsSite};
use crate::pmem::{run_guarded, Topology};
use crate::queues::sharded::Shardable;
use crate::queues::{ConcurrentQueue, PersistentQueue};

use super::future::{AsyncError, CompletionSlot};
use super::Shared;

/// An operation published to the combiner.
pub(crate) enum AsyncOp {
    /// Enqueue `value`; complete after the batch flush retires.
    Enq { value: u64, slot: Arc<CompletionSlot> },
    /// Dequeue; stage the value and complete after the dequeue-log flush
    /// retires (EMPTY completes immediately — no persistent effect).
    /// `tag` is an opaque caller correlation id handed to the
    /// executed-hook (the async harness passes the submitting tid so the
    /// checker's `DeqExecuted` markers attribute correctly).
    Deq { tag: u64, slot: Arc<CompletionSlot> },
    /// Combiner-executed closure (flat-combining escape hatch, e.g. the
    /// broker's ack path): runs on the worker's tid against the queue's
    /// topology (receiving the shard-plan epoch in force at execution),
    /// returns `(result, pool_mask)`; completion waits until every pool
    /// in `pool_mask` has been `psync`ed by the worker.
    Exec {
        f: Box<dyn FnOnce(&Topology, usize, u64) -> (u64, u64) + Send>,
        slot: Arc<CompletionSlot>,
    },
}

impl AsyncOp {
    pub(crate) fn fail(self, err: AsyncError) {
        match self {
            AsyncOp::Enq { slot, .. }
            | AsyncOp::Deq { slot, .. }
            | AsyncOp::Exec { slot, .. } => slot.fail(err),
        }
    }

    /// Trace-correlation id of the op's completion slot.
    pub(crate) fn trace_id(&self) -> u64 {
        match self {
            AsyncOp::Enq { slot, .. }
            | AsyncOp::Deq { slot, .. }
            | AsyncOp::Exec { slot, .. } => slot.id,
        }
    }
}

/// Bounded MPMC ring (Vyukov sequence-number scheme): producers are the
/// caller threads, consumers the flusher workers. `push` fails (returning
/// the op) when full — the submission path turns that into backpressure.
pub(crate) struct OpRing {
    cells: Box<[RingCell]>,
    mask: usize,
    head: CachePadded<AtomicUsize>,
    tail: CachePadded<AtomicUsize>,
}

struct RingCell {
    seq: AtomicUsize,
    op: UnsafeCell<Option<AsyncOp>>,
}

// SAFETY: the sequence protocol gives each cell exactly one writer (the
// pusher that won the tail CAS) and one reader (the popper that won the
// head CAS) per lap, with Release/Acquire ordering on `seq` publishing
// the payload between them.
unsafe impl Send for OpRing {}
unsafe impl Sync for OpRing {}

impl OpRing {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(2);
        Self {
            cells: (0..cap)
                .map(|i| RingCell { seq: AtomicUsize::new(i), op: UnsafeCell::new(None) })
                .collect(),
            mask: cap - 1,
            head: CachePadded::new(AtomicUsize::new(0)),
            tail: CachePadded::new(AtomicUsize::new(0)),
        }
    }

    pub fn push(&self, op: AsyncOp) -> Result<(), AsyncOp> {
        let mut pos = self.tail.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[pos & self.mask];
            let seq = cell.seq.load(Ordering::Acquire);
            if seq == pos {
                match self.tail.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: tail CAS win = exclusive claim on this
                        // cell for this lap.
                        unsafe { *cell.op.get() = Some(op) };
                        cell.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(cur) => pos = cur,
                }
            } else if (seq as isize) < (pos as isize) {
                return Err(op); // full (cell still un-popped from last lap)
            } else {
                pos = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Approximate occupancy (ops pushed but not yet popped) — the
    /// combiner ring-occupancy gauge. Racy by nature; monotone counters
    /// make it non-negative.
    pub fn occupancy(&self) -> usize {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Relaxed);
        tail.saturating_sub(head)
    }

    pub fn pop(&self) -> Option<AsyncOp> {
        let mut pos = self.head.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[pos & self.mask];
            let seq = cell.seq.load(Ordering::Acquire);
            if seq == pos + 1 {
                match self.head.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: head CAS win = exclusive claim.
                        let op = unsafe { (*cell.op.get()).take() };
                        cell.seq.store(pos + self.mask + 1, Ordering::Release);
                        return op;
                    }
                    Err(cur) => pos = cur,
                }
            } else if (seq as isize) <= (pos as isize) {
                return None; // empty
            } else {
                pos = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

/// Handle over the spawned flusher workers. Stopping is graceful: workers
/// drain the ring, flush what remains, complete every future, and detach
/// their queue slots. After a simulated crash the workers have already
/// failed everything and exited; `stop` then just joins.
pub struct Flusher {
    workers: Vec<std::thread::JoinHandle<()>>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    crashed: Arc<std::sync::atomic::AtomicBool>,
    /// Type-erased `seal + drain_fail(Closed)` on the shared state, run
    /// after the workers exit: an op pushed after the last worker's final
    /// ring check would otherwise be stranded with its future forever
    /// pending — sealing keeps the "racing submissions fail Closed,
    /// never hang" promise.
    finisher: Box<dyn Fn() + Send>,
}

impl Flusher {
    pub(crate) fn spawn<Q: Shardable + 'static>(
        shared: &Arc<Shared<Q>>,
        first_tid: usize,
    ) -> Flusher {
        let workers = (0..shared.cfg.flushers)
            .map(|i| {
                let shared = Arc::clone(shared);
                let tid = first_tid + i;
                std::thread::spawn(move || worker_loop(shared, tid))
            })
            .collect();
        let fin = Arc::clone(shared);
        Flusher {
            workers,
            stop: Arc::clone(&shared.stop),
            crashed: Arc::clone(&shared.crashed),
            finisher: Box::new(move || {
                fin.seal();
                fin.drain_fail(AsyncError::Closed);
            }),
        }
    }

    /// Signal shutdown and join the workers. Callers must have stopped
    /// submitting first (a submission racing `stop` is failed with
    /// [`AsyncError::Closed`] — by the workers' final drain or by the
    /// post-join seal — never silently dropped). Returns `true` if any
    /// worker observed a simulated crash (in which case pending futures
    /// were failed with [`AsyncError::Crashed`], not completed).
    pub fn stop(mut self) -> bool {
        self.join()
    }

    fn join(&mut self) -> bool {
        self.stop.store(true, Ordering::Release);
        for h in self.workers.drain(..) {
            // A CrashSignal unwind is caught inside the worker; a real
            // panic propagates here.
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
        // No consumers remain: seal and fail anything that raced in.
        // Idempotent after the crash path's own seal + drain.
        (self.finisher)();
        self.crashed.load(Ordering::Acquire)
    }
}

impl Drop for Flusher {
    fn drop(&mut self) {
        // stop() drains self.workers; a bare drop signals + joins so the
        // threads never leak past the Flusher's lifetime.
        let _ = self.join();
    }
}

/// One combiner worker. See module docs for the protocol; the correctness
/// core is that `complete()` is only ever reached on the straight-line
/// path after a flush (or auto-flush) returned normally.
fn worker_loop<Q: Shardable + 'static>(shared: Arc<Shared<Q>>, tid: usize) {
    let q = &shared.queue;
    let mut parked_enq: Vec<Arc<CompletionSlot>> = Vec::new();
    let mut parked_deq: Vec<Arc<CompletionSlot>> = Vec::new();
    let mut parked_exec: Vec<Arc<CompletionSlot>> = Vec::new();
    // Pools the parked Exec ops' pwbs landed on but which no queue flush
    // is known to have psynced yet.
    let mut exec_pools: u64 = 0;
    // When the oldest parked op was admitted (deadline trigger).
    let mut oldest: Option<Instant> = None;
    let exec_hook = shared.deq_executed_hook.lock().unwrap().clone();
    // Registry instruments (no-ops while the registry is disabled): the
    // ring-occupancy gauge cell is this worker's own — single-writer.
    let m_ring = obs::registry().gauge(
        "persiq_async_ring_occupancy",
        "Operations waiting in the combiner submission ring",
    );
    let m_flush_us = obs::registry().histogram(
        "persiq_async_flush_latency_us",
        "Microseconds from an explicit flush's oldest admitted op to its group psync",
    );
    // The shard-plan epoch this combiner last operated under: re-sharding
    // flips are observed between batches (the queue's own dispatch pins
    // the live plan per op; this is the combiner-side observation point
    // for stats and exec closures). `plan_epoch()` is a plain atomic
    // hint — with epoch-pinned plan access there is no lock anywhere on
    // this loop, so a concurrent `resize` never stalls a combiner.
    let mut plan_epoch = q.plan_epoch();

    let outcome = run_guarded(|| {
        PersistentQueue::attach(q.as_ref(), tid);
        loop {
            let stopping = shared.stop.load(Ordering::Acquire);
            let mut progressed = false;
            let ep = q.plan_epoch();
            if ep != plan_epoch {
                plan_epoch = ep;
                shared.stats.plan_flips.fetch_add(1, Ordering::Relaxed);
            }

            m_ring.set(tid, shared.ring.occupancy() as i64);

            // Admit work while the in-flight window has room.
            while parked_enq.len() + parked_deq.len() + parked_exec.len() < shared.cfg.depth {
                let Some(op) = shared.ring.pop() else { break };
                progressed = true;
                if oldest.is_none() {
                    oldest = Some(Instant::now());
                }
                obs::trace::future_stage(tid, q.topology().vtime(tid), "execute", op.trace_id());
                match op {
                    AsyncOp::Enq { value, slot } => {
                        // Park BEFORE executing: a crash unwinding out of
                        // enqueue() must find the slot in the parked list
                        // so the fail path below resolves it.
                        parked_enq.push(slot);
                        if let Err(e) = q.enqueue(tid, value) {
                            let slot = parked_enq.pop().expect("just pushed");
                            shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                            slot.fail(AsyncError::Queue(e));
                        }
                    }
                    AsyncOp::Deq { tag, slot } => {
                        parked_deq.push(slot);
                        match q.dequeue(tid) {
                            Ok(Some(v)) => {
                                parked_deq.last().expect("just pushed").stage(v + 1);
                                // Executed (consumption staged, durability
                                // pending): the harness's checker marker.
                                if let Some(h) = &exec_hook {
                                    h(tag, v);
                                }
                            }
                            Ok(None) => {
                                // EMPTY executions fire the marker too:
                                // the checker matches markers to open
                                // invokes positionally (oldest first), so
                                // an unmarked EMPTY would silently absorb
                                // a later value-carrying op's mark and
                                // fabricate a loss. EMPTYs resolve
                                // immediately, so their marked invoke
                                // always gets its response and never
                                // enters the pending budget.
                                if let Some(h) = &exec_hook {
                                    h(tag, 0);
                                }
                                // EMPTY has no persistent effect: resolve
                                // immediately (stage() default 0 = None).
                                let slot = parked_deq.pop().expect("just pushed");
                                slot.complete();
                                shared.stats.empties.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => {
                                let slot = parked_deq.pop().expect("just pushed");
                                shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                                slot.fail(AsyncError::Queue(e));
                            }
                        }
                    }
                    AsyncOp::Exec { f, slot } => {
                        parked_exec.push(slot);
                        let (v, pools) = f(q.topology(), tid, q.plan_epoch());
                        parked_exec.last().expect("just pushed").stage(v);
                        exec_pools |= pools;
                    }
                }
                // The inner queue may have auto-flushed on its batch
                // boundary: harvest what that made durable.
                harvest(
                    &shared,
                    tid,
                    &mut parked_enq,
                    &mut parked_deq,
                    &mut parked_exec,
                    &mut exec_pools,
                    &mut oldest,
                    0,
                );
            }

            let inflight = parked_enq.len() + parked_deq.len() + parked_exec.len();
            if inflight > 0 {
                let deadline_hit = oldest
                    .is_some_and(|t| t.elapsed() >= Duration::from_micros(shared.cfg.flush_us));
                if inflight >= shared.cfg.depth || deadline_hit || stopping {
                    if inflight >= shared.cfg.depth {
                        shared.stats.depth_flushes.fetch_add(1, Ordering::Relaxed);
                    } else if deadline_hit {
                        shared.stats.deadline_flushes.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(t) = oldest {
                        m_flush_us.record(tid, t.elapsed().as_micros() as u64);
                    }
                    // The queue flush psyncs the pools its batches
                    // touched; Exec pwbs on OTHER pools need their own
                    // drain before their futures may resolve.
                    let psynced = q.flush(tid);
                    let remaining = exec_pools & !psynced;
                    if remaining != 0 {
                        // Exec closures are acknowledgement work (the
                        // broker's DONE marks): their stray-pool drains
                        // attribute to BrokerAck, not Op.
                        let _site = obs::enter_site(ObsSite::BrokerAck);
                        for p in 0..q.topology().len() {
                            if remaining & (1 << p) != 0 {
                                q.topology().pool(p).psync(tid);
                            }
                        }
                    }
                    exec_pools = 0;
                    // flush() returned normally: everything parked is
                    // durable. (A crash inside flush/psync unwinds past
                    // this point — the fail path owns the slots then.)
                    harvest(
                        &shared,
                        tid,
                        &mut parked_enq,
                        &mut parked_deq,
                        &mut parked_exec,
                        &mut exec_pools,
                        &mut oldest,
                        u64::MAX,
                    );
                    progressed = true;
                }
            }

            if stopping
                && parked_enq.is_empty()
                && parked_deq.is_empty()
                && parked_exec.is_empty()
            {
                // Ring drained by the admission loop above (it broke on
                // empty, or we'd still have in-flight ops). One more pop
                // closes the race with a final submission: callers are
                // documented to stop submitting before stop(), so an op
                // that slips in here is failed Closed, never dropped.
                match shared.ring.pop() {
                    None => break,
                    Some(op) => {
                        shared.stats.failed.fetch_add(1, Ordering::Relaxed);
                        op.fail(AsyncError::Closed);
                        continue;
                    }
                }
            }
            if !progressed {
                // Idle, or waiting out the deadline: sleep a small slice.
                let us = if oldest.is_some() {
                    (shared.cfg.flush_us / 8).clamp(1, 50)
                } else {
                    20
                };
                std::thread::sleep(Duration::from_micros(us));
            }
        }
        PersistentQueue::detach(q.as_ref(), tid);
    });

    if outcome.crashed() {
        shared.crashed.store(true, Ordering::Release);
        // Seal the layer, fail everything in flight, drain the ring.
        shared.seal();
        let n = parked_enq.len() + parked_deq.len() + parked_exec.len();
        shared.stats.failed.fetch_add(n as u64, Ordering::Relaxed);
        // Only these dequeues can have consumed an item without returning
        // it (ring-drained ops below never executed) — the tight loss
        // budget the durability property test checks against.
        shared
            .stats
            .crash_inflight_deqs
            .fetch_add(parked_deq.len() as u64, Ordering::Relaxed);
        for slot in parked_enq.drain(..) {
            slot.fail(AsyncError::Crashed);
        }
        for slot in parked_deq.drain(..) {
            slot.fail(AsyncError::Crashed);
        }
        for slot in parked_exec.drain(..) {
            slot.fail(AsyncError::Crashed);
        }
        shared.drain_fail(AsyncError::Crashed);
    }
}

/// Complete every parked future whose durability point has retired.
/// `exec_ready_mask == u64::MAX` means "an explicit flush just returned"
/// (exec futures resolve too); `0` means "only harvest what the queue's
/// own auto-flush realized" (exec pwbs may still be pending on pools the
/// auto-flush did not drain, so exec slots stay parked).
#[allow(clippy::too_many_arguments)]
fn harvest<Q: Shardable>(
    shared: &Shared<Q>,
    tid: usize,
    parked_enq: &mut Vec<Arc<CompletionSlot>>,
    parked_deq: &mut Vec<Arc<CompletionSlot>>,
    parked_exec: &mut Vec<Arc<CompletionSlot>>,
    exec_pools: &mut u64,
    oldest: &mut Option<Instant>,
    exec_ready_mask: u64,
) {
    let trace_on = obs::trace::enabled();
    let now = || shared.queue.topology().vtime(tid);
    let (pe, pd) = shared.queue.pending_ops(tid);
    if pe == 0 && !parked_enq.is_empty() {
        for slot in parked_enq.drain(..) {
            if trace_on {
                obs::trace::future_stage(tid, now(), "durable", slot.id);
            }
            slot.complete();
            if trace_on {
                obs::trace::future_stage(tid, now(), "resolve", slot.id);
            }
            shared.stats.enq_done.fetch_add(1, Ordering::Relaxed);
        }
    }
    if pd == 0 && !parked_deq.is_empty() {
        let hook = shared.deq_resolved_hook.lock().unwrap().clone();
        for slot in parked_deq.drain(..) {
            // Durability point reached: let the observer act BEFORE the
            // caller can see the resolution (the broker starts the job
            // lease here, closing the die-between-await-and-resolve
            // window).
            if let (Some(h), enc) = (&hook, slot.staged()) {
                if enc != 0 {
                    h(enc - 1);
                }
            }
            if trace_on {
                obs::trace::future_stage(tid, now(), "durable", slot.id);
            }
            slot.complete();
            if trace_on {
                obs::trace::future_stage(tid, now(), "resolve", slot.id);
            }
            shared.stats.deq_done.fetch_add(1, Ordering::Relaxed);
        }
    }
    if exec_ready_mask == u64::MAX && !parked_exec.is_empty() {
        debug_assert_eq!(*exec_pools, 0, "explicit flush must have drained exec pools");
        for slot in parked_exec.drain(..) {
            if trace_on {
                obs::trace::future_stage(tid, now(), "durable", slot.id);
            }
            slot.complete();
            if trace_on {
                obs::trace::future_stage(tid, now(), "resolve", slot.id);
            }
            shared.stats.exec_done.fetch_add(1, Ordering::Relaxed);
        }
    }
    if parked_enq.is_empty() && parked_deq.is_empty() && parked_exec.is_empty() {
        *oldest = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot_op(v: u64) -> (AsyncOp, Arc<CompletionSlot>) {
        let slot = CompletionSlot::new();
        (AsyncOp::Enq { value: v, slot: Arc::clone(&slot) }, slot)
    }

    #[test]
    fn ring_push_pop_fifo() {
        let r = OpRing::new(8);
        for v in 0..5u64 {
            assert!(r.push(slot_op(v).0).is_ok());
        }
        for v in 0..5u64 {
            match r.pop() {
                Some(AsyncOp::Enq { value, .. }) => assert_eq!(value, v),
                other => panic!("expected Enq({v}), got {:?}", other.is_some()),
            }
        }
        assert!(r.pop().is_none());
    }

    #[test]
    fn ring_full_returns_op() {
        let r = OpRing::new(2);
        assert!(r.push(slot_op(0).0).is_ok());
        assert!(r.push(slot_op(1).0).is_ok());
        match r.push(slot_op(2).0) {
            Err(AsyncOp::Enq { value, .. }) => assert_eq!(value, 2, "full ring hands the op back"),
            _ => panic!("push into a full ring must fail"),
        }
        // Popping frees a cell; the next push succeeds.
        assert!(r.pop().is_some());
        assert!(r.push(slot_op(3).0).is_ok());
    }

    #[test]
    fn ring_mpmc_no_loss_no_dup() {
        let r = Arc::new(OpRing::new(64));
        let total = 4 * 2000usize;
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let r = Arc::clone(&r);
            producers.push(std::thread::spawn(move || {
                for i in 0..2000u64 {
                    let mut op = slot_op(p * 2000 + i).0;
                    loop {
                        match r.push(op) {
                            Ok(()) => break,
                            Err(o) => {
                                op = o;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        // Shared popped counter so both consumers agree on termination.
        let popped = Arc::new(AtomicUsize::new(0));
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let r = Arc::clone(&r);
            let popped = Arc::clone(&popped);
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while popped.load(Ordering::Relaxed) < total {
                    if let Some(AsyncOp::Enq { value, .. }) = r.pop() {
                        popped.fetch_add(1, Ordering::Relaxed);
                        got.push(value);
                    } else {
                        std::thread::yield_now();
                    }
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        let mut all: Vec<u64> = Vec::new();
        for c in consumers {
            all.extend(c.join().unwrap());
        }
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate op popped");
        assert_eq!(all.len(), total, "op lost in the ring");
    }
}
