//! `asyncq` — an executor-agnostic **async completion layer** over the
//! sharded/batched queue: `enqueue_async` / `dequeue_async` return
//! futures that resolve at the operation's *durability point* instead of
//! blocking the caller through the batch window.
//!
//! ## The durability-gated completion contract
//!
//! The sharded layer's group commit (PRs 1–2) amortizes persistence to
//! `1/B` psyncs per enqueue and `1/K` per dequeue, but under the **sync**
//! API an operation *returns before it is durable* — buffered durable
//! linearizability, with the crash-time trailing-loss / trailing-
//! redelivery windows the checker must explicitly excuse. This layer
//! inverts the tradeoff:
//!
//! > **A future never resolves successfully before the `psync` covering
//! > its operation has retired.**
//!
//! * [`AsyncQueue::enqueue_async`] resolves `Ok(())` only once the
//!   enqueue's batch flush retired — the item is durably in the queue and
//!   cannot be lost by any later crash.
//! * [`AsyncQueue::dequeue_async`] resolves `Ok(Some(v))` only once the
//!   consumption's dequeue-log flush retired — recovery will never
//!   redeliver `v`. (`Ok(None)` — EMPTY — has no persistent effect and
//!   resolves immediately.)
//! * A crash before the flush fails the future with
//!   [`AsyncError::Crashed`]: the caller learns the op's durability is
//!   unknown, exactly like a database client whose commit ACK never
//!   arrived.
//!
//! The resolved-implies-durable direction is **by construction**: the
//! only code path that marks a future READY runs strictly after the
//! flush call returned normally, and a simulated crash *unwinds* out of
//! the flush (see [`crate::pmem::CrashSignal`]), so a crashed flush can
//! never reach the wake. Consequently the relaxed-FIFO checker needs
//! **zero** trailing-loss / trailing-redelivery allowance for histories
//! recorded at async-resolution boundaries — the async API restores
//! strict durable linearizability (up to relaxed-FIFO order) *at the
//! same 1/B + 1/K psync cost* (`tests/prop_async_durability.rs` enforces
//! both claims).
//!
//! Flight-recorder note ([`crate::obs::flight`]): this layer records no
//! events of its own. The flusher workers drive the inner sharded
//! queue's `enqueue`/`dequeue`/`flush`, so each combined operation's
//! advisory events and the certifying `BatchSeal`/`DeqSeal` land in the
//! *flusher thread's* ring via the sharded hooks — post-crash forensics
//! sees async traffic attributed to the threads that made it durable.
//!
//! ## Architecture: flat combining, not per-caller batches
//!
//! Callers do not touch the queue. They publish operations into a
//! bounded lock-free ring ([`flusher::OpRing`]) and immediately receive
//! a future; [`flusher::Flusher`] worker threads — each owning one
//! sharded-queue thread slot — pop operations, execute them against
//! their own batch logs, and complete the whole in-flight window when
//! the group `psync` retires (flat combining à la Rusanovsky et al.;
//! see [`flusher`] for why the persistency model forces this shape).
//! Flushes are **depth-triggered** ([`AsyncCfg::depth`] in-flight ops),
//! **deadline-triggered** ([`AsyncCfg::flush_us`] µs latency bound), or
//! implicit when the inner queue's own batch boundary auto-flushes.
//! When the ring is full the submission path spins — bounded in-flight
//! work is the backpressure story, surfaced in
//! [`AsyncStats::backpressure`].
//!
//! ## Knobs
//!
//! | knob | CLI | meaning |
//! |---|---|---|
//! | [`AsyncCfg::flush_us`] | `--flush-us` | deadline: max µs an admitted op waits for its flush |
//! | [`AsyncCfg::depth`] | `--async-depth` | per-flusher in-flight window (depth flush trigger + backpressure bound) |
//! | [`AsyncCfg::flushers`] | `--flushers` | combiner worker threads (each needs its own queue tid) |

pub mod flusher;
pub mod future;

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crossbeam_utils::CachePadded;

use crate::pmem::Topology;
use crate::queues::perlcrq::PerLcrq;
use crate::queues::sharded::{Shardable, ShardedQueue};
use crate::queues::{QueueError, MAX_ITEM};

pub use flusher::Flusher;
pub use future::{block_on, AsyncError, DeqFuture, EnqFuture, ExecFuture};

use self::flusher::{AsyncOp, OpRing};
use self::future::CompletionSlot;

/// Upper bound on [`AsyncCfg::depth`].
pub const MAX_ASYNC_DEPTH: usize = 4096;

/// Async-layer configuration (see module docs for the knob semantics).
#[derive(Clone, Debug)]
pub struct AsyncCfg {
    /// Deadline flush trigger: maximum microseconds an admitted operation
    /// waits before its window is flushed.
    pub flush_us: u64,
    /// Per-flusher in-flight window: admitted-but-not-yet-durable ops
    /// before a depth flush fires; also bounds total outstanding work
    /// (backpressure).
    pub depth: usize,
    /// Number of combiner worker threads. Each occupies one queue thread
    /// slot starting at the `first_tid` passed to
    /// [`AsyncQueue::spawn_flusher`].
    pub flushers: usize,
}

impl Default for AsyncCfg {
    fn default() -> Self {
        Self { flush_us: 50, depth: 32, flushers: 1 }
    }
}

impl AsyncCfg {
    /// Validate the configuration (CLI and constructors surface the
    /// error; see [`QueueError::BadConfig`]).
    pub fn validate(&self) -> Result<(), QueueError> {
        if self.depth == 0 || self.depth > MAX_ASYNC_DEPTH {
            return Err(QueueError::BadConfig("async depth must be in 1..=4096"));
        }
        if self.flushers == 0 || self.flushers > crate::pmem::MAX_THREADS {
            return Err(QueueError::BadConfig("flushers must be in 1..=MAX_THREADS"));
        }
        if self.flush_us == 0 {
            return Err(QueueError::BadConfig("flush-us must be nonzero"));
        }
        Ok(())
    }
}

/// Counters exported by [`AsyncQueue::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AsyncStats {
    /// Operations accepted into the submission ring.
    pub submitted: u64,
    /// Enqueue futures resolved Ok (durably enqueued).
    pub enq_done: u64,
    /// Dequeue futures resolved with a value (durably consumed).
    pub deq_done: u64,
    /// Exec futures resolved.
    pub exec_done: u64,
    /// Dequeue futures resolved EMPTY.
    pub empties: u64,
    /// Futures resolved with an error (crash, close, queue rejection).
    pub failed: u64,
    /// Flushes fired by the depth trigger.
    pub depth_flushes: u64,
    /// Flushes fired by the deadline trigger.
    pub deadline_flushes: u64,
    /// Submission spins against a full ring (backpressure events).
    pub backpressure: u64,
    /// Dequeues that had EXECUTED (were admitted and ran against the
    /// queue, possibly consuming an item) but whose flush never retired
    /// when a crash failed them. This — not the total failed-dequeue
    /// count, which includes ring-drained ops that never touched the
    /// queue — bounds how many values an async crash can consume without
    /// returning them (`tests/prop_async_durability.rs` uses it as its
    /// loss budget; the checker derives the same bound itself from the
    /// `DeqExecuted` markers the harness records via
    /// [`AsyncQueue::set_deq_executed_hook`]).
    pub crash_inflight_deqs: u64,
    /// Shard-plan flips (`ShardedQueue::resize`) the combiners observed
    /// between batches — each one means subsequent ops stripe over a new
    /// plan generation.
    pub plan_flips: u64,
}

/// Volatile async-layer counters. Padded per counter: `submitted` /
/// `backpressure` are bumped by every submitting thread while
/// `enq_done` / `deq_done` / `exec_done` are bumped by the combiners —
/// packed into one struct these RMWs would all contend on one or two
/// cache lines (the same false-sharing audit that padded the sharded
/// layer's `ResizeCells`; see `pmem/stats.rs` module docs).
#[derive(Default)]
pub(crate) struct StatCells {
    pub submitted: CachePadded<AtomicU64>,
    pub enq_done: CachePadded<AtomicU64>,
    pub deq_done: CachePadded<AtomicU64>,
    pub exec_done: CachePadded<AtomicU64>,
    pub empties: CachePadded<AtomicU64>,
    pub failed: CachePadded<AtomicU64>,
    pub depth_flushes: CachePadded<AtomicU64>,
    pub deadline_flushes: CachePadded<AtomicU64>,
    pub backpressure: CachePadded<AtomicU64>,
    pub crash_inflight_deqs: CachePadded<AtomicU64>,
    pub plan_flips: CachePadded<AtomicU64>,
}

/// Observer invoked with a payload value at an async-layer event (e.g.
/// the broker's lease start at resolution). Kept type-erased so the
/// broker/harness can hook in without the queue layer depending on them.
pub type ValueHook = Arc<dyn Fn(u64) + Send + Sync>;
/// Observer invoked with `(tag, value)` when a tagged dequeue executes
/// (the harness records the checker's `DeqExecuted` marker, attributing
/// it to the submitting thread via the tag).
pub type TaggedHook = Arc<dyn Fn(u64, u64) + Send + Sync>;

/// State shared between caller handles and flusher workers.
pub(crate) struct Shared<Q: Shardable> {
    pub queue: Arc<ShardedQueue<Q>>,
    pub ring: OpRing,
    pub cfg: AsyncCfg,
    /// No new submissions accepted (set by crash or shutdown).
    pub closed: AtomicBool,
    /// Graceful-shutdown request for the workers.
    pub stop: Arc<AtomicBool>,
    /// A worker observed a simulated crash.
    pub crashed: Arc<AtomicBool>,
    /// Callers currently inside the submission critical section; `seal`
    /// waits them out so no op can slip in behind the closing drain.
    pub pushers: AtomicUsize,
    pub stats: StatCells,
    /// Invoked with each dequeued value at its **durability point**,
    /// strictly before the future resolves: the broker starts the job
    /// lease here (lease-at-resolution — a worker dying between the
    /// await and `resolve_take` leaves a leased, reapable job instead of
    /// a stranded one). Set before spawning flushers.
    pub deq_resolved_hook: Mutex<Option<ValueHook>>,
    /// Invoked with `(tag, value)` when a dequeue EXECUTES against the
    /// queue (consumption staged, durability pending): the async harness
    /// records the checker's `DeqExecuted` marker here.
    pub deq_executed_hook: Mutex<Option<TaggedHook>>,
}

impl<Q: Shardable> Shared<Q> {
    /// Stop accepting submissions and wait out in-flight pushers. After
    /// this returns, draining the ring observes every op that will ever
    /// be in it. SeqCst on both the flag store and the counter loads:
    /// this is a Dekker-style handshake with [`AsyncQueue::submit`]'s
    /// increment-then-check — either the sealer sees the pusher's
    /// increment (and waits it out) or the pusher sees `closed` (and
    /// backs off); weaker orderings would allow both to miss.
    pub fn seal(&self) {
        self.closed.store(true, Ordering::SeqCst);
        while self.pushers.load(Ordering::SeqCst) != 0 {
            std::thread::yield_now();
        }
    }

    /// Fail every op still queued in the ring. Call after [`Shared::seal`].
    pub fn drain_fail(&self, err: AsyncError) {
        while let Some(op) = self.ring.pop() {
            self.stats.failed.fetch_add(1, Ordering::Relaxed);
            op.fail(err.clone());
        }
    }
}

/// The async completion layer. Cheap to clone (an `Arc` handle); hand a
/// clone to every submitting thread. See module docs for the contract.
pub struct AsyncQueue<Q: Shardable = PerLcrq> {
    shared: Arc<Shared<Q>>,
}

impl<Q: Shardable> Clone for AsyncQueue<Q> {
    fn clone(&self) -> Self {
        Self { shared: Arc::clone(&self.shared) }
    }
}

impl<Q: Shardable + 'static> AsyncQueue<Q> {
    /// Wrap a sharded queue. The queue's own `batch`/`batch_deq` sizes
    /// stay in force (auto-flush on batch boundaries); the async layer
    /// adds the depth/deadline triggers on top.
    pub fn new(queue: Arc<ShardedQueue<Q>>, cfg: AsyncCfg) -> Result<Self, QueueError> {
        cfg.validate()?;
        let ring = OpRing::new((cfg.depth * cfg.flushers * 2).max(64));
        Ok(Self {
            shared: Arc::new(Shared {
                queue,
                ring,
                cfg,
                closed: AtomicBool::new(false),
                stop: Arc::new(AtomicBool::new(false)),
                crashed: Arc::new(AtomicBool::new(false)),
                pushers: AtomicUsize::new(0),
                stats: StatCells::default(),
                deq_resolved_hook: Mutex::new(None),
                deq_executed_hook: Mutex::new(None),
            }),
        })
    }

    /// Install the dequeue-resolution observer (see
    /// [`Shared::deq_resolved_hook`]). Call before spawning flushers.
    pub fn set_deq_resolved_hook(&self, hook: ValueHook) {
        *self.shared.deq_resolved_hook.lock().unwrap() = Some(hook);
    }

    /// Install the dequeue-executed observer (see
    /// [`Shared::deq_executed_hook`]). Call before spawning flushers.
    pub fn set_deq_executed_hook(&self, hook: TaggedHook) {
        *self.shared.deq_executed_hook.lock().unwrap() = Some(hook);
    }

    /// Spawn the configured number of flusher workers on queue thread
    /// slots `first_tid .. first_tid + cfg.flushers`. The usual tid
    /// exclusivity contract applies: those slots must not be used by any
    /// other live thread. Returns the handle that stops/joins them.
    pub fn spawn_flusher(&self, first_tid: usize) -> Flusher {
        Flusher::spawn(&self.shared, first_tid)
    }

    /// Submit an asynchronous enqueue. The future resolves `Ok(())` only
    /// after the item is durably in the queue (see module docs). Spins
    /// (backpressure) while the in-flight window is full.
    pub fn enqueue_async(&self, value: u64) -> EnqFuture {
        let slot = CompletionSlot::new();
        if value >= MAX_ITEM {
            self.shared.stats.failed.fetch_add(1, Ordering::Relaxed);
            slot.fail(AsyncError::Queue(QueueError::ItemOutOfRange(value)));
            return EnqFuture { slot };
        }
        self.submit(AsyncOp::Enq { value, slot: Arc::clone(&slot) });
        EnqFuture { slot }
    }

    /// Submit an asynchronous dequeue. Resolves `Ok(Some(v))` once the
    /// consumption is durable, `Ok(None)` immediately on EMPTY.
    pub fn dequeue_async(&self) -> DeqFuture {
        self.dequeue_async_tagged(0)
    }

    /// [`AsyncQueue::dequeue_async`] with a caller correlation `tag`
    /// handed to the executed-hook (see [`TaggedHook`]); the async
    /// harness passes the submitting tid so checker markers attribute to
    /// the right thread's open invokes.
    pub fn dequeue_async_tagged(&self, tag: u64) -> DeqFuture {
        let slot = CompletionSlot::new();
        self.submit(AsyncOp::Deq { tag, slot: Arc::clone(&slot) });
        DeqFuture { slot }
    }

    /// Flat-combining escape hatch: run `f` on a flusher's thread slot
    /// against the queue's topology. `f` receives `(topology, tid,
    /// plan_epoch)` — the shard-plan epoch in force when the closure
    /// executes, so combiner-side logic can observe re-sharding
    /// transitions — and returns `(result, pool_mask)`; the future
    /// resolves with `result` only after every pool in `pool_mask` has
    /// been `psync`ed by that worker — i.e. after any `pwb`s `f` issued
    /// there have retired. The broker's `ack_async` rides this to
    /// group-commit DONE-marking psyncs with the queue's flush.
    pub fn exec_async(
        &self,
        f: impl FnOnce(&Topology, usize, u64) -> (u64, u64) + Send + 'static,
    ) -> ExecFuture {
        let slot = CompletionSlot::new();
        self.submit(AsyncOp::Exec { f: Box::new(f), slot: Arc::clone(&slot) });
        ExecFuture { slot }
    }

    fn submit(&self, op: AsyncOp) {
        let sh = &*self.shared;
        let id = op.trace_id();
        // Increment-then-check pairs with Shared::seal's set-then-wait
        // (SeqCst on both sides — see seal's comment).
        sh.pushers.fetch_add(1, Ordering::SeqCst);
        let bail = |op: AsyncOp| {
            sh.pushers.fetch_sub(1, Ordering::SeqCst);
            sh.stats.failed.fetch_add(1, Ordering::Relaxed);
            op.fail(if sh.crashed.load(Ordering::Acquire) {
                AsyncError::Crashed
            } else {
                AsyncError::Closed
            });
        };
        if sh.closed.load(Ordering::SeqCst) {
            bail(op);
            return;
        }
        let mut op = op;
        loop {
            match sh.ring.push(op) {
                Ok(()) => break,
                Err(returned) => {
                    op = returned;
                    sh.stats.backpressure.fetch_add(1, Ordering::Relaxed);
                    // Backpressure spin: keep checking closed so a dead
                    // flusher (full ring forever) cannot wedge callers.
                    if sh.closed.load(Ordering::SeqCst) {
                        bail(op);
                        return;
                    }
                    std::thread::yield_now();
                }
            }
        }
        sh.stats.submitted.fetch_add(1, Ordering::Relaxed);
        sh.pushers.fetch_sub(1, Ordering::SeqCst);
        if crate::obs::trace::enabled() {
            // Submitters have no queue tid; ring 0 collects their events
            // (rings are mutexed, so cross-thread emission is safe).
            crate::obs::trace::future_stage(0, sh.queue.topology().max_vtime(), "submit", id);
        }
    }

    /// Refuse new submissions and fail everything still queued (the
    /// flusher keeps running until stopped; already-admitted ops still
    /// complete normally). The crash path does this automatically.
    pub fn close(&self) {
        self.shared.seal();
        self.shared.drain_fail(AsyncError::Closed);
    }

    /// Has the layer been sealed (crash or [`AsyncQueue::close`])?
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// Did a flusher worker observe a simulated crash?
    pub fn crashed(&self) -> bool {
        self.shared.crashed.load(Ordering::Acquire)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> AsyncStats {
        let s = &self.shared.stats;
        AsyncStats {
            submitted: s.submitted.load(Ordering::Relaxed),
            enq_done: s.enq_done.load(Ordering::Relaxed),
            deq_done: s.deq_done.load(Ordering::Relaxed),
            exec_done: s.exec_done.load(Ordering::Relaxed),
            empties: s.empties.load(Ordering::Relaxed),
            failed: s.failed.load(Ordering::Relaxed),
            depth_flushes: s.depth_flushes.load(Ordering::Relaxed),
            deadline_flushes: s.deadline_flushes.load(Ordering::Relaxed),
            backpressure: s.backpressure.load(Ordering::Relaxed),
            crash_inflight_deqs: s.crash_inflight_deqs.load(Ordering::Relaxed),
            plan_flips: s.plan_flips.load(Ordering::Relaxed),
        }
    }

    /// Registry-style metric families from [`AsyncQueue::stats`]. (The
    /// live ring-occupancy gauge and flush-latency histogram live in the
    /// global [`crate::obs::registry`], updated by the combiner workers.)
    pub fn metric_families(&self) -> Vec<crate::obs::Family> {
        use crate::obs::{Family, Kind, Sample};
        let s = self.stats();
        let c = |name: &str, help: &str, v: u64| {
            Family::scalar(name, help, Kind::Counter, vec![Sample::plain(v as f64)])
        };
        vec![
            c(
                "persiq_async_submitted_total",
                "Operations accepted into the submission ring",
                s.submitted,
            ),
            Family::scalar(
                "persiq_async_resolved_total",
                "Futures resolved successfully, by kind",
                Kind::Counter,
                vec![
                    Sample::labelled("kind", "enq", s.enq_done as f64),
                    Sample::labelled("kind", "deq", s.deq_done as f64),
                    Sample::labelled("kind", "exec", s.exec_done as f64),
                    Sample::labelled("kind", "empty", s.empties as f64),
                ],
            ),
            c(
                "persiq_async_failed_total",
                "Futures resolved with an error (crash, close, queue rejection)",
                s.failed,
            ),
            Family::scalar(
                "persiq_async_flushes_total",
                "Explicit group flushes by trigger",
                Kind::Counter,
                vec![
                    Sample::labelled("trigger", "depth", s.depth_flushes as f64),
                    Sample::labelled("trigger", "deadline", s.deadline_flushes as f64),
                ],
            ),
            c(
                "persiq_async_backpressure_total",
                "Submission spins against a full ring",
                s.backpressure,
            ),
            c(
                "persiq_async_plan_flips_total",
                "Shard-plan flips observed by the combiners",
                s.plan_flips,
            ),
        ]
    }

    /// The active shard-plan epoch of the wrapped queue.
    pub fn plan_epoch(&self) -> u64 {
        self.shared.queue.plan_epoch()
    }

    /// The wrapped sharded queue.
    pub fn queue(&self) -> &Arc<ShardedQueue<Q>> {
        &self.shared.queue
    }

    /// The configuration in force.
    pub fn cfg(&self) -> &AsyncCfg {
        &self.shared.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig, PmemPool};
    use crate::queues::{ConcurrentQueue, PersistentQueue, QueueConfig};
    use crate::util::rng::Xoshiro256;

    /// Huge deadline/depth: only explicit boundaries (inner batch, crash)
    /// can resolve futures — what the gating tests need.
    fn lazy_cfg() -> AsyncCfg {
        AsyncCfg { flush_us: 10_000_000, depth: MAX_ASYNC_DEPTH, flushers: 1 }
    }

    fn mk(
        shards: usize,
        batch: usize,
        batch_deq: usize,
        acfg: AsyncCfg,
    ) -> (Arc<PmemPool>, Arc<ShardedQueue>, AsyncQueue, Flusher) {
        let topo = crate::pmem::Topology::single(PmemConfig {
            capacity_words: 1 << 22,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 5,
        });
        let cfg = QueueConfig { shards, batch, batch_deq, ring_size: 64, ..Default::default() };
        // tids: 0..4 for test callers, 4.. for the flusher workers.
        let q = Arc::new(ShardedQueue::new_perlcrq(&topo, 4 + acfg.flushers, cfg).unwrap());
        let aq = AsyncQueue::new(Arc::clone(&q), acfg).unwrap();
        let fl = aq.spawn_flusher(4);
        (Arc::clone(topo.primary()), q, aq, fl)
    }

    fn settle() {
        std::thread::sleep(std::time::Duration::from_millis(40));
    }

    #[test]
    fn enq_futures_gate_on_batch_flush() {
        let (_p, _q, aq, fl) = mk(2, 4, 1, lazy_cfg());
        let early: Vec<EnqFuture> = (0..3).map(|v| aq.enqueue_async(v)).collect();
        settle();
        for (i, f) in early.iter().enumerate() {
            assert!(
                !f.is_resolved(),
                "future {i} resolved before its batch's psync (3 < batch of 4)"
            );
        }
        // 4th enqueue fills the batch: the inner auto-flush retires the
        // psync and every parked future resolves.
        let last = aq.enqueue_async(3);
        assert_eq!(last.wait(), Ok(()));
        for f in early {
            assert_eq!(f.wait(), Ok(()));
        }
        assert!(aq.stats().enq_done >= 4);
        fl.stop();
    }

    #[test]
    fn depth_trigger_flushes_before_batch_boundary() {
        let acfg = AsyncCfg { depth: 2, ..lazy_cfg() };
        let (_p, _q, aq, fl) = mk(2, 8, 1, acfg);
        // batch = 8 would hold these volatile; depth = 2 must flush.
        let a = aq.enqueue_async(1);
        let b = aq.enqueue_async(2);
        assert_eq!(a.wait(), Ok(()));
        assert_eq!(b.wait(), Ok(()));
        assert!(aq.stats().depth_flushes >= 1);
        fl.stop();
    }

    #[test]
    fn deadline_trigger_flushes_trickle_traffic() {
        let acfg = AsyncCfg { flush_us: 500, depth: MAX_ASYNC_DEPTH, flushers: 1 };
        let (_p, _q, aq, fl) = mk(2, 8, 1, acfg);
        let f = aq.enqueue_async(7);
        assert_eq!(f.wait(), Ok(()), "deadline flush must resolve a lone op");
        assert!(aq.stats().deadline_flushes >= 1);
        fl.stop();
    }

    #[test]
    fn deq_futures_gate_on_dequeue_log_flush() {
        let (_p, q, aq, fl) = mk(1, 1, 2, lazy_cfg());
        // Per-op durable enqueues (batch = 1) so only the dequeue side
        // gates.
        for v in 0..4u64 {
            aq.enqueue_async(v).wait().unwrap();
        }
        let d1 = aq.dequeue_async();
        settle();
        assert!(!d1.is_resolved(), "first dequeue resolved before its log flush (K = 2)");
        let d2 = aq.dequeue_async(); // 2nd seals the dequeue batch
        assert_eq!(d2.wait(), Ok(Some(1)));
        assert_eq!(d1.wait(), Ok(Some(0)));
        fl.stop();
        // Remaining items still in the queue (sync drain for the check).
        assert_eq!(q.dequeue(0).unwrap(), Some(2));
        assert_eq!(q.dequeue(0).unwrap(), Some(3));
    }

    #[test]
    fn empty_dequeue_resolves_immediately() {
        let (_p, _q, aq, fl) = mk(2, 4, 4, lazy_cfg());
        assert_eq!(aq.dequeue_async().wait(), Ok(None));
        fl.stop();
    }

    #[test]
    fn crash_fails_unflushed_futures_and_seals_the_layer() {
        crate::pmem::crash::install_quiet_crash_hook();
        let (p, q, aq, fl) = mk(2, 4, 1, lazy_cfg());
        let a = aq.enqueue_async(10);
        let b = aq.enqueue_async(11);
        settle();
        assert!(!a.is_resolved() && !b.is_resolved());
        // Arm the crash; the flusher hits it on its next pmem op.
        p.crash_now();
        let c = aq.enqueue_async(12);
        assert_eq!(a.wait(), Err(AsyncError::Crashed));
        assert_eq!(b.wait(), Err(AsyncError::Crashed));
        assert_eq!(c.wait(), Err(AsyncError::Crashed));
        assert!(fl.stop(), "flusher must report the crash");
        assert!(aq.is_closed() && aq.crashed());
        // Post-seal submissions fail fast.
        assert_eq!(aq.enqueue_async(13).wait(), Err(AsyncError::Crashed));
        // Nothing unflushed survives (evict/pending = 0): the failed
        // futures' items are gone — exactly what Crashed promises.
        let mut rng = Xoshiro256::seed_from(9);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn resolved_before_crash_means_durable() {
        crate::pmem::crash::install_quiet_crash_hook();
        let (p, q, aq, fl) = mk(2, 4, 1, lazy_cfg());
        for v in 0..8u64 {
            // Two full batches: every future resolves via auto-flush.
            aq.enqueue_async(v).wait().unwrap();
        }
        p.crash_now();
        let dead = aq.enqueue_async(99);
        assert_eq!(dead.wait(), Err(AsyncError::Crashed));
        fl.stop();
        let mut rng = Xoshiro256::seed_from(10);
        p.crash(&mut rng);
        q.recover(&p);
        let mut got = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<u64>>(), "resolved enqueues must survive");
    }

    #[test]
    fn exec_rides_the_group_psync() {
        let (p, q, aq, fl) = mk(2, 4, 1, AsyncCfg { depth: 2, ..lazy_cfg() });
        let addr = p.alloc_lines(1);
        let f = aq.exec_async(move |topo, tid, plan_epoch| {
            assert_eq!(plan_epoch, 1, "exec closures observe the live plan epoch");
            let pool = topo.pool(0);
            pool.store(tid, addr, 77);
            pool.pwb(tid, addr);
            (1, 1 << 0)
        });
        assert!(aq.enqueue_async(5).wait().is_ok()); // depth 2: exec + enq flush
        assert_eq!(f.wait(), Ok(1));
        fl.stop();
        // The exec's store must be durable now.
        let mut rng = Xoshiro256::seed_from(11);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(p.load(0, addr), 77, "exec pwb must have ridden the group psync");
    }

    #[test]
    fn out_of_range_item_fails_fast() {
        let (_p, _q, aq, fl) = mk(2, 4, 1, lazy_cfg());
        assert_eq!(
            aq.enqueue_async(MAX_ITEM).wait(),
            Err(AsyncError::Queue(QueueError::ItemOutOfRange(MAX_ITEM)))
        );
        fl.stop();
    }

    #[test]
    fn graceful_stop_completes_everything() {
        let (_p, q, aq, fl) = mk(4, 8, 8, lazy_cfg());
        let futs: Vec<EnqFuture> = (0..13).map(|v| aq.enqueue_async(v)).collect();
        // stop() drains the ring and flushes the partial window.
        assert!(!fl.stop(), "clean stop must not report a crash");
        for f in futs {
            assert_eq!(f.wait(), Ok(()));
        }
        let mut got = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            got.push(v);
        }
        got.sort_unstable();
        assert_eq!(got, (0..13).collect::<Vec<u64>>());
    }

    #[test]
    fn bad_async_cfg_rejected() {
        for acfg in [
            AsyncCfg { depth: 0, ..Default::default() },
            AsyncCfg { depth: MAX_ASYNC_DEPTH + 1, ..Default::default() },
            AsyncCfg { flushers: 0, ..Default::default() },
            AsyncCfg { flush_us: 0, ..Default::default() },
        ] {
            assert!(matches!(acfg.validate(), Err(QueueError::BadConfig(_))));
        }
        assert!(AsyncCfg::default().validate().is_ok());
    }
}
