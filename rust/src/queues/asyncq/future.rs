//! Completion slots and futures for the async layer — hand-rolled wakers,
//! no executor dependency.
//!
//! A [`CompletionSlot`] is the single shared cell between a caller-held
//! future and the [`super::flusher`] worker that executes + durably
//! realizes the operation. Its lifecycle is a one-way state machine:
//!
//! ```text
//! PENDING ──(stage value)──▶ PENDING ──(flush psync retired)──▶ READY
//!     └──────────────(crash / close / queue error)────────────▶ FAILED
//! ```
//!
//! The staged value is written while the slot is still PENDING (only the
//! flusher writes it, before publishing); the `Release` store of the state
//! publishes it, the future's `Acquire` load receives it. **The READY
//! transition is the durability gate**: the flusher performs it only after
//! the `psync` covering the operation's batch has retired, so a resolved
//! future is proof of durability — never a promise of it.
//!
//! Waker handling is the standard two-phase registration: `poll` re-checks
//! the state *after* parking its waker so a completion racing the
//! registration can never be lost. [`block_on`] drives any future from a
//! plain thread with a park/unpark waker, which is what the harness, the
//! broker service and the tests use — the layer is executor-agnostic by
//! construction, not by feature flag.

use std::future::Future;
use std::pin::Pin;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Waker};

use crate::queues::QueueError;

/// Why an async operation did not complete.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AsyncError {
    /// A simulated crash interrupted the flusher before this operation's
    /// flush `psync` retired: the op's durability is *unknown* (an
    /// unflushed enqueue may be lost; an unflushed dequeue's item will be
    /// redelivered after recovery). Resubmit after recovery.
    Crashed,
    /// The async layer was shut down before the operation was executed.
    Closed,
    /// The underlying queue rejected the operation.
    Queue(QueueError),
}

impl std::fmt::Display for AsyncError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AsyncError::Crashed => write!(f, "crash before the operation's flush retired"),
            AsyncError::Closed => write!(f, "async layer closed"),
            AsyncError::Queue(e) => write!(f, "queue error: {e}"),
        }
    }
}

impl std::error::Error for AsyncError {}

const PENDING: u8 = 0;
const READY: u8 = 1;
const FAILED: u8 = 2;

/// Shared completion cell — see module docs for the protocol.
pub(crate) struct CompletionSlot {
    state: AtomicU8,
    /// Staged payload; meaning depends on the future type (deq: `value+1`
    /// or 0 for EMPTY; exec: the closure's result; enq: unused).
    value: AtomicU64,
    /// Monotone op id correlating this future's trace events
    /// (submit → execute → durable → resolve) across threads.
    pub(crate) id: u64,
    waiting: Mutex<WaitState>,
}

/// Source of [`CompletionSlot::id`] — process-wide so trace correlation
/// ids never collide across layers.
static NEXT_OP_ID: AtomicU64 = AtomicU64::new(0);

#[derive(Default)]
struct WaitState {
    waker: Option<Waker>,
    err: Option<AsyncError>,
}

impl CompletionSlot {
    pub fn new() -> Arc<Self> {
        Arc::new(Self {
            state: AtomicU8::new(PENDING),
            value: AtomicU64::new(0),
            id: NEXT_OP_ID.fetch_add(1, Ordering::Relaxed),
            waiting: Mutex::new(WaitState::default()),
        })
    }

    /// Write the payload while still PENDING (flusher-only; published by
    /// the later READY store).
    pub fn stage(&self, v: u64) {
        debug_assert_eq!(self.state.load(Ordering::Relaxed), PENDING);
        self.value.store(v, Ordering::Relaxed);
    }

    /// Durability gate passed: publish READY and wake the waiter. Must
    /// only be called after the `psync` covering this op has retired.
    pub fn complete(&self) {
        self.state.store(READY, Ordering::Release);
        self.wake();
    }

    /// Resolve with an error (crash, close, queue rejection).
    pub fn fail(&self, err: AsyncError) {
        {
            let mut w = self.waiting.lock().unwrap();
            w.err = Some(err);
        }
        self.state.store(FAILED, Ordering::Release);
        self.wake();
    }

    fn wake(&self) {
        let waker = self.waiting.lock().unwrap().waker.take();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// Has the op resolved (either way)? Non-blocking observability hook.
    pub fn is_resolved(&self) -> bool {
        self.state.load(Ordering::Acquire) != PENDING
    }

    /// The staged payload (flusher-side read, pre-publication): the
    /// completion hooks use this to observe a dequeue's value at its
    /// durability point, before the READY store hands it to the caller.
    pub fn staged(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    fn take_err(&self) -> AsyncError {
        self.waiting.lock().unwrap().err.clone().unwrap_or(AsyncError::Closed)
    }

    /// Core poll: two-phase waker registration so completion cannot race
    /// past a parking poller.
    fn poll_slot(&self, cx: &mut Context<'_>) -> Poll<Result<u64, AsyncError>> {
        match self.state.load(Ordering::Acquire) {
            READY => return Poll::Ready(Ok(self.value.load(Ordering::Relaxed))),
            FAILED => return Poll::Ready(Err(self.take_err())),
            _ => {}
        }
        {
            let mut w = self.waiting.lock().unwrap();
            w.waker = Some(cx.waker().clone());
        }
        // Re-check: a complete()/fail() between the first load and the
        // registration took the lock after us and saw our waker — or it
        // beat the lock, in which case this load observes the new state.
        match self.state.load(Ordering::Acquire) {
            READY => Poll::Ready(Ok(self.value.load(Ordering::Relaxed))),
            FAILED => Poll::Ready(Err(self.take_err())),
            _ => Poll::Pending,
        }
    }
}

/// Future of an [`super::AsyncQueue::enqueue_async`]: resolves `Ok(())`
/// only after the enqueue's batch flush `psync` retired (the item is
/// durably in the queue), or with the [`AsyncError`] that prevented it.
pub struct EnqFuture {
    pub(crate) slot: Arc<CompletionSlot>,
}

/// Future of an [`super::AsyncQueue::dequeue_async`]: resolves
/// `Ok(Some(v))` only after the consumption's dequeue-log flush retired
/// (the take is durable — recovery will never redeliver `v`), `Ok(None)`
/// for EMPTY (no persistent effect, resolves immediately).
pub struct DeqFuture {
    pub(crate) slot: Arc<CompletionSlot>,
}

/// Future of an [`super::AsyncQueue::exec_async`] combiner closure:
/// resolves with the closure's result after the group `psync` covering
/// the pools it touched retired.
pub struct ExecFuture {
    pub(crate) slot: Arc<CompletionSlot>,
}

impl EnqFuture {
    /// Resolved yet (either way)? Does not consume the future.
    pub fn is_resolved(&self) -> bool {
        self.slot.is_resolved()
    }

    /// Block the current thread until resolution (park/unpark waker).
    pub fn wait(self) -> Result<(), AsyncError> {
        block_on(self)
    }
}

impl DeqFuture {
    pub fn is_resolved(&self) -> bool {
        self.slot.is_resolved()
    }

    pub fn wait(self) -> Result<Option<u64>, AsyncError> {
        block_on(self)
    }
}

impl ExecFuture {
    pub fn is_resolved(&self) -> bool {
        self.slot.is_resolved()
    }

    pub fn wait(self) -> Result<u64, AsyncError> {
        block_on(self)
    }
}

impl Future for EnqFuture {
    type Output = Result<(), AsyncError>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        self.slot.poll_slot(cx).map(|r| r.map(|_| ()))
    }
}

impl Future for DeqFuture {
    type Output = Result<Option<u64>, AsyncError>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        // Deq payload encoding: 0 = EMPTY, v+1 = value (the same
        // "occupied cells hold item + 1" convention as the rings).
        self.slot
            .poll_slot(cx)
            .map(|r| r.map(|enc| if enc == 0 { None } else { Some(enc - 1) }))
    }
}

impl Future for ExecFuture {
    type Output = Result<u64, AsyncError>;

    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Self::Output> {
        self.slot.poll_slot(cx)
    }
}

/// Minimal single-future executor: poll, park until woken, repeat. This is
/// all the harness and broker service need — any real executor's waker
/// works just as well, the layer only ever touches [`std::task::Waker`].
pub fn block_on<F: Future>(fut: F) -> F::Output {
    struct ThreadWaker(std::thread::Thread);
    impl std::task::Wake for ThreadWaker {
        fn wake(self: Arc<Self>) {
            self.0.unpark();
        }
        fn wake_by_ref(self: &Arc<Self>) {
            self.0.unpark();
        }
    }
    let mut fut = std::pin::pin!(fut);
    let waker = Waker::from(Arc::new(ThreadWaker(std::thread::current())));
    let mut cx = Context::from_waker(&waker);
    loop {
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(out) => return out,
            // park() may wake spuriously; the loop re-polls, which is
            // always sound for a correctly implemented future.
            Poll::Pending => std::thread::park(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ready_publishes_staged_value() {
        let slot = CompletionSlot::new();
        slot.stage(41 + 1);
        assert!(!slot.is_resolved());
        slot.complete();
        let f = DeqFuture { slot };
        assert!(f.is_resolved());
        assert_eq!(f.wait(), Ok(Some(41)));
    }

    #[test]
    fn empty_deq_decodes_none() {
        let slot = CompletionSlot::new();
        slot.stage(0);
        slot.complete();
        assert_eq!(DeqFuture { slot }.wait(), Ok(None));
    }

    #[test]
    fn failure_carries_error() {
        let slot = CompletionSlot::new();
        slot.fail(AsyncError::Crashed);
        assert_eq!(EnqFuture { slot }.wait(), Err(AsyncError::Crashed));
        let slot = CompletionSlot::new();
        slot.fail(AsyncError::Queue(QueueError::CapacityExhausted));
        assert_eq!(
            ExecFuture { slot }.wait(),
            Err(AsyncError::Queue(QueueError::CapacityExhausted))
        );
    }

    #[test]
    fn block_on_wakes_across_threads() {
        let slot = CompletionSlot::new();
        let s2 = Arc::clone(&slot);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            s2.stage(7 + 1);
            s2.complete();
        });
        assert_eq!(DeqFuture { slot }.wait(), Ok(Some(7)));
        h.join().unwrap();
    }

    #[test]
    fn completion_racing_registration_is_not_lost() {
        // Hammer the poll-vs-complete race: many iterations of a waiter
        // blocking while another thread completes "immediately".
        for i in 0..200u64 {
            let slot = CompletionSlot::new();
            let s2 = Arc::clone(&slot);
            let h = std::thread::spawn(move || {
                s2.stage(i + 1);
                s2.complete();
            });
            assert_eq!(DeqFuture { slot }.wait(), Ok(Some(i)));
            h.join().unwrap();
        }
    }
}
