//! IQ — the infinite-array queue (paper §3, Algorithm 1 black lines).
//!
//! The queue is an (conceptually infinite) array `Q` initialized to `⊥`,
//! plus two FAI objects `Head` and `Tail`. An enqueuer FAIs `Tail` to claim
//! an index and `GET&SET`s its item into that cell; a dequeuer FAIs `Head`
//! and `GET&SET`s `⊤` into the claimed cell, returning whatever was there.
//! Each cell is touched by at most one enqueuer and one dequeuer.
//!
//! The "infinite" array is a finite arena region here (capacity is a
//! config knob); running past it yields `CapacityExhausted`.
//!
//! ## Cell encoding
//! `⊥ = 0` (fresh NVM), `⊤ = u64::MAX`, item `v` stored as `v + 1`.

use std::sync::Arc;

use super::{ConcurrentQueue, QueueConfig, QueueError, MAX_ITEM};
use crate::pmem::{PAddr, PmemPool};

/// `⊥` — unoccupied cell (the all-zeroes fresh-NVM state).
pub const BOT: u64 = 0;
/// `⊤` — consumed cell.
pub const TOP: u64 = u64::MAX;

/// Encode an item for storage.
#[inline]
pub fn enc(item: u64) -> u64 {
    debug_assert!(item < MAX_ITEM);
    item + 1
}

/// Decode a stored (non-sentinel) value.
#[inline]
pub fn dec(stored: u64) -> u64 {
    debug_assert!(stored != BOT && stored != TOP);
    stored - 1
}

/// Shared persistent layout of IQ/PerIQ (both algorithms use the same
/// arena image; PerIQ adds persistence instructions and a recovery
/// function).
pub struct IqLayout {
    /// `Tail` FAI object (own cache line).
    pub tail: PAddr,
    /// `Head` FAI object (own cache line).
    pub head: PAddr,
    /// Cell array base (one word per cell).
    pub cells: PAddr,
    /// Number of cells.
    pub capacity: usize,
}

impl IqLayout {
    /// Allocate the layout in `pool`.
    pub fn alloc(pool: &PmemPool, capacity: usize) -> Self {
        // Head and Tail each get a private line: they are distinct hot
        // spots and must not false-share (the paper's algorithms assume
        // this; so does the cost model).
        let tail = pool.alloc_lines(1);
        let head = pool.alloc_lines(1);
        let cells = pool.alloc_lines(capacity.div_ceil(crate::pmem::WORDS_PER_LINE));
        // Contention declarations (see pmem::Hotness): endpoints are
        // touched by every thread; each cell by one enqueuer + one
        // dequeuer (the paper's low-contention property).
        pool.set_hot(tail, 1, crate::pmem::Hotness::Global);
        pool.set_hot(head, 1, crate::pmem::Hotness::Global);
        Self { tail, head, cells, capacity }
    }

    /// Address of cell `i`.
    #[inline]
    pub fn cell(&self, i: u64) -> PAddr {
        debug_assert!((i as usize) < self.capacity);
        self.cells.add(i as usize)
    }
}

/// The volatile IQ (no persistence instructions).
pub struct Iq {
    pool: Arc<PmemPool>,
    pub(crate) layout: IqLayout,
}

impl Iq {
    pub fn new(pool: &Arc<PmemPool>, _nthreads: usize, cfg: QueueConfig) -> Self {
        cfg.validate().expect("invalid QueueConfig");
        Self { pool: Arc::clone(pool), layout: IqLayout::alloc(pool, cfg.iq_capacity) }
    }

    /// Current head/tail (test observability).
    pub fn indices(&self, tid: usize) -> (u64, u64) {
        (self.pool.load(tid, self.layout.head), self.pool.load(tid, self.layout.tail))
    }
}

impl ConcurrentQueue for Iq {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        loop {
            let t = p.fai(tid, self.layout.tail); // line 3
            if t as usize >= self.layout.capacity {
                return Err(QueueError::CapacityExhausted);
            }
            if p.swap(tid, self.layout.cell(t), enc(item)) == BOT {
                return Ok(()); // line 4-6
            }
            // A dequeuer beat us to the cell (wrote ⊤): retry with a new
            // index.
        }
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let p = &self.pool;
        loop {
            let h = p.fai(tid, self.layout.head); // line 9
            if h as usize >= self.layout.capacity {
                return Err(QueueError::CapacityExhausted);
            }
            let x = p.swap(tid, self.layout.cell(h), TOP); // line 10
            if x != BOT {
                debug_assert_ne!(x, TOP, "cell dequeued twice — FAI uniqueness violated");
                return Ok(Some(dec(x))); // line 11-13
            }
            // line 14: EMPTY check — Tail ≤ h+1 means no enqueuer is ahead.
            let t = p.load(tid, self.layout.tail);
            if t <= h + 1 {
                return Ok(None);
            }
        }
    }

    fn name(&self) -> &'static str {
        "iq"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};

    fn mk(capacity: usize) -> Iq {
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 18).with_cost(CostModel::zero()),
        ));
        let cfg = QueueConfig { iq_capacity: capacity, ..Default::default() };
        Iq::new(&pool, 4, cfg)
    }

    #[test]
    fn fifo_single_thread() {
        let q = mk(1024);
        for v in 0..100u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..100u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn empty_on_fresh_queue() {
        let q = mk(64);
        assert_eq!(q.dequeue(0).unwrap(), None);
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn interleaved_enq_deq() {
        let q = mk(4096);
        for round in 0..50u64 {
            q.enqueue(0, round * 2).unwrap();
            q.enqueue(1, round * 2 + 1).unwrap();
            assert_eq!(q.dequeue(2).unwrap(), Some(round * 2));
            assert_eq!(q.dequeue(3).unwrap(), Some(round * 2 + 1));
        }
    }

    #[test]
    fn capacity_exhaustion() {
        let q = mk(16);
        for v in 0..16u64 {
            q.enqueue(0, v).unwrap();
        }
        assert_eq!(q.enqueue(0, 99), Err(QueueError::CapacityExhausted));
    }

    #[test]
    fn item_out_of_range_rejected() {
        let q = mk(16);
        assert_eq!(q.enqueue(0, MAX_ITEM), Err(QueueError::ItemOutOfRange(MAX_ITEM)));
    }

    #[test]
    fn empty_dequeues_burn_indices() {
        // An EMPTY dequeue consumed a Head index; the matching enqueue index
        // will be skipped by the enqueuer's retry loop (top swap).
        let q = mk(1024);
        assert_eq!(q.dequeue(0).unwrap(), None); // burns index 0 with ⊤
        q.enqueue(1, 7).unwrap(); // lands at index 1 after a retry
        assert_eq!(q.dequeue(0).unwrap(), Some(7));
    }

    #[test]
    fn mpmc_stress_no_loss_no_dup() {
        use std::sync::atomic::{AtomicU64, Ordering};
        let pool = Arc::new(PmemPool::new(
            PmemConfig::default().with_capacity(1 << 21).with_cost(CostModel::zero()),
        ));
        let cfg = QueueConfig { iq_capacity: 1 << 18, ..Default::default() };
        let q = Arc::new(Iq::new(&pool, 8, cfg));
        let per_thread = 2000u64;
        let nprod = 4usize;
        let total = nprod as u64 * per_thread;
        let consumed = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for pid in 0..nprod {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    q.enqueue(pid, (pid as u64) * per_thread + i).unwrap();
                }
            }));
        }
        let seen = Arc::new(std::sync::Mutex::new(Vec::new()));
        for cid in 0..4usize {
            let q = Arc::clone(&q);
            let seen = Arc::clone(&seen);
            let consumed = Arc::clone(&consumed);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while consumed.load(Ordering::Relaxed) < total {
                    match q.dequeue(nprod + cid).unwrap() {
                        Some(v) => {
                            got.push(v);
                            consumed.fetch_add(1, Ordering::Relaxed);
                        }
                        None => std::thread::yield_now(),
                    }
                }
                seen.lock().unwrap().extend(got);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = seen.lock().unwrap().clone();
        assert_eq!(all.len(), total as usize);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "every item exactly once");
    }
}
