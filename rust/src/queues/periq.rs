//! PerIQ — the persistent infinite-array queue (paper §4.1, Algorithm 1).
//!
//! PerIQ performs exactly **one `pwb` + `psync` pair per operation**, and
//! always on the cell the operation wrote — a location touched by at most
//! two threads — respecting both persistence principles of \[1\]: few
//! persistence instructions, on low-contention variables.
//!
//! `Head` and `Tail` are *not* persisted (in the base variant); the
//! recovery function reconstructs them by scanning `Q`:
//!
//! * `Tail` := first cell of the first streak of `n` consecutive `⊥` cells
//!   (there are at most `n−1` holes between occupied cells, one per
//!   in-flight enqueuer, so `n` consecutive `⊥`s prove no persisted item
//!   lies beyond).
//! * `Head` := one past the last `⊤` left of `Tail` (dequeuers persist the
//!   `⊤` they swap in, so no persisted-consumed cell may sit at or after
//!   `Head`).
//!
//! The Algorithm 6 variant additionally persists `Tail` every
//! `periq_tail_interval` enqueues, trading normal-execution throughput for
//! recovery time (Figures 4–6); recovery then scans only from the persisted
//! `Tail` onward.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::iq::{dec, enc, IqLayout, BOT, TOP};
use super::{ConcurrentQueue, PersistentQueue, QueueConfig, QueueError, MAX_ITEM};
use crate::pmem::{PmemPool, WORDS_PER_LINE};
use crossbeam_utils::CachePadded;

/// The persistent IQ.
pub struct PerIq {
    pool: Arc<PmemPool>,
    layout: IqLayout,
    nthreads: usize,
    /// Persist `Tail` every `k` enqueues (0 = never; Alg. 6 knob).
    tail_interval: usize,
    /// Per-thread volatile enqueue counters (`nOps_i` of Alg. 6).
    nops: Vec<CachePadded<AtomicU64>>,
}

impl PerIq {
    pub fn new(pool: &Arc<PmemPool>, nthreads: usize, cfg: QueueConfig) -> Self {
        assert!(nthreads >= 1);
        cfg.validate().expect("invalid QueueConfig");
        Self {
            pool: Arc::clone(pool),
            layout: IqLayout::alloc(pool, cfg.iq_capacity),
            nthreads,
            tail_interval: cfg.periq_tail_interval,
            nops: (0..nthreads).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
        }
    }

    /// Current head/tail (test observability).
    pub fn indices(&self, tid: usize) -> (u64, u64) {
        (self.pool.load(tid, self.layout.head), self.pool.load(tid, self.layout.tail))
    }

    /// Number of live items (test observability; not linearizable).
    pub fn approx_len(&self, tid: usize) -> u64 {
        let (h, t) = self.indices(tid);
        t.saturating_sub(h)
    }

    /// Algorithm 6: persist `Tail` and `Head` every `tail_interval`
    /// operations of this thread. (Alg. 6 shows the enqueue side; we count
    /// dequeues too so the recovery window bound `Head_live − H₀ ≤ n·k + n`
    /// holds under dequeue-heavy phases as well.)
    #[inline]
    fn maybe_persist_endpoints(&self, tid: usize) {
        if self.tail_interval == 0 {
            return;
        }
        let n = self.nops[tid].fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.tail_interval as u64 == 0 {
            let p = &self.pool;
            p.pwb(tid, self.layout.tail);
            p.pwb(tid, self.layout.head);
            p.psync(tid);
        }
    }
}

impl ConcurrentQueue for PerIq {
    fn enqueue(&self, tid: usize, item: u64) -> Result<(), QueueError> {
        if item >= MAX_ITEM {
            return Err(QueueError::ItemOutOfRange(item));
        }
        let p = &self.pool;
        loop {
            let t = p.fai(tid, self.layout.tail); // line 3
            if t as usize >= self.layout.capacity {
                return Err(QueueError::CapacityExhausted);
            }
            let cell = self.layout.cell(t);
            let old = p.swap(tid, cell, enc(item));
            if old == BOT {
                // line 5: the ONLY persistence pair of the operation.
                p.pwb(tid, cell);
                p.psync(tid);
                self.maybe_persist_endpoints(tid);
                return Ok(());
            }
            // Retry path: our blind swap displaced the dequeuer's (durable)
            // ⊤ with an item we are about to re-enqueue elsewhere. Restore
            // the ⊤ before retrying — otherwise a crash-time eviction of
            // this line can persist the abandoned copy and recovery would
            // resurrect the value at TWO indices (a duplicate). This
            // corner is absent from the paper's Algorithm 1 (its proofs
            // only reason about each operation's *final* iteration; CRQ is
            // immune because its CAS2 never writes blindly) — see
            // EXPERIMENTS.md §Deviations.
            debug_assert_eq!(old, TOP);
            p.store(tid, cell, TOP);
        }
    }

    fn dequeue(&self, tid: usize) -> Result<Option<u64>, QueueError> {
        let p = &self.pool;
        loop {
            let h = p.fai(tid, self.layout.head); // line 9
            if h as usize >= self.layout.capacity {
                return Err(QueueError::CapacityExhausted);
            }
            let cell = self.layout.cell(h);
            let x = p.swap(tid, cell, TOP); // line 10
            if x != BOT {
                debug_assert_ne!(x, TOP, "cell dequeued twice");
                // line 12: persist the ⊤ we wrote — one pair per op.
                p.pwb(tid, cell);
                p.psync(tid);
                self.maybe_persist_endpoints(tid);
                return Ok(Some(dec(x)));
            }
            let t = p.load(tid, self.layout.tail); // line 14
            if t <= h + 1 {
                // line 15: persist the ⊤ marking this head position so the
                // EMPTY response is durable.
                p.pwb(tid, cell);
                p.psync(tid);
                self.maybe_persist_endpoints(tid);
                return Ok(None);
            }
        }
    }

    fn name(&self) -> &'static str {
        if self.tail_interval > 0 {
            "periq-ptail"
        } else {
            "periq"
        }
    }
}

impl PersistentQueue for PerIq {
    /// Algorithm 1, lines 17–26.
    ///
    /// Both scans are *bounded below* by whatever endpoint values reached
    /// NVM (via the Alg. 6 periodic persists, or opportunistic eviction):
    /// a persisted `Tail = T₀` witnesses that indices `< T₀` were claimed,
    /// so the ⊥-streak scan may start there; a persisted `Head = H₀`
    /// witnesses dequeues up to `H₀` (the paper's "deq is persisted if some
    /// value of Head ≥ i has been written back"), so the ⊤ walk-back may
    /// stop there. This is what makes the persist-endpoints variant's
    /// recovery O(interval) instead of O(queue length) — the Figs. 4–6
    /// tradeoff.
    fn recover(&self, pool: &PmemPool) {
        let tid = 0;
        let cap = self.layout.capacity as u64;
        let n = self.nthreads as u64;

        // --- Recover Tail (lines 18-23) ---
        let tail_start = pool.load(tid, self.layout.tail); // persisted (or 0)
        let head_floor = pool.load(tid, self.layout.head); // persisted (or 0)
        let mut scan = tail_start;
        let mut count_bot: u64 = 0;
        let mut tail;
        while count_bot < n && scan < cap {
            if pool.load(tid, self.layout.cell(scan)) == BOT {
                count_bot += 1;
            } else {
                count_bot = 0;
            }
            scan += 1;
        }
        if count_bot >= n {
            // First cell of the ⊥ streak.
            tail = scan - n;
        } else {
            // Degenerate: array exhausted without a streak — everything up
            // to `scan` is (or was) used.
            tail = scan;
        }
        tail = tail.max(tail_start);

        // --- Recover Head (lines 24-26) ---
        // Head must land right after the LAST persisted ⊤ (so no ⊤ remains
        // in [Head, Tail) and every persisted dequeue is linearized along
        // with the in-flight "holes" below it — §4.1).
        let mut head;
        if self.tail_interval > 0 {
            // Persist-endpoints variant: every thread flushes Head at
            // least every `k` of its ops, so no dequeue index can exceed
            // H₀ + n·k + n. A bounded FORWARD scan over that window finds
            // the last ⊤ in O(n·k) — independent of queue size (the flat
            // curve of Fig. 5).
            let window = self.nthreads as u64 * self.tail_interval as u64 + n;
            let limit = tail.min(head_floor.saturating_add(window)).min(cap);
            head = head_floor;
            let mut i = head_floor;
            while i < limit {
                if pool.load(tid, self.layout.cell(i)) == TOP {
                    head = i + 1;
                }
                i += 1;
            }
        } else {
            // Pure PerIQ: walk left from Tail until the first ⊤ (or the
            // floor) — O(queue length), the growing curve of Fig. 5.
            head = tail;
            while head > head_floor {
                if pool.load(tid, self.layout.cell(head - 1)) == TOP {
                    break;
                }
                head -= 1;
            }
        }
        head = head.max(head_floor);

        pool.store(tid, self.layout.tail, tail);
        pool.store(tid, self.layout.head, head);
        // Make the recovered endpoints durable so a repeated crash during
        // the next epoch cannot observe pre-recovery endpoint values.
        pool.pwb(tid, self.layout.tail);
        pool.pwb(tid, self.layout.head);
        pool.psync(tid);

        // Volatile bookkeeping dies with the crash.
        for c in &self.nops {
            c.store(0, Ordering::Relaxed);
        }
        let _ = WORDS_PER_LINE; // (layout granularity documented above)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::{CostModel, PmemConfig};
    use crate::util::rng::Xoshiro256;

    fn mk(nthreads: usize, tail_interval: usize) -> (Arc<PmemPool>, PerIq) {
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 42,
        }));
        let cfg = QueueConfig {
            iq_capacity: 1 << 12,
            periq_tail_interval: tail_interval,
            ..Default::default()
        };
        let q = PerIq::new(&pool, nthreads, cfg);
        (pool, q)
    }

    #[test]
    fn fifo_and_empty() {
        let (_p, q) = mk(2, 0);
        for v in 0..64u64 {
            q.enqueue(0, v).unwrap();
        }
        for v in 0..64u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        assert_eq!(q.dequeue(1).unwrap(), None);
    }

    #[test]
    fn ops_persist_exactly_one_pair() {
        let (p, q) = mk(1, 0);
        p.stats.reset();
        q.enqueue(0, 5).unwrap();
        let s = p.stats.total();
        assert_eq!(s.pwbs, 1, "enqueue must issue exactly one pwb");
        assert_eq!(s.psyncs, 1, "enqueue must issue exactly one psync");
        p.stats.reset();
        let _ = q.dequeue(0).unwrap();
        let s = p.stats.total();
        assert_eq!(s.pwbs, 1, "dequeue must issue exactly one pwb");
        assert_eq!(s.psyncs, 1);
    }

    #[test]
    fn completed_ops_survive_crash() {
        let (p, q) = mk(2, 0);
        for v in 10..20u64 {
            q.enqueue(0, v).unwrap();
        }
        // Consume a prefix.
        for v in 10..13u64 {
            assert_eq!(q.dequeue(1).unwrap(), Some(v));
        }
        let mut rng = Xoshiro256::seed_from(1);
        p.crash(&mut rng);
        q.recover(&p);
        // Remaining items must come out in order.
        for v in 13..20u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(v), "item {v} lost across crash");
        }
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn recovery_on_empty_queue() {
        let (p, q) = mk(2, 0);
        let mut rng = Xoshiro256::seed_from(2);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0).unwrap(), None);
        q.enqueue(0, 3).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(3));
    }

    #[test]
    fn recovery_after_total_drain() {
        let (p, q) = mk(2, 0);
        for v in 0..32u64 {
            q.enqueue(0, v).unwrap();
        }
        for _ in 0..32 {
            assert!(q.dequeue(1).unwrap().is_some());
        }
        let mut rng = Xoshiro256::seed_from(3);
        p.crash(&mut rng);
        q.recover(&p);
        assert_eq!(q.dequeue(0).unwrap(), None, "drained queue must recover empty");
        // And stays usable.
        q.enqueue(0, 77).unwrap();
        assert_eq!(q.dequeue(1).unwrap(), Some(77));
    }

    #[test]
    fn recovered_tail_skips_holes_up_to_n() {
        // Simulate in-flight enqueuers' holes: indices 8..16 were claimed
        // by enqueuers that crashed before persisting anything (a full
        // cache line of holes — pwb granularity is the line, so holes
        // inside a persisted line would be flushed along with it). With
        // n = 9 threads, an 8-hole streak must NOT stop the tail scan; the
        // persisted item at index 16 must be found.
        let (p, q) = mk(9, 0);
        for v in 0..8u64 {
            q.enqueue(0, 100 + v).unwrap(); // idx 0-7 (line 0), persisted
        }
        for _ in 8..16u64 {
            let _ = p.fai(0, q.layout.tail); // claim idx 8..15, write nothing
        }
        q.enqueue(0, 200).unwrap(); // idx 16 (line 2), persisted
        let mut rng = Xoshiro256::seed_from(4);
        p.crash(&mut rng);
        q.recover(&p);
        let (h, t) = q.indices(0);
        assert_eq!(h, 0);
        assert_eq!(t, 17, "tail must be past the persisted item at idx 16");
        for v in 0..8u64 {
            assert_eq!(q.dequeue(0).unwrap(), Some(100 + v));
        }
        // Holes 8..15 are skipped by the dequeue retry loop.
        assert_eq!(q.dequeue(0).unwrap(), Some(200));
        assert_eq!(q.dequeue(0).unwrap(), None);
    }

    #[test]
    fn tail_interval_persists_endpoints() {
        let (p, q) = mk(1, 4);
        p.stats.reset();
        for v in 0..8u64 {
            q.enqueue(0, v).unwrap();
        }
        let s = p.stats.total();
        // 8 cell pwbs + 2 endpoint flushes × 2 lines = 12.
        assert_eq!(s.pwbs, 12);
        assert_eq!(q.name(), "periq-ptail");
        // Crash: persisted tail makes recovery start late.
        let mut rng = Xoshiro256::seed_from(5);
        p.crash(&mut rng);
        q.recover(&p);
        let (h, t) = q.indices(0);
        assert_eq!(t, 8);
        assert_eq!(h, 0);
    }

    #[test]
    fn recovery_scan_cost_scales_with_queue_size() {
        // The paper's Figs 4-5 tradeoff: pure PerIQ recovery scans the used
        // prefix; the persist-tail variant scans O(n).
        let (p0, q0) = mk(1, 0);
        let (p1, q1) = mk(1, 1);
        for v in 0..1000u64 {
            q0.enqueue(0, v).unwrap();
            q1.enqueue(0, v).unwrap();
        }
        let mut rng = Xoshiro256::seed_from(6);
        p0.crash(&mut rng);
        p1.crash(&mut rng);
        p0.reset_meter();
        p1.reset_meter();
        q0.recover(&p0);
        q1.recover(&p1);
        let scan0 = p0.stats.total().loads;
        let scan1 = p1.stats.total().loads;
        assert!(
            scan0 > scan1 * 10,
            "pure PerIQ recovery ({scan0} loads) must scan far more than \
             persist-tail recovery ({scan1} loads)"
        );
    }

    #[test]
    fn abandoned_retry_cell_cannot_resurrect_value() {
        // Regression: an enqueue that retries past a ⊤-burned cell must
        // not leave its item there in the cache view — with eviction, that
        // copy would persist and recovery would duplicate the value.
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 18,
            cost: CostModel::zero(),
            evict_prob: 1.0, // every dirty line persists at crash
            pending_flush_prob: 1.0,
            seed: 42,
        }));
        let cfg = QueueConfig { iq_capacity: 1 << 12, ..Default::default() };
        let q = PerIq::new(&pool, 2, cfg);
        // Burn index 0 with an EMPTY dequeue (⊤ persisted by its pwb).
        assert_eq!(q.dequeue(1).unwrap(), None);
        // The enqueue gets t=0, hits the ⊤, retries and lands at t=1.
        q.enqueue(0, 777).unwrap();
        let mut rng = Xoshiro256::seed_from(7);
        pool.crash(&mut rng);
        q.recover(&pool);
        let mut drained = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            drained.push(v);
        }
        assert_eq!(drained, vec![777], "value must appear exactly once, got {drained:?}");
    }

    #[test]
    fn concurrent_crash_cycle_no_dup_no_invented() {
        use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
        install_quiet_crash_hook();
        let pool = Arc::new(PmemPool::new(PmemConfig {
            capacity_words: 1 << 20,
            cost: CostModel::zero(),
            evict_prob: 0.3,
            pending_flush_prob: 0.5,
            seed: 9,
        }));
        let cfg = QueueConfig { iq_capacity: 1 << 14, ..Default::default() };
        let q = Arc::new(PerIq::new(&pool, 4, cfg));
        pool.arm_crash_after(5_000);
        let mut handles = Vec::new();
        for tid in 0..4usize {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                let _ = run_guarded(|| {
                    for i in 0..100_000u64 {
                        let v = tid as u64 * 1_000_000 + i;
                        if q.enqueue(tid, v).is_err() {
                            break;
                        }
                        if let Ok(Some(x)) = q.dequeue(tid) {
                            got.push(x);
                        }
                    }
                });
                got
            }));
        }
        let mut pre_crash: Vec<u64> = Vec::new();
        for h in handles {
            pre_crash.extend(h.join().unwrap());
        }
        let mut rng = Xoshiro256::seed_from(10);
        pool.crash(&mut rng);
        q.recover(&pool);
        // Drain everything left.
        let mut post: Vec<u64> = Vec::new();
        while let Some(v) = q.dequeue(0).unwrap() {
            post.push(v);
        }
        // No duplicates between pre-crash returns and post-crash drains.
        let mut all = pre_crash.clone();
        all.extend(&post);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "duplicate item across crash boundary");
        // No invented values.
        for v in &all {
            assert!(v % 1_000_000 < 100_000, "invented value {v}");
        }
    }
}
