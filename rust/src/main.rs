//! `persiq` — CLI launcher.
//!
//! ```text
//! persiq list                       # available algorithms
//! persiq bench     --algo perlcrq --threads 1,2,4 --ops 200000
//! persiq bench     --algo sharded-perlcrq --shards 8 --batch 8 --batch-deq 8 --threads 8
//! persiq recover   --algo periq --cycles 10 --steps 50000
//! persiq verify    --algo perlcrq --cycles 5
//! persiq verify    --algo sharded-perlcrq --shards 4 --cycles 10
//! persiq serve     --producers 2 --workers 2 --jobs 500 --crash-cycles 2
//! persiq serve     --shards 4 --batch 4 --crash-cycles 2
//! persiq bench     --algo sharded-perlcrq --pools 2 --placement colocate --shards 4
//! persiq verify    --algo sharded-perlcrq --pools 2 --relax auto --cycles 5
//! persiq audit     --pools 2 --placement colocate --batch 4 --batch-deq 4
//! persiq bench     --async --batch 8 --batch-deq 8 --flush-us 50 --threads 4
//! persiq serve     --async --shards 4 --batch 4 --flushers 2 --lease-ms 200
//! persiq bench     --algo sharded-perlcrq --resharding-schedule 4:8@50 --threads 4
//! persiq verify    --algo sharded-perlcrq --resharding-schedule 4:8@50 --cycles 5
//! persiq serve     --queue sharded --resize 8 --jobs 500
//! persiq resize    --shards-to 8 --jobs 500  # online grow demo + audit
//! persiq micro                      # pmem primitive costs
//! persiq obs                        # metrics dump + psync-by-site ledger
//! persiq obs       --trace obs.jsonl --batch 8 --shards 4
//! persiq bench     --algo sharded-perlcrq --trace out.jsonl
//! persiq serve     --metrics-every 1 --crash-cycles 2
//! ```
//!
//! The algorithm lists, validation and `--algo all` expansion all derive
//! from `queues::registry()` / `queues::persistent_registry()` — a newly
//! registered queue shows up everywhere automatically.

use std::sync::Arc;

use anyhow::Result;

use persiq::config::{Config, ReshardSchedule};
use persiq::coordinator::{run_service, Broker, ServiceConfig};
use persiq::harness::bench::Suite;
use persiq::harness::failure::{mean_recovery_secs, mean_recovery_sim_ns};
use persiq::harness::runner::{drain_all, run_workload};
use persiq::harness::{run_cycles, CycleConfig, MidHook, RunConfig, Workload};
use persiq::obs;
use persiq::pmem::crash::install_quiet_crash_hook;
use persiq::pmem::{CostModel, MeterMode, PlacementPolicy, PmemPool, MAX_POOLS};
use persiq::queues::{
    by_name, persistent_by_name, persistent_names, registry, registry_names, QueueCtx,
};
use persiq::runtime::MetricsEngine;
use persiq::util::cli::{Args, Command};
use persiq::util::report::{fnum, Csv};
use persiq::util::rng::entropy_seed;
use persiq::verify::{
    calibrate_relaxation, check_with, options_for, overtake_stats, relaxation_for,
    resharding_relaxation, CheckOptions, History,
};
use persiq::{log_info, log_warn};

fn main() {
    install_quiet_crash_hook();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "list" => cmd_list(),
        "bench" => cmd_bench(rest),
        "recover" => cmd_recover(rest),
        "verify" => cmd_verify(rest),
        "serve" => cmd_serve(rest),
        "resize" => cmd_resize(rest),
        "audit" => cmd_audit(rest),
        "forensics" => cmd_forensics(rest),
        "micro" => cmd_micro(rest),
        "obs" => cmd_obs(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n\n{}", usage_text()),
    }
}

fn usage_text() -> String {
    format!(
        "persiq {} — persistent FIFO queues on simulated NVM\n\n\
         SUBCOMMANDS:\n\
         \x20 list      list queue algorithms\n\
         \x20 bench     throughput benchmark (simulated + wall-clock)\n\
         \x20 recover   crash/recovery cycles; recovery cost (paper §5)\n\
         \x20 verify    randomized crash workloads + durable-linearizability checker\n\
         \x20 serve     persistent task-broker service demo\n\
         \x20 resize    online elastic re-sharding demo (grow/shrink under load)\n\
         \x20 audit     broker SubmitLog <-> queue reconciliation dump\n\
         \x20 forensics post-crash flight-recorder timeline + recovery cross-check\n\
         \x20 micro     pmem primitive cost microbenchmark\n\
         \x20 obs       observability dump: Prometheus metrics + psync-by-site ledger\n\n\
         Run `persiq <cmd> --help` for options.",
        persiq::VERSION
    )
}

fn print_usage() {
    println!("{}", usage_text());
}

fn cmd_list() -> Result<()> {
    println!("algorithms (queues::registry):");
    for (name, _) in registry() {
        let persistent = persistent_by_name(name).is_some();
        println!("  {name:<16} {}", if persistent { "[persistent]" } else { "" });
    }
    Ok(())
}

fn queue_ctx(cfg: &Config, nthreads: usize) -> QueueCtx {
    QueueCtx { topo: cfg.build_topology(), nthreads, cfg: cfg.queue.clone() }
}

/// Resolve an `--algo` spec ("all" or a comma-separated list) against the
/// registry — the single source of truth for names, so listings, error
/// messages and `all` expansion never drift from `queues::registry()`.
fn resolve_algos(spec: &str, persistent_only: bool) -> Result<Vec<String>> {
    let known = if persistent_only { persistent_names() } else { registry_names() };
    if spec == "all" {
        return Ok(known.iter().map(|s| s.to_string()).collect());
    }
    let mut out = Vec::new();
    for a in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        anyhow::ensure!(
            known.iter().any(|k| *k == a),
            "unknown{} algorithm {a:?}; available: {}",
            if persistent_only { " persistent" } else { "" },
            known.join(", ")
        );
        out.push(a.to_string());
    }
    anyhow::ensure!(!out.is_empty(), "no algorithm given; available: {}", known.join(", "));
    Ok(out)
}

/// The queue / topology / async flag set shared by every workload
/// subcommand — registered and parsed in exactly one place, so a new
/// shared knob lands once instead of once per subcommand.
struct QueueArgs;

impl QueueArgs {
    /// Register the shared queue/topology options on a subcommand.
    fn register(cmd: Command) -> Command {
        cmd.opt("shards", "shard count for sharded algorithms (lane count for blockfifo)")
            .opt("batch", "enqueue batch size for sharded algorithms (1 = per-op persistence)")
            .opt(
                "batch-deq",
                "dequeue batch size for sharded algorithms (1 = per-op persistence)",
            )
            .opt("block", "blockfifo block size: entries claimed per FAI / sealed per psync")
            .opt("dchoice", "blockfifo-multi: lanes each dequeue samples before stealing")
            .opt("recycle", "palloc segment recycling: on|off (off = leak-and-bump ablation)")
            .opt(
                "magazine",
                "palloc per-thread magazine capacity per size class (0 = shared freelist only)",
            )
            .opt("pools", "NVM pools (sockets), each with its own bandwidth chain (default 1)")
            .opt("placement", "shard placement: interleave | colocate | pinned:<p0,p1,...>")
    }

    /// Additionally register the async completion-layer knobs — only on
    /// subcommands that actually have an `--async` path (bench, serve),
    /// so the other commands don't advertise silent no-op flags.
    /// [`QueueArgs::apply`] reads them via `Args::get`, which returns the
    /// config default when the option was never registered.
    fn register_async(cmd: Command) -> Command {
        cmd.opt("flush-us", "async completion layer: deadline flush in microseconds")
            .opt("async-depth", "async completion layer: per-flusher in-flight window")
            .opt("flushers", "async completion layer: combiner worker threads")
    }

    /// Register the online re-sharding schedule — only on subcommands
    /// with a workload to resize under (bench, verify).
    fn register_resharding(cmd: Command) -> Command {
        cmd.opt(
            "resharding-schedule",
            "online resize mid-run: <from_k>:<to_k>@<pct> (e.g. 4:8@50 grows 4->8 \
             stripes at 50% of the ops; forces --algo sharded-perlcrq)",
        )
    }

    /// Apply the shared overrides to the config and validate them
    /// (surfacing `BadConfig` as a CLI error instead of a construction
    /// panic).
    fn apply(cfg: &mut Config, a: &Args) -> Result<()> {
        cfg.queue.shards = a.get_parse("shards", cfg.queue.shards)?;
        cfg.queue.batch = a.get_parse("batch", cfg.queue.batch)?;
        cfg.queue.batch_deq = a.get_parse("batch-deq", cfg.queue.batch_deq)?;
        cfg.queue.block = a.get_parse("block", cfg.queue.block)?;
        cfg.queue.dchoice = a.get_parse("dchoice", cfg.queue.dchoice)?;
        if let Some(r) = a.get("recycle") {
            cfg.queue.recycle = match r {
                "on" | "true" | "1" => true,
                "off" | "false" | "0" => false,
                other => anyhow::bail!("--recycle must be on|off, got {other:?}"),
            };
        }
        cfg.queue.magazine = a.get_parse("magazine", cfg.queue.magazine)?;
        cfg.pools = a.get_parse("pools", cfg.pools)?;
        anyhow::ensure!(
            cfg.pools >= 1 && cfg.pools <= MAX_POOLS,
            "pool count must be in 1..={MAX_POOLS} (--pools / [topology] pools)"
        );
        if let Some(p) = a.get("placement") {
            cfg.queue.placement = PlacementPolicy::parse(p).map_err(|e| anyhow::anyhow!(e))?;
        }
        if let PlacementPolicy::Pinned(list) = &cfg.queue.placement {
            if let Some(&bad) = list.iter().find(|&&p| p >= cfg.pools) {
                anyhow::bail!("pinned placement names pool {bad} but --pools is {}", cfg.pools);
            }
        }
        cfg.queue.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(s) = a.get("resharding-schedule") {
            cfg.resharding =
                Some(ReshardSchedule::parse(s).map_err(|e| anyhow::anyhow!(e))?);
        }
        if let Some(sched) = &cfg.resharding {
            // The schedule owns the starting shard count.
            cfg.queue.shards = sched.from_k;
        }
        cfg.asyncq.flush_us = a.get_parse("flush-us", cfg.asyncq.flush_us)?;
        cfg.asyncq.depth = a.get_parse("async-depth", cfg.asyncq.depth)?;
        cfg.asyncq.flushers = a.get_parse("flushers", cfg.asyncq.flushers)?;
        cfg.asyncq.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        Ok(())
    }
}

/// Arm the JSONL event trace around `body` when `--trace <path>` was
/// given (subcommands registering the option); flush the merged,
/// ts-sorted file afterwards even when `body` errs.
fn with_trace(a: &Args, body: impl FnOnce() -> Result<()>) -> Result<()> {
    let armed = a.get("trace").is_some();
    if let Some(p) = a.get("trace") {
        obs::trace::start(p);
    }
    let res = body();
    if armed {
        match obs::trace::stop() {
            Ok(Some(rep)) => {
                println!(
                    "[trace: {} events -> {} ({} dropped)]",
                    rep.written,
                    rep.path.display(),
                    rep.dropped
                );
                if rep.dropped > 0 {
                    log_warn!(
                        "trace: {} events were evicted from full rings — raise the ring \
                         capacity or narrow the run to keep the timeline complete",
                        rep.dropped
                    );
                }
            }
            Ok(None) => {}
            Err(e) => log_warn!("trace flush failed: {e}"),
        }
    }
    res
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let cmd = Command::new("bench", "throughput benchmark over simulated threads")
        .opt_default(
            "algo",
            "algorithm(s), comma-separated, or 'all' (see `persiq list`)",
            "perlcrq",
        )
        .opt_default("threads", "thread counts, comma-separated", "1,2,4,8")
        .opt("ops", "total operations per point")
        .opt_default("workload", "pairs|random5050|enq-heavy|deq-heavy", "pairs")
        .opt("seed", "RNG seed (default: entropy)")
        .flag(
            "async",
            "drive the sharded queue through the async completion layer \
             (producers overlap persistence; durability-gated futures)",
        )
        .flag("latency", "also report latency percentiles via the metrics engine")
        .opt(
            "trace",
            "write a JSONL event trace (psyncs by site, batch seals, resize spans, \
             future lifecycles) to this path",
        );
    let cmd = QueueArgs::register_resharding(QueueArgs::register_async(QueueArgs::register(cmd)));
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let algos = resolve_algos(a.get("algo").unwrap_or("perlcrq"), false)?;
    let threads = a.get_list::<usize>("threads", &[1, 2, 4, 8])?;
    let ops = a.get_parse::<u64>("ops", cfg.bench_ops)?;
    let workload = Workload::parse(a.get("workload").unwrap_or("pairs"))
        .ok_or_else(|| anyhow::anyhow!("unknown workload"))?;
    let seed = a.get_parse::<u64>("seed", entropy_seed())?;
    let want_latency = a.flag("latency");
    log_info!("bench seed = {seed}");

    with_trace(&a, || {
        if a.flag("async") {
            // The async layer rides the sharded queue's batch logs: --algo
            // is fixed. Surface ignored flags instead of misattributing
            // numbers.
            let algo_spec = a.get("algo").unwrap_or("perlcrq");
            if algo_spec != "perlcrq" && algo_spec != "sharded-perlcrq" {
                anyhow::bail!("--async benches sharded-perlcrq only (got --algo {algo_spec})");
            }
            if want_latency {
                log_warn!(
                    "--latency is ignored with --async (no per-op sampling on the async path)"
                );
            }
            if cfg.resharding.is_some() {
                anyhow::bail!(
                    "--resharding-schedule is a sync-bench knob; resize the async path with \
                     `persiq serve --async --resize <k>`"
                );
            }
            return bench_async(&cfg, &threads, ops, workload, seed);
        }

        if let Some(sched) = cfg.resharding {
            let algo_spec = a.get("algo").unwrap_or("perlcrq");
            if algo_spec != "perlcrq" && algo_spec != "sharded-perlcrq" {
                anyhow::bail!(
                    "--resharding-schedule resizes sharded-perlcrq only (got --algo {algo_spec})"
                );
            }
            return bench_resharding(&cfg, sched, &threads, ops, workload, seed);
        }

        let engine = if want_latency { Some(MetricsEngine::auto()) } else { None };
        let mut csv = Csv::new(vec![
            "algo", "threads", "sim_mops", "wall_mops", "pwbs_per_op", "psyncs_per_op",
            "remote_per_op", "p50_ns", "p99_ns",
        ]);
        for algo in &algos {
            let ctor = by_name(algo).ok_or_else(|| anyhow::anyhow!("unknown algo {algo}"))?;
            for &n in &threads {
                let ctx = queue_ctx(&cfg, n);
                let q = ctor(&ctx);
                let rc = RunConfig {
                    nthreads: n,
                    total_ops: ops,
                    workload,
                    seed,
                    sample_every: if want_latency { 16 } else { 0 },
                    ..Default::default()
                };
                let r = run_workload(&ctx.topo, &q, &rc);
                let stats = ctx.topo.stats_total();
                let (p50, p99) = if let Some(engine) = &engine {
                    let samples: Vec<f64> =
                        r.latency_samples.iter().flatten().cloned().collect();
                    let m = engine.metrics(&samples)?;
                    (m.p50, m.p99)
                } else {
                    (0.0, 0.0)
                };
                csv.row(vec![
                    algo.clone(),
                    n.to_string(),
                    fnum(r.sim_mops),
                    fnum(r.wall_mops),
                    format!("{:.2}", stats.pwbs as f64 / r.ops_done.max(1) as f64),
                    format!("{:.2}", stats.psyncs as f64 / r.ops_done.max(1) as f64),
                    format!("{:.2}", stats.remote_ops as f64 / r.ops_done.max(1) as f64),
                    fnum(p50),
                    fnum(p99),
                ]);
            }
        }
        print!("{}", csv.to_table());
        csv.save(std::path::Path::new("results/cli_bench.csv"))?;
        println!("[saved results/cli_bench.csv]");
        Ok(())
    })
}

/// `bench --async`: producers submit through the completion layer and
/// hold windows of durability-gated futures; the flusher workers own the
/// queue tids (`threads` counts producers; flushers come on top from
/// `--flushers`). Only the sharded queue has the batch logs the layer
/// rides, so `--algo` is fixed to `sharded-perlcrq` here.
fn bench_async(
    cfg: &Config,
    threads: &[usize],
    ops: u64,
    workload: Workload,
    seed: u64,
) -> Result<()> {
    use persiq::harness::{run_async_workload, AsyncRunConfig};
    use persiq::queues::sharded::ShardedQueue;
    log_info!(
        "async bench: sharded-perlcrq, flush-us={} depth={} flushers={}",
        cfg.asyncq.flush_us,
        cfg.asyncq.depth,
        cfg.asyncq.flushers
    );
    let mut csv = Csv::new(vec![
        "threads", "flushers", "sim_mops", "wall_mops", "pwbs_per_op", "psyncs_per_op",
        "resolved", "failed", "depth_flushes", "deadline_flushes", "backpressure",
    ]);
    for &n in threads {
        let nthreads = n + cfg.asyncq.flushers;
        let topo = cfg.build_topology();
        let q = Arc::new(
            ShardedQueue::new_perlcrq(&topo, nthreads, cfg.queue.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        );
        let rc = AsyncRunConfig {
            producers: n,
            total_ops: ops,
            workload,
            seed,
            window: cfg.asyncq.depth.max(1),
            acfg: cfg.asyncq.clone(),
            ..Default::default()
        };
        let r = run_async_workload(&topo, &q, &rc);
        anyhow::ensure!(!r.crashed, "async bench crashed unexpectedly");
        let stats = topo.stats_total();
        let per = |x: u64| format!("{:.2}", x as f64 / r.ops_done.max(1) as f64);
        csv.row(vec![
            n.to_string(),
            cfg.asyncq.flushers.to_string(),
            fnum(r.sim_mops),
            fnum(r.wall_mops),
            per(stats.pwbs),
            per(stats.psyncs),
            r.ops_done.to_string(),
            r.failed.to_string(),
            r.stats.depth_flushes.to_string(),
            r.stats.deadline_flushes.to_string(),
            r.stats.backpressure.to_string(),
        ]);
    }
    print!("{}", csv.to_table());
    csv.save(std::path::Path::new("results/cli_bench_async.csv"))?;
    println!("[saved results/cli_bench_async.csv]");
    Ok(())
}

/// `bench --resharding-schedule from:to@pct`: one sharded queue per
/// thread count, resized **online** by thread 0 mid-workload. Reports
/// the usual throughput row plus the transition outcome (plan epoch,
/// frozen residue, retirement).
fn bench_resharding(
    cfg: &Config,
    sched: ReshardSchedule,
    threads: &[usize],
    ops: u64,
    workload: Workload,
    seed: u64,
) -> Result<()> {
    use persiq::queues::sharded::ShardedQueue;
    log_info!("resharding bench: sharded-perlcrq, schedule {sched}");
    let mut csv = Csv::new(vec![
        "threads", "schedule", "sim_mops", "wall_mops", "pwbs_per_op", "psyncs_per_op",
        "plan_epoch", "residue", "retired",
    ]);
    for &n in threads {
        let topo = cfg.build_topology();
        let q = Arc::new(
            ShardedQueue::new_perlcrq(&topo, n, cfg.queue.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        );
        let ops_per_thread = (ops / n as u64).max(1);
        let hook_q = Arc::clone(&q);
        let to_k = sched.to_k;
        let rc = RunConfig {
            nthreads: n,
            total_ops: ops,
            workload,
            seed,
            hook_after: ops_per_thread * sched.at_percent / 100,
            mid_hook: Some(MidHook(Arc::new(move |tid: usize| {
                if let Err(e) = hook_q.resize(tid, to_k) {
                    persiq::log_warn!("online resize failed: {e}");
                }
            }))),
            ..Default::default()
        };
        let as_conc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let r = run_workload(&topo, &as_conc, &rc);
        // Residual drain traffic retires a still-open transition.
        let retired = q.try_retire(0);
        let stats = topo.stats_total();
        let rs = q.resize_stats();
        csv.row(vec![
            n.to_string(),
            sched.to_string(),
            fnum(r.sim_mops),
            fnum(r.wall_mops),
            format!("{:.2}", stats.pwbs as f64 / r.ops_done.max(1) as f64),
            format!("{:.2}", stats.psyncs as f64 / r.ops_done.max(1) as f64),
            q.plan_epoch().to_string(),
            rs.last_residue.to_string(),
            retired.to_string(),
        ]);
        anyhow::ensure!(
            q.plan_epoch() >= 2,
            "the schedule's resize never committed (ops too few for the trigger point?)"
        );
    }
    print!("{}", csv.to_table());
    csv.save(std::path::Path::new("results/cli_bench_resharding.csv"))?;
    println!("[saved results/cli_bench_resharding.csv]");
    Ok(())
}

fn cmd_recover(args: &[String]) -> Result<()> {
    let cmd = Command::new("recover", "crash/recovery cycles (paper §5 framework)")
        .opt_default("algo", "persistent algorithm (see `persiq list`)", "periq")
        .opt_default("cycles", "number of cycles", "10")
        .opt_default("steps", "pmem steps before each crash", "50000")
        .opt_default("threads", "worker threads", "4")
        .opt("ops", "max ops per cycle")
        .opt("seed", "RNG seed");
    let cmd = QueueArgs::register(cmd);
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let algos = resolve_algos(a.get("algo").unwrap_or("periq"), true)?;
    let nthreads = a.get_parse::<usize>("threads", 4)?;
    for algo in &algos {
        let ctor = persistent_by_name(algo)
            .ok_or_else(|| anyhow::anyhow!("{algo} is not a persistent algorithm"))?;
        let ctx = queue_ctx(&cfg, nthreads);
        let q = ctor(&ctx);
        let ccfg = CycleConfig {
            cycles: a.get_parse("cycles", 10)?,
            steps: a.get_parse("steps", 50_000)?,
            run: RunConfig {
                nthreads,
                total_ops: a.get_parse("ops", 10_000_000)?,
                seed: a.get_parse("seed", entropy_seed())?,
                ..Default::default()
            },
            seed: a.get_parse("seed", entropy_seed())?,
        };
        let res = run_cycles(&ctx.topo, &q, &ccfg);
        let mut csv = Csv::new(vec![
            "cycle", "ops_before_crash", "recovery_us", "recovery_sim_us", "loads",
        ]);
        for (i, c) in res.iter().enumerate() {
            csv.row(vec![
                i.to_string(),
                c.ops_before_crash.to_string(),
                format!("{:.1}", c.recovery_wall_secs * 1e6),
                format!("{:.1}", c.recovery_sim_ns as f64 / 1e3),
                c.recovery_loads.to_string(),
            ]);
        }
        println!("[{algo}]");
        print!("{}", csv.to_table());
        println!(
            "mean recovery: {:.1} µs wall, {:.1} µs simulated",
            mean_recovery_secs(&res) * 1e6,
            mean_recovery_sim_ns(&res) / 1e3
        );
    }
    Ok(())
}

fn cmd_verify(args: &[String]) -> Result<()> {
    let cmd = Command::new("verify", "durable-linearizability torture test")
        .opt_default("algo", "persistent algorithm(s) or 'all' (see `persiq list`)", "all")
        .opt_default("cycles", "crash cycles per run", "4")
        .opt_default("threads", "worker threads", "4")
        .opt_default("ops", "ops per cycle attempt", "40000")
        .opt_default("steps", "pmem steps before crash", "30000")
        .opt(
            "relax",
            "allowed FIFO overtakes per dequeue: a number, or 'auto' to calibrate the \
             bound from the observed overtake distribution (default: static formula per \
             algorithm)",
        )
        .flag(
            "async",
            "verify through the async completion layer: histories recorded at the \
             future boundaries get the same checker gate as sync runs (implies --algo \
             sharded-perlcrq; durability-gated resolution means zero trailing \
             allowances)",
        )
        .opt("seed", "RNG seed")
        .opt("trace", "write a JSONL event trace to this path");
    let cmd =
        QueueArgs::register_resharding(QueueArgs::register_async(QueueArgs::register(cmd)));
    let a = cmd.parse(args)?;
    with_trace(&a, || verify_run(&a))
}

/// The body of `verify`, run under an (optionally armed) event trace so
/// crash cycles, resize phases, and recovery spans land in `--trace`.
fn verify_run(a: &Args) -> Result<()> {
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, a)?;
    let seed = a.get_parse::<u64>("seed", entropy_seed())?;
    log_info!("verify seed = {seed}");
    let sched = cfg.resharding;
    if a.flag("async") {
        let spec = a.get("algo").unwrap_or("all");
        if spec != "all" && spec != "sharded-perlcrq" {
            anyhow::bail!("--async verifies sharded-perlcrq only (got --algo {spec})");
        }
        if sched.is_some() {
            anyhow::bail!("--resharding-schedule is a sync-verify knob (no --async)");
        }
        return verify_async(&cfg, a, seed);
    }
    let algos = if sched.is_some() {
        // The schedule resizes the concrete sharded queue: pin the algo.
        let spec = a.get("algo").unwrap_or("all");
        if spec != "all" && spec != "sharded-perlcrq" {
            anyhow::bail!(
                "--resharding-schedule verifies sharded-perlcrq only (got --algo {spec})"
            );
        }
        vec!["sharded-perlcrq".to_string()]
    } else {
        resolve_algos(a.get("algo").unwrap_or("all"), true)?
    };
    let nthreads = a.get_parse::<usize>("threads", 4)?;
    let cycles = a.get_parse::<usize>("cycles", 4)?;
    let ops = a.get_parse::<u64>("ops", 40_000)?;
    let steps = a.get_parse::<u64>("steps", 30_000)?;
    let mut failed = false;
    for algo in &algos {
        let ctor = persistent_by_name(algo)
            .ok_or_else(|| anyhow::anyhow!("{algo} is not persistent"))?;
        let ctx = queue_ctx(&cfg, nthreads);
        // With a schedule the concrete sharded queue is built directly —
        // the resize hook and residue stats need the typed handle.
        let resharder = if sched.is_some() {
            Some(Arc::new(
                persiq::queues::sharded::ShardedQueue::new_perlcrq(
                    &ctx.topo,
                    nthreads,
                    ctx.cfg.clone(),
                )
                .map_err(|e| anyhow::anyhow!("{e}"))?,
            ))
        } else {
            None
        };
        let q: Arc<dyn persiq::queues::PersistentQueue> = match &resharder {
            Some(sq) => Arc::clone(sq) as _,
            None => ctor(&ctx),
        };
        let as_conc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
        let mut rng = persiq::util::rng::Xoshiro256::seed_from(seed);
        let mut logs: Vec<Vec<persiq::verify::Event>> = Vec::new();
        for cycle in 0..cycles {
            ctx.topo.arm_crash_after(steps);
            // Every cycle retries the schedule's resize (a no-op once the
            // target stripe count is active): a crash landing anywhere
            // inside a transition is exactly what this exercises.
            let mid_hook = match (&resharder, &sched) {
                (Some(sq), Some(s)) => {
                    let sq = Arc::clone(sq);
                    let to_k = s.to_k;
                    Some(MidHook(Arc::new(move |tid: usize| {
                        let _ = sq.resize(tid, to_k);
                    })))
                }
                _ => None,
            };
            let rc = RunConfig {
                nthreads,
                total_ops: ops,
                record: true,
                salt: cycle as u64 + 1,
                seed: seed ^ (cycle as u64) << 16,
                hook_after: sched
                    .map(|s| (ops / nthreads as u64).max(1) * s.at_percent / 100)
                    .unwrap_or(0),
                mid_hook,
                ..Default::default()
            };
            let r = run_workload(&ctx.topo, &as_conc, &rc);
            logs.extend(r.logs);
            ctx.topo.crash(&mut rng);
            q.recover(ctx.pool());
        }
        let drained = drain_all(&as_conc, 0);
        let history = History::from_logs(logs, drained);
        // The per-algorithm checker policy — relaxation bound, crash-gated
        // trailing windows, EMPTY-check applicability — comes from one
        // place (`verify::options_for`), shared with the registry-driven
        // tests. Sharded algorithms are k-relaxed (bounded shard skew),
        // blockfifo is k-relaxed with the block as the skew unit;
        // everything else is strict. Every cycle above ended in a
        // topology-wide crash, hence `cycles` crashed epochs.
        let relaxed = algo.starts_with("sharded") || algo.starts_with("blockfifo");
        let mut opts = options_for(algo, nthreads, &cfg.queue, cycles as u64);
        let static_relax = match (&resharder, &sched) {
            // Across a re-sharding boundary: the steady-state bound at
            // the larger stripe count, plus the observed frozen-shard
            // residue (cross-plan overtake allowance).
            (Some(sq), Some(s)) => {
                let rs = sq.resize_stats();
                let k = resharding_relaxation(
                    nthreads,
                    s.from_k.max(s.to_k),
                    cfg.queue.batch.max(cfg.queue.batch_deq),
                    rs.residue_total,
                );
                log_info!(
                    "{algo}: cross-plan allowance: {} flips, residue {} -> relax {k}",
                    rs.flips,
                    rs.residue_total
                );
                k
            }
            _ => opts.relaxation,
        };
        // Auto-calibration only applies to relaxed algorithms: strict
        // queues are checked at k = 0, and raising their bound to an
        // observed-plus-headroom value would weaken the check.
        let relax_auto = a.get("relax") == Some("auto") && relaxed;
        if a.get("relax") == Some("auto") && !relaxed {
            log_info!("{algo}: strict FIFO algorithm — --relax auto keeps k = 0");
        }
        // "auto" keeps the static bound here (strict algorithms stay at
        // k = 0; relaxed ones are recalibrated below).
        opts.relaxation = if a.get("relax") == Some("auto") {
            static_relax
        } else {
            a.get_parse("relax", static_relax)?
        };
        let mut auto_note = String::new();
        if relax_auto {
            // Pass 1: measure the overtake distribution with the FIFO
            // bound disabled, derive the calibrated k, then run the real
            // check against it (all other axioms stay exact in both
            // passes).
            let probe = check_with(
                &history,
                &CheckOptions {
                    relaxation: usize::MAX,
                    collect_overtakes: true,
                    max_report: 0,
                    ..opts
                },
            );
            let stats = overtake_stats(&probe.overtake_counts);
            let k = calibrate_relaxation(&probe.overtake_counts);
            auto_note = format!(
                " [auto: k={k} from {} dequeues (p50={} p99={} max={}); static bound={}]",
                stats.checked, stats.p50, stats.p99, stats.max, static_relax
            );
            if k > static_relax {
                log_warn!(
                    "{algo}: calibrated relaxation {k} exceeds the static bound \
                     {static_relax} — the static formula is no longer conservative"
                );
            }
            opts.relaxation = k;
        }
        let rep = check_with(&history, &opts);
        let status = if rep.ok() { "OK " } else { "FAIL" };
        println!(
            "{status} {algo:<16} enq={} deq={} empties={} drained={} violations={} \
             max_overtakes={} (relax={}) absorbed: crash={} trailing={} redelivered={}{}",
            rep.enq_completed,
            rep.deq_values,
            rep.deq_empties,
            rep.drained,
            rep.violations.len(),
            rep.max_overtakes,
            opts.relaxation,
            rep.absorbed_losses,
            rep.absorbed_trailing,
            rep.absorbed_redelivered,
            auto_note,
        );
        for v in &rep.violations {
            log_warn!("  {algo}: {v:?}");
            failed = true;
        }
    }
    anyhow::ensure!(!failed, "durable-linearizability violations detected");
    Ok(())
}

/// `verify --async`: crash cycles through the async completion layer,
/// with producer histories recorded at the **future boundaries**
/// (`EnqOk`/`DeqOk` stamp at resolution, which is durability-gated).
/// Because nothing resolves before its psync, the checker runs with
/// *zero* trailing-loss/redelivery allowance — stricter than the sync
/// path's batched windows; only the sharded queue's bounded skew is
/// allowed (plus `--relax auto` calibration, as in sync mode).
fn verify_async(cfg: &Config, a: &Args, seed: u64) -> Result<()> {
    use persiq::harness::{run_async_workload, AsyncRunConfig};
    use persiq::queues::sharded::ShardedQueue;
    let producers = a.get_parse::<usize>("threads", 4)?;
    let cycles = a.get_parse::<usize>("cycles", 4)?;
    let ops = a.get_parse::<u64>("ops", 40_000)?;
    let steps = a.get_parse::<u64>("steps", 30_000)?;
    let nthreads = producers + cfg.asyncq.flushers;
    log_info!(
        "async verify: sharded-perlcrq, {producers} producers + {} flushers, \
         flush-us={} depth={}",
        cfg.asyncq.flushers,
        cfg.asyncq.flush_us,
        cfg.asyncq.depth
    );
    let topo = cfg.build_topology();
    let q = Arc::new(
        ShardedQueue::new_perlcrq(&topo, nthreads, cfg.queue.clone())
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    );
    let mut rng = persiq::util::rng::Xoshiro256::seed_from(seed);
    let mut logs: Vec<Vec<persiq::verify::Event>> = Vec::new();
    for cycle in 0..cycles {
        topo.arm_crash_after(steps);
        let rc = AsyncRunConfig {
            producers,
            total_ops: ops,
            record: true,
            salt: cycle as u64 + 1,
            seed: seed ^ (cycle as u64) << 16,
            window: cfg.asyncq.depth.max(1),
            acfg: cfg.asyncq.clone(),
            ..Default::default()
        };
        let r = run_async_workload(&topo, &q, &rc);
        logs.extend(r.logs);
        topo.crash(&mut rng);
        q.recover(topo.primary());
    }
    let as_conc: Arc<dyn persiq::queues::ConcurrentQueue> = Arc::clone(&q) as _;
    let drained = drain_all(&as_conc, 0);
    let history = History::from_logs(logs, drained);
    let static_relax = relaxation_for("sharded-perlcrq", nthreads, &cfg.queue);
    // Durability-gated resolution: no trailing windows, no EMPTY check
    // (an async EMPTY may overlap another producer's in-flight batch).
    let mut opts = CheckOptions {
        relaxation: if a.get("relax") == Some("auto") {
            static_relax
        } else {
            a.get_parse("relax", static_relax)?
        },
        crashed_epochs: cycles as u64,
        check_empty: false,
        ..Default::default()
    };
    let mut auto_note = String::new();
    if a.get("relax") == Some("auto") {
        let probe = check_with(
            &history,
            &CheckOptions {
                relaxation: usize::MAX,
                collect_overtakes: true,
                max_report: 0,
                ..opts
            },
        );
        let stats = overtake_stats(&probe.overtake_counts);
        let k = calibrate_relaxation(&probe.overtake_counts);
        auto_note = format!(
            " [auto: k={k} from {} dequeues (p50={} p99={} max={}); static bound={}]",
            stats.checked, stats.p50, stats.p99, stats.max, static_relax
        );
        opts.relaxation = k;
    }
    let rep = check_with(&history, &opts);
    let status = if rep.ok() { "OK " } else { "FAIL" };
    println!(
        "{status} {:<16} enq={} deq={} empties={} drained={} violations={} \
         max_overtakes={} (relax={}) absorbed: crash={}{}",
        "async-sharded",
        rep.enq_completed,
        rep.deq_values,
        rep.deq_empties,
        rep.drained,
        rep.violations.len(),
        rep.max_overtakes,
        opts.relaxation,
        rep.absorbed_losses,
        auto_note,
    );
    for v in &rep.violations {
        log_warn!("  async-sharded: {v:?}");
    }
    anyhow::ensure!(rep.ok(), "durable-linearizability violations detected (async)");
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let cmd = Command::new("serve", "persistent task-broker service")
        .opt_default("producers", "producer threads", "2")
        .opt_default("workers", "worker threads", "2")
        .opt_default("jobs", "jobs per producer per cycle", "500")
        .opt_default("crash-cycles", "crash/recovery cycles (0 = none)", "0")
        .opt_default("steps", "pmem steps before each crash", "50000")
        .opt_default("queue", "work queue kind: perlcrq|sharded", "perlcrq")
        .flag(
            "async",
            "serve through the async completion layer (submit_async / take_async / \
             ack_async riding the group commit; implies --queue sharded)",
        )
        .opt("lease-ms", "per-job lease on in-flight jobs in ms (0 = off)")
        .opt(
            "resize",
            "online re-shard the work queue to this stripe count during the first \
             cycle, under live producers/workers (implies --queue sharded)",
        )
        .opt(
            "metrics-every",
            "print a Prometheus-text metrics dump (all families + psync site ledger) \
             every N cycles (0 = off)",
        )
        .opt("seed", "RNG seed")
        .opt("trace", "write a JSONL event trace to this path");
    let cmd = QueueArgs::register_async(QueueArgs::register(cmd));
    let a = cmd.parse(args)?;
    with_trace(&a, || serve_run(&a))
}

/// The body of `serve`, run under an (optionally armed) event trace so
/// broker submits/acks, crash cycles, and lease reaps land in `--trace`.
fn serve_run(a: &Args) -> Result<()> {
    let mut cfg = Config::load_default();
    let use_async = a.flag("async");
    let resize_to = a.get_parse::<usize>("resize", 0)?;
    anyhow::ensure!(
        resize_to <= persiq::queues::MAX_SHARDS,
        "--resize must be in 1..={} (got {resize_to})",
        persiq::queues::MAX_SHARDS
    );
    // The broker's queue kind is an explicit choice (config-file [queue]
    // shards/batch only parameterize it); --shards/--batch/--pools/
    // --placement/--async imply sharded (only the sharded queue spreads
    // over a topology's pools and carries the async layer's batch logs).
    let sharded_broker = match a.get("queue").unwrap_or("perlcrq") {
        "sharded" => true,
        "perlcrq" => {
            use_async
                || resize_to > 0
                || a.get("shards").is_some()
                || a.get("batch").is_some()
                || a.get("batch-deq").is_some()
                || a.get("pools").is_some()
                || a.get("placement").is_some()
        }
        other => anyhow::bail!("unknown --queue {other:?} (perlcrq|sharded)"),
    };
    QueueArgs::apply(&mut cfg, a)?;
    let producers = a.get_parse::<usize>("producers", 2)?;
    let workers = a.get_parse::<usize>("workers", 2)?;
    // Async mode adds the flusher workers' thread slots on top of the
    // producer/worker tids; an online resize adds one admin slot after
    // those.
    let base_threads = producers + workers + if use_async { cfg.asyncq.flushers } else { 0 };
    let scfg = ServiceConfig {
        producers,
        workers,
        jobs_per_producer: a.get_parse("jobs", 500)?,
        crash_cycles: a.get_parse("crash-cycles", 0)?,
        crash_steps: a.get_parse("steps", 50_000)?,
        seed: a.get_parse("seed", entropy_seed())?,
        use_async,
        acfg: cfg.asyncq.clone(),
        lease_ms: a.get_parse("lease-ms", cfg.lease_ms)?,
        resize_to,
        admin_tid: base_threads,
        metrics_every: a.get_parse("metrics-every", 0)?,
    };
    let nthreads = base_threads + if resize_to > 0 { 1 } else { 0 };
    let topo = cfg.build_topology();
    let broker = if sharded_broker {
        log_info!(
            "broker work queue: sharded-perlcrq (shards={}, batch={}, batch-deq={}, \
             pools={}, placement={}{})",
            cfg.queue.shards,
            cfg.queue.batch,
            cfg.queue.batch_deq,
            topo.len(),
            cfg.queue.placement,
            if use_async {
                format!(
                    ", async: flush-us={} depth={} flushers={}",
                    cfg.asyncq.flush_us, cfg.asyncq.depth, cfg.asyncq.flushers
                )
            } else {
                String::new()
            }
        );
        Arc::new(
            Broker::new_sharded(&topo, nthreads, 1 << 16, cfg.queue.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        )
    } else {
        Arc::new(Broker::new_on(&topo, nthreads, 1 << 16, cfg.queue.ring_size))
    };
    let rep = run_service(&topo, &broker, &scfg)?;
    println!(
        "broker: submitted={} done={} pending={} crashes={} wall={:.3}s",
        rep.submitted, rep.done, rep.pending_after, rep.crashes, rep.wall_secs
    );
    // Observability loss is a finding, not a formatting detail: an
    // overwritten flight ring means `forensics` would see a truncated
    // window for this run's tail.
    let overwritten: u64 = topo.pools().iter().map(|p| p.flight().overwritten()).sum();
    if overwritten > 0 {
        log_warn!(
            "flight recorder: {overwritten} ring entr{} overwritten — post-crash \
             forensics would see a truncated event window",
            if overwritten == 1 { "y was" } else { "ies were" }
        );
    }
    if resize_to > 0 {
        let rec = broker.reconcile_report(0);
        println!(
            "plan: epoch={} shards={} (flips={} retires={} residue={})",
            rec.plan.0, rec.plan.1, rec.resize.flips, rec.resize.retires,
            rec.resize.residue_total
        );
        anyhow::ensure!(
            rec.draining_plan.is_none(),
            "the resize transition must have retired by the end of serve"
        );
    }
    let engine = MetricsEngine::auto();
    if !rep.latency_samples.is_empty() {
        let m = engine.metrics(&rep.latency_samples)?;
        println!(
            "job latency (simulated, backend={}): mean={} p50={} p95={} p99={} ns",
            m.backend,
            fnum(m.mean),
            fnum(m.p50),
            fnum(m.p95),
            fnum(m.p99)
        );
    }
    anyhow::ensure!(rep.done == rep.submitted, "job loss detected");
    Ok(())
}

/// `persiq resize`: the zero-to-aha elastic re-sharding demo — run an
/// embedded broker service (producers + workers live), re-shard the work
/// queue online mid-run via an admin thread, then audit: every job done
/// exactly once, exactly one plan left, reconciliation invariants intact.
fn cmd_resize(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "resize",
        "online elastic re-sharding demo: grow/shrink the sharded work queue under load",
    )
    .opt_default("shards-to", "stripe count to resize to mid-run", "8")
    .opt_default("producers", "producer threads", "2")
    .opt_default("workers", "worker threads", "2")
    .opt_default("jobs", "jobs per producer", "500")
    .opt_default("crash-cycles", "crash/recovery cycles (0 = none)", "0")
    .opt_default("steps", "pmem steps before each crash", "50000")
    .opt("seed", "RNG seed");
    let cmd = QueueArgs::register(cmd);
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let producers = a.get_parse::<usize>("producers", 2)?;
    let workers = a.get_parse::<usize>("workers", 2)?;
    let resize_to = a.get_parse::<usize>("shards-to", 8)?;
    anyhow::ensure!(
        (1..=persiq::queues::MAX_SHARDS).contains(&resize_to),
        "--shards-to must be in 1..={} (got {resize_to})",
        persiq::queues::MAX_SHARDS
    );
    let scfg = ServiceConfig {
        producers,
        workers,
        jobs_per_producer: a.get_parse("jobs", 500)?,
        crash_cycles: a.get_parse("crash-cycles", 0)?,
        crash_steps: a.get_parse("steps", 50_000)?,
        seed: a.get_parse("seed", entropy_seed())?,
        resize_to,
        admin_tid: producers + workers,
        ..Default::default()
    };
    let topo = cfg.build_topology();
    let broker = Arc::new(
        Broker::new_sharded(&topo, producers + workers + 1, 1 << 16, cfg.queue.clone())
            .map_err(|e| anyhow::anyhow!("{e}"))?,
    );
    log_info!(
        "resize demo: {} -> {resize_to} stripes online (placement {}, pools {})",
        cfg.queue.shards,
        cfg.queue.placement,
        topo.len()
    );
    let rep = run_service(&topo, &broker, &scfg)?;
    let rec = broker.reconcile_report(0);
    println!(
        "resize: submitted={} done={} pending={} crashes={}",
        rep.submitted, rep.done, rep.pending_after, rep.crashes
    );
    println!(
        "plan  : epoch={} shards={} draining={} (flips={} retires={} residue={} \
         drained-from-frozen={})",
        rec.plan.0,
        rec.plan.1,
        rec.draining_plan.is_some(),
        rec.resize.flips,
        rec.resize.retires,
        rec.resize.residue_total,
        rec.resize.drained_from_frozen
    );
    anyhow::ensure!(rep.done == rep.submitted, "job loss across the resize");
    anyhow::ensure!(rec.draining_plan.is_none(), "transition did not retire");
    anyhow::ensure!(rec.plan.1 == resize_to, "resize never committed");
    anyhow::ensure!(rec.mismatches() == 0, "reconciliation invariants violated");
    println!("online re-shard OK: exactly-once completion + single committed plan");
    Ok(())
}

fn cmd_audit(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "audit",
        "broker SubmitLog <-> work-queue reconciliation dump (per-state counts + mismatches)",
    )
    .opt_default("producers", "producer threads", "2")
    .opt_default("jobs", "jobs per producer", "200")
    .opt_default("consume", "fraction of submitted jobs to take+complete first", "0.5")
    .opt_default("crash", "crash + recover before auditing (0 = audit the live state)", "1")
    .opt_default("queue", "work queue kind: perlcrq|sharded", "sharded")
    .opt("seed", "RNG seed");
    let cmd = QueueArgs::register(cmd);
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let producers = a.get_parse::<usize>("producers", 2)?;
    let jobs = a.get_parse::<usize>("jobs", 200)?;
    let consume = a.get_parse::<f64>("consume", 0.5)?.clamp(0.0, 1.0);
    let do_crash = a.get_parse::<u64>("crash", 1)? > 0;
    let seed = a.get_parse::<u64>("seed", entropy_seed())?;
    let nthreads = producers + 1; // + one consumer slot

    let topo = cfg.build_topology();
    let broker = match a.get("queue").unwrap_or("sharded") {
        "sharded" => Arc::new(
            Broker::new_sharded(&topo, nthreads, 1 << 16, cfg.queue.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        ),
        "perlcrq" => Arc::new(Broker::new_on(&topo, nthreads, 1 << 16, cfg.queue.ring_size)),
        other => anyhow::bail!("unknown --queue {other:?} (perlcrq|sharded)"),
    };

    // Deterministic single-threaded scenario: submit from every producer
    // slot (leaving any batched handle enqueues unflushed — exactly the
    // window recovery must reconcile), consume a fraction, then
    // optionally crash + recover.
    for p in 0..producers {
        broker.attach_worker(p);
        for i in 0..jobs {
            let payload = format!("audit:p{p}:{i}").into_bytes();
            broker.submit(p, &payload[..payload.len().min(48)])?;
        }
    }
    let target = ((producers * jobs) as f64 * consume) as usize;
    let consumer = producers;
    broker.attach_worker(consumer);
    let mut consumed = 0usize;
    while consumed < target {
        let Some((jid, _)) = broker.take(consumer)? else { break };
        if broker.complete(consumer, jid)? {
            consumed += 1;
        }
    }
    if do_crash {
        let mut rng = persiq::util::rng::Xoshiro256::seed_from(seed);
        topo.crash(&mut rng);
        broker.recover();
    } else {
        broker.quiesce();
    }

    let rep = broker.reconcile_report(0);
    println!(
        "audit ({}; pools={}, placement={}, {}):",
        a.get("queue").unwrap_or("sharded"),
        topo.len(),
        cfg.queue.placement,
        if do_crash { "post-crash, post-recovery" } else { "live" }
    );
    println!(
        "  submit logs : submitted={} done={} pending={} unwritten={}",
        rep.audit.submitted, rep.audit.done, rep.audit.pending, rep.audit.unwritten
    );
    let per_pool: Vec<String> = rep
        .per_pool_submitted
        .iter()
        .enumerate()
        .map(|(i, n)| format!("pool{i}={n}"))
        .collect();
    println!("  per-pool    : {}", per_pool.join(" "));
    if rep.plan != (0, 0) {
        println!(
            "  shard plan  : epoch={} shards={} draining={} (flips={} retires={})",
            rep.plan.0,
            rep.plan.1,
            rep.draining_plan
                // The residue is a len_hint sum: an upper bound on the
                // frozen stripes' undrained items, not an exact count.
                .map(|(e, k, r)| format!("epoch {e} ({k} stripes, residue <= {r})"))
                .unwrap_or_else(|| "none".to_string()),
            rep.resize.flips,
            rep.resize.retires
        );
    }
    println!(
        "  work queue  : handles={} pending={} done={} unwritten={} duplicates={}",
        rep.queued, rep.queued_pending, rep.queued_done, rep.queued_unwritten,
        rep.queued_duplicates
    );
    println!(
        "  mismatches  : {} (stranded-pending={} queued-done={} queued-unwritten={} \
         queued-duplicates={})",
        rep.mismatches(),
        rep.stranded_pending,
        rep.queued_done,
        rep.queued_unwritten,
        rep.queued_duplicates
    );
    println!("  psync/pwb by attribution site:");
    for line in obs::render_site_ledger(&topo.site_ledger(), 0).lines() {
        println!("    {line}");
    }
    anyhow::ensure!(
        rep.mismatches() == 0,
        "SubmitLog <-> queue reconciliation mismatch detected"
    );
    println!("  reconciliation invariants hold");
    Ok(())
}

/// `persiq forensics` — run a broker workload into a (simulated) crash,
/// scan every pool's persistent flight-recorder rings **before** recovery
/// mutates the image, reconstruct the merged timeline, then recover and
/// cross-check recovery's decisions against the recorded events:
///
/// * every certified-durable submit/enqueue survives (redelivered or DONE),
/// * no certified-durable ack/dequeue of a DONE job is redelivered,
/// * the durably committed plan epoch is adopted,
/// * the `ReconcileReport` itself has zero mismatches.
///
/// Exits nonzero on any unexplained discrepancy.
fn cmd_forensics(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "forensics",
        "post-crash flight-recorder scan: merged timeline + recovery cross-check",
    )
    .opt_default("producers", "producer threads", "2")
    .opt_default("jobs", "jobs per producer (keep small enough that rings don't wrap)", "15")
    .opt_default("consume", "fraction of submitted jobs to take+complete before the cut", "0.5")
    .opt_default("crash-at", "crash after N pmem steps (0 = cut at workload end)", "0")
    .opt_default("resize", "re-shard the work queue to K stripes mid-run (0 = off)", "0")
    .opt_default("queue", "work queue kind: perlcrq|sharded", "sharded")
    .opt_default("events", "merged-timeline rows to print", "20")
    .opt("out", "write the JSON report to this path")
    .opt("seed", "RNG seed (default: entropy)")
    .opt("trace", "also write the volatile JSONL event trace of the run");
    let cmd = QueueArgs::register(cmd);
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let producers = a.get_parse::<usize>("producers", 2)?;
    let jobs = a.get_parse::<usize>("jobs", 15)?;
    let consume = a.get_parse::<f64>("consume", 0.5)?.clamp(0.0, 1.0);
    let crash_at = a.get_parse::<u64>("crash-at", 0)?;
    let resize_to = a.get_parse::<usize>("resize", 0)?;
    let nrows = a.get_parse::<usize>("events", 20)?;
    let seed = a.get_parse::<u64>("seed", entropy_seed())?;
    let nthreads = producers + 1; // + one consumer slot

    with_trace(&a, || {
        let topo = cfg.build_topology();
        let broker = match a.get("queue").unwrap_or("sharded") {
            "sharded" => Arc::new(
                Broker::new_sharded(&topo, nthreads, 1 << 16, cfg.queue.clone())
                    .map_err(|e| anyhow::anyhow!("{e}"))?,
            ),
            "perlcrq" => Arc::new(Broker::new_on(&topo, nthreads, 1 << 16, cfg.queue.ring_size)),
            other => anyhow::bail!("unknown --queue {other:?} (perlcrq|sharded)"),
        };

        // Pre-crash ground truth, appended only *after* each call returns —
        // a crash unwinds out of the op, so these sets reflect exactly what
        // the application observed before the cut.
        let taken: std::cell::RefCell<Vec<u64>> = Default::default();
        let completed: std::cell::RefCell<Vec<u64>> = Default::default();
        if crash_at > 0 {
            topo.arm_crash_after(crash_at);
        }
        let consumer = producers;
        let outcome = persiq::pmem::run_guarded(|| -> Result<()> {
            for p in 0..producers {
                broker.attach_worker(p);
            }
            broker.attach_worker(consumer);
            let per_round = ((producers as f64) * consume).round() as usize;
            for i in 0..jobs {
                if resize_to > 0 && i == jobs / 2 {
                    let _ = broker.resize(consumer, resize_to);
                }
                for p in 0..producers {
                    let payload = format!("fx:p{p}:{i}");
                    broker.submit(p, payload.as_bytes())?;
                }
                for _ in 0..per_round {
                    let Some((jid, _)) = broker.take(consumer)? else { break };
                    taken.borrow_mut().push(jid.0.to_u64());
                    if broker.complete(consumer, jid)? {
                        completed.borrow_mut().push(jid.0.to_u64());
                    }
                }
            }
            Ok(())
        });
        let crashed = outcome.crashed();
        if let persiq::pmem::RunOutcome::Completed(r) = outcome {
            r?;
            if crash_at > 0 {
                log_warn!(
                    "workload finished before the armed cut ({crash_at} steps); \
                     cutting at workload end"
                );
            }
        }
        // Realize the storage cut (pending-flush/eviction races), then scan
        // the shadow images BEFORE recovery appends to the rings.
        let mut rng = persiq::util::rng::Xoshiro256::seed_from(seed);
        topo.crash(&mut rng);
        let scans = obs::flight::scan(&topo);
        let tl = obs::flight::timeline(&scans);

        broker.recover();
        let rep = broker.reconcile_report(0);
        // Drain the recovered queue: the post-recovery truth the recorded
        // events are checked against. (`take` skips DONE jobs by design.)
        let mut survivors: Vec<u64> = Vec::new();
        while let Some((jid, _)) = broker.take(consumer)? {
            survivors.push(jid.0.to_u64());
        }
        let survivor_set: std::collections::HashSet<u64> = survivors.iter().copied().collect();
        let taken_set: std::collections::HashSet<u64> =
            taken.borrow().iter().copied().collect();
        let state_of =
            |h: u64| broker.state(consumer, persiq::coordinator::JobId(GAddr::from_u64(h)));

        // ---- Cross-checks: recorded events vs recovered truth ----
        let mut violations: Vec<String> = Vec::new();
        for &h in &tl.broker_submits {
            match state_of(h) {
                persiq::coordinator::JobState::Unwritten => violations.push(format!(
                    "durable BrokerSubmit {h:#x}: job record unreadable after recovery"
                )),
                persiq::coordinator::JobState::Pending if !survivor_set.contains(&h) => {
                    violations.push(format!(
                        "durable BrokerSubmit {h:#x}: still PENDING but not redelivered"
                    ))
                }
                _ => {}
            }
        }
        for &h in &tl.broker_acks {
            if state_of(h) != persiq::coordinator::JobState::Done {
                violations
                    .push(format!("durable BrokerAck {h:#x}: job not DONE after recovery"));
            }
            if survivor_set.contains(&h) {
                violations.push(format!("durable BrokerAck {h:#x}: DONE job redelivered"));
            }
        }
        let (mut durable_enqs, mut durable_deqs, mut inflight) = (0usize, 0usize, 0usize);
        for line in &tl.threads {
            inflight += line.inflight.len();
            for &h in &line.durable_enqs {
                durable_enqs += 1;
                // A durably-queued handle must survive: redelivered, or its
                // job already DONE, or (at-least-once) already returned to a
                // pre-crash `take` whose dequeue log sealed.
                if state_of(h) != persiq::coordinator::JobState::Done
                    && !survivor_set.contains(&h)
                    && !taken_set.contains(&h)
                {
                    violations.push(format!(
                        "durable OpEnq {h:#x} (tid {}): handle lost by recovery",
                        line.tid
                    ));
                }
            }
            for &h in &line.durable_deqs {
                durable_deqs += 1;
                if state_of(h) == persiq::coordinator::JobState::Unwritten {
                    violations.push(format!(
                        "durable OpDeq {h:#x} (tid {}): dequeued a job with no record",
                        line.tid
                    ));
                }
                if state_of(h) == persiq::coordinator::JobState::Done
                    && survivor_set.contains(&h)
                {
                    violations
                        .push(format!("durable OpDeq {h:#x}: DONE job redelivered anyway"));
                }
            }
        }
        if let Some(&(e, k, _)) =
            tl.plan_commits.iter().filter(|(_, _, ph)| *ph >= 1).max_by_key(|(e, _, _)| *e)
        {
            if rep.plan.0 < e {
                violations.push(format!(
                    "durable plan freeze epoch {e} (k={k}) not adopted (recovered epoch {})",
                    rep.plan.0
                ));
            }
        }
        if rep.mismatches() != 0 {
            violations.push(format!(
                "ReconcileReport mismatches: {} (stranded-pending={} queued-done={} \
                 queued-unwritten={} queued-duplicates={})",
                rep.mismatches(),
                rep.stranded_pending,
                rep.queued_done,
                rep.queued_unwritten,
                rep.queued_duplicates
            ));
        }
        // Survivors the rings never saw: each sits beyond the open ring tail
        // (its seal psync never completed — the entry luck-landed or was
        // never written). Informational, not a violation; meaningless once a
        // ring wrapped.
        let recorded: std::collections::HashSet<u64> = tl
            .events
            .iter()
            .filter(|e| {
                matches!(
                    e.kind,
                    obs::FlightKind::OpEnq | obs::FlightKind::BrokerSubmit
                )
            })
            .map(|e| e.payload)
            .collect();
        let unrecorded = survivors.iter().filter(|h| !recorded.contains(h)).count();

        // ---- Human report ----
        println!(
            "forensics ({}; pools={}, {}; cut {}):",
            a.get("queue").unwrap_or("sharded"),
            topo.len(),
            if crashed { "crashed mid-op" } else { "cut at workload end" },
            if crash_at > 0 { format!("--crash-at {crash_at}") } else { "quiescent".into() }
        );
        println!(
            "  rings       : {} events across {} pools ({} certified-durable kinds: \
             enq={} deq={} submit={} ack={}), {} in-flight at cut, {} torn, {} overwritten",
            tl.events.len(),
            scans.iter().filter(|s| s.present).count(),
            tl.threads.iter().map(|t| t.seals).sum::<usize>(),
            durable_enqs,
            durable_deqs,
            tl.broker_submits.len(),
            tl.broker_acks.len(),
            inflight,
            tl.torn,
            tl.overwritten
        );
        let mut table = Csv::new(vec!["clock", "pool", "tid", "seq", "kind", "payload", "durable"]);
        let skip = tl.events.len().saturating_sub(nrows);
        for (ring_durable, e) in tl.events.iter().skip(skip).map(|e| {
            let durable = scans
                .iter()
                .flat_map(|s| &s.rings)
                .find(|r| r.tid == e.tid && r.events.iter().any(|x| x == e))
                .map(|r| r.certified(e))
                .unwrap_or(false);
            (durable, e)
        }) {
            table.row(vec![
                e.clock.to_string(),
                e.socket.to_string(),
                e.tid.to_string(),
                e.seq.to_string(),
                e.kind.name().to_string(),
                format!("{:#x}", e.payload),
                if ring_durable { "yes".into() } else { "open-tail".to_string() },
            ]);
        }
        for line in table.to_table().lines() {
            println!("    {line}");
        }
        for t in &tl.threads {
            println!(
                "  tid {:>3}     : last durable {} | {} durable enq, {} durable deq, \
                 {} in-flight",
                t.tid,
                t.last_durable
                    .map(|e| format!("{} @clock {}", e.kind.name(), e.clock))
                    .unwrap_or_else(|| "-".into()),
                t.durable_enqs.len(),
                t.durable_deqs.len(),
                t.inflight.len()
            );
        }
        println!(
            "  recovery    : submitted={} done={} pending={} | redelivered={} \
             unrecorded-beyond-tail={} | plan epoch={} k={}",
            rep.audit.submitted,
            rep.audit.done,
            rep.audit.pending,
            survivors.len(),
            unrecorded,
            rep.plan.0,
            rep.plan.1
        );
        println!("  psync/pwb by attribution site:");
        for line in obs::render_site_ledger(&topo.site_ledger(), 0).lines() {
            println!("    {line}");
        }
        for v in &violations {
            log_warn!("forensics violation: {v}");
        }

        // ---- JSON report ----
        if let Some(path) = a.get("out") {
            use persiq::util::report::Json;
            let mut threads = Vec::new();
            for t in &tl.threads {
                threads.push(
                    Json::obj()
                        .push("tid", Json::Num(t.tid as f64))
                        .push(
                            "last_durable",
                            t.last_durable
                                .map(|e| Json::Str(e.kind.name().into()))
                                .unwrap_or(Json::Null),
                        )
                        .push("durable_enqs", Json::Num(t.durable_enqs.len() as f64))
                        .push("durable_deqs", Json::Num(t.durable_deqs.len() as f64))
                        .push("inflight", Json::Num(t.inflight.len() as f64)),
                );
            }
            let report = Json::obj()
                .push("schema", Json::Str("persiq-forensics-v1".into()))
                .push(
                    "config",
                    Json::obj()
                        .push("queue", Json::Str(a.get("queue").unwrap_or("sharded").into()))
                        .push("producers", Json::Num(producers as f64))
                        .push("jobs", Json::Num(jobs as f64))
                        .push("crash_at", Json::Num(crash_at as f64))
                        .push("resize", Json::Num(resize_to as f64))
                        .push("seed", Json::Num(seed as f64)),
                )
                .push("crashed", Json::Bool(crashed))
                .push(
                    "timeline",
                    Json::obj()
                        .push("events", Json::Num(tl.events.len() as f64))
                        .push("durable_enqs", Json::Num(durable_enqs as f64))
                        .push("durable_deqs", Json::Num(durable_deqs as f64))
                        .push("broker_submits", Json::Num(tl.broker_submits.len() as f64))
                        .push("broker_acks", Json::Num(tl.broker_acks.len() as f64))
                        .push("plan_commits", Json::Num(tl.plan_commits.len() as f64))
                        .push("inflight", Json::Num(inflight as f64))
                        .push("torn", Json::Num(tl.torn as f64))
                        .push("overwritten", Json::Num(tl.overwritten as f64))
                        .push("threads", Json::Arr(threads)),
                )
                .push(
                    "crosscheck",
                    Json::obj()
                        .push("submitted", Json::Num(rep.audit.submitted as f64))
                        .push("done", Json::Num(rep.audit.done as f64))
                        .push("pending", Json::Num(rep.audit.pending as f64))
                        .push("redelivered", Json::Num(survivors.len() as f64))
                        .push("unrecorded_beyond_tail", Json::Num(unrecorded as f64))
                        .push("mismatches", Json::Num(rep.mismatches() as f64)),
                )
                .push(
                    "violations",
                    Json::Arr(violations.iter().map(|v| Json::Str(v.clone())).collect()),
                )
                .push("pass", Json::Bool(violations.is_empty()));
            report.save(std::path::Path::new(path))?;
            println!("  [report -> {path}]");
        }

        anyhow::ensure!(
            violations.is_empty(),
            "forensics cross-check found {} unexplained discrepancies",
            violations.len()
        );
        println!("  flight-recorder cross-check holds ({} events explained)", tl.events.len());
        Ok(())
    })
}

fn cmd_micro(args: &[String]) -> Result<()> {
    let cmd = Command::new("micro", "pmem primitive cost microbenchmark")
        .opt_default("iters", "iterations per primitive", "100000")
        .flag("wallclock", "use wall-clock spin metering");
    let a = cmd.parse(args)?;
    let iters = a.get_parse::<u64>("iters", 100_000)?;
    let mut cfg = Config::load_default();
    if a.flag("wallclock") {
        cfg.pmem.cost.meter = MeterMode::WallclockSpin;
    }
    let pool = Arc::new(PmemPool::new(cfg.pmem.clone()));
    let mut suite = Suite::new("micro_pmem_cli", "pmem primitive simulated costs");
    let cold = pool.alloc_lines(1);
    let hot = pool.alloc_lines(1);
    // Warm the hot line's accessor mask from 8 thread ids.
    for t in 0..8 {
        let _ = pool.fai(t, hot);
    }
    let run = |name: &str, suite: &mut Suite, f: &dyn Fn(u64)| {
        let before = pool.vtime(0);
        for i in 0..iters {
            f(i);
        }
        let per_op = (pool.vtime(0) - before) as f64 / iters as f64;
        suite.measure(name, 1.0, || per_op);
    };
    run("fai_uncontended", &mut suite, &|_| {
        let _ = pool.fai(0, cold);
    });
    run("fai_hot", &mut suite, &|_| {
        let _ = pool.fai(0, hot);
    });
    run("pwb_swsr+psync", &mut suite, &|_| {
        pool.pwb(0, cold);
        pool.psync(0);
    });
    run("pwb_hot+psync", &mut suite, &|_| {
        pool.pwb(0, hot);
        pool.psync(0);
    });
    suite.finish()?;
    let c = &cfg.pmem.cost;
    println!(
        "model: atomic={}ns conflict={}ns/accessor pwb={}ns (+{}ns/accessor hot) psync={}ns",
        c.atomic_ns, c.conflict_ns, c.pwb_ns, c.pwb_hot_ns, c.psync_ns
    );
    let _ = CostModel::default();
    Ok(())
}

/// `persiq obs`: the observability zero-to-aha — drive a short,
/// deterministic workload across the whole stack (sharded work queue
/// under a broker, then an async completion-layer burst over the same
/// queue), and dump every metrics surface: the psync-by-site ledger
/// table (the paper's `1/B + 1/K` accounting, live) and the combined
/// Prometheus text of the registry, pmem, sharded, async and broker
/// families.
fn cmd_obs(args: &[String]) -> Result<()> {
    let cmd = Command::new(
        "obs",
        "observability dump: run a short workload, print Prometheus metrics + psync site ledger",
    )
    .opt_default("producers", "producer (submit) thread slots", "2")
    .opt_default("jobs", "jobs per producer", "200")
    .opt_default("consume", "fraction of submitted jobs to take+complete synchronously", "0.75")
    .opt_default("async-jobs", "jobs to push through the async completion layer", "64")
    .opt("trace", "also write a JSONL event trace of the run to this path");
    let cmd = QueueArgs::register_async(QueueArgs::register(cmd));
    let a = cmd.parse(args)?;
    let mut cfg = Config::load_default();
    QueueArgs::apply(&mut cfg, &a)?;
    let producers = a.get_parse::<usize>("producers", 2)?;
    let jobs = a.get_parse::<usize>("jobs", 200)?;
    let consume = a.get_parse::<f64>("consume", 0.75)?.clamp(0.0, 1.0);
    let async_jobs = a.get_parse::<usize>("async-jobs", 64)?;
    // Everything below runs on the caller thread except the flusher
    // workers: tids [0, producers) submit, `consumer` takes/completes,
    // the flushers own [producers + 1, producers + 1 + flushers).
    let consumer = producers;
    let nthreads = producers + 1 + cfg.asyncq.flushers;

    with_trace(&a, || {
        let topo = cfg.build_topology();
        let broker = Arc::new(
            Broker::new_sharded(&topo, nthreads, 1 << 16, cfg.queue.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?,
        );

        // Sync phase: submit everything, consume a fraction — populates
        // the BatchFlush/DeqFlush/BrokerAck ledger rows and the broker's
        // job-state families.
        for p in 0..producers {
            broker.attach_worker(p);
            for i in 0..jobs {
                let payload = format!("obs:p{p}:{i}").into_bytes();
                broker.submit(p, &payload[..payload.len().min(48)])?;
            }
            broker.detach_worker(p);
        }
        broker.attach_worker(consumer);
        let target = ((producers * jobs) as f64 * consume) as usize;
        let mut completed = 0usize;
        while completed < target {
            let Some((jid, _)) = broker.take(consumer)? else { break };
            if broker.complete(consumer, jid)? {
                completed += 1;
            }
        }

        // Async burst: the same queue through the completion layer, so
        // the async families (ring occupancy, flush latency, resolved
        // counts) and future-lifecycle trace events are live too.
        let mut async_fams = Vec::new();
        if async_jobs > 0 {
            let aq =
                broker.async_layer(cfg.asyncq.clone()).map_err(|e| anyhow::anyhow!("{e}"))?;
            let flusher = aq.spawn_flusher(consumer + 1);
            let mut submits = Vec::with_capacity(async_jobs);
            for i in 0..async_jobs {
                let payload = format!("obs:async:{i}").into_bytes();
                let (_id, fut) = broker
                    .submit_async(consumer, &payload[..payload.len().min(48)], &aq)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                submits.push(fut);
            }
            for fut in submits {
                fut.wait().map_err(|e| anyhow::anyhow!("submit future: {e}"))?;
            }
            let mut acks = Vec::new();
            for _ in 0..async_jobs {
                match broker.take_async(&aq).wait() {
                    Ok(Some(h)) => {
                        if let Some((jid, _)) = broker.resolve_take(consumer, h) {
                            acks.push(broker.ack_async(jid, &aq));
                        }
                    }
                    Ok(None) => break,
                    Err(e) => anyhow::bail!("take future: {e}"),
                }
            }
            completed += acks.len();
            for ack in acks {
                let _ = ack.wait();
            }
            async_fams = aq.metric_families();
            flusher.stop();
        }
        broker.quiesce();

        // Exposition: the human ledger table first, then one combined
        // Prometheus dump (family names are disjoint across layers).
        let ledger = topo.site_ledger();
        println!("== psync/pwb by attribution site ==");
        print!("{}", obs::render_site_ledger(&ledger, completed as u64));
        println!();
        println!("== Prometheus metrics ==");
        let mut fams = obs::registry().families();
        fams.extend(topo.metric_families());
        fams.extend(broker.metric_families(consumer));
        fams.extend(async_fams);
        fams.extend(obs::ledger_families(&ledger));
        print!("{}", obs::render(&fams));
        Ok(())
    })
}
