//! Per-thread operation counters (relaxed increments on cache-padded slots;
//! aggregated by the bench harness — e.g. the persistence-principles
//! ablation reports `pwb`/`psync` counts per operation).
//!
//! `pwb`/`psync` counts are additionally attributed to the issuing
//! [`ObsSite`] (per-site ledger arrays), so the paper's persistence
//! accounting can be checked per code path, not just in aggregate; see
//! [`crate::obs::site`].
//!
//! ## False-sharing audit (epoch-pinning PR)
//!
//! Every hot counter in this module is already cache-line isolated:
//! [`PoolStats`] wraps each thread's whole [`OpCounters`] block —
//! including both per-site arrays — in a `CachePadded` slot, and a
//! thread only ever touches its own slot, so the ~200-byte struct spans
//! lines no other thread writes. The pools' shared per-thread vclocks
//! are likewise `CachePadded` (see `pool.rs::SharedState`; its `homes`
//! array is unpadded but write-once at construction and read-only
//! after). The counters that *did* false-share — multi-writer atomics
//! packed into one line — lived above this layer and were padded in the
//! same PR: `ShardedQueue`'s `ResizeCells` (every dequeuer bumps
//! `drained_from_frozen` during a drain) and the async layer's
//! `AsyncStats`.

use crossbeam_utils::CachePadded;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::site::{ObsSite, SiteLedger, SITE_COUNT};

/// Counters for one thread.
#[derive(Default)]
pub struct OpCounters {
    pub loads: AtomicU64,
    pub stores: AtomicU64,
    pub rmws: AtomicU64,
    pub cas_failures: AtomicU64,
    pub pwbs: AtomicU64,
    pub pfences: AtomicU64,
    pub psyncs: AtomicU64,
    pub conflicts: AtomicU64,
    /// Cross-socket accesses: pwbs/RMWs issued by a thread whose home
    /// socket differs from the target pool's socket (multi-pool
    /// topologies only — always 0 on a single pool).
    pub remote_ops: AtomicU64,
    /// `psyncs` split by attribution site (indexed by
    /// [`ObsSite::index`]; sums to `psyncs`).
    pub psync_site: [AtomicU64; SITE_COUNT],
    /// `pwbs` split by attribution site (sums to `pwbs`).
    pub pwb_site: [AtomicU64; SITE_COUNT],
}

// Counters are single-writer (one thread per slot): plain load+store
// avoids the lock-prefixed RMW on the hot path (~20 cycles each).
macro_rules! bump {
    ($self:ident . $field:ident) => {{
        let v = $self.$field.load(Ordering::Relaxed);
        $self.$field.store(v + 1, Ordering::Relaxed)
    }};
}

impl OpCounters {
    #[inline]
    pub fn load(&self) {
        bump!(self.loads);
    }
    #[inline]
    pub fn store(&self) {
        bump!(self.stores);
    }
    #[inline]
    pub fn rmw(&self) {
        bump!(self.rmws);
    }
    #[inline]
    pub fn cas_failure(&self) {
        bump!(self.cas_failures);
    }
    #[inline]
    pub fn pwb(&self) {
        self.pwb_at(ObsSite::Op);
    }
    /// Count a `pwb` attributed to `site` (the pmem pool passes the
    /// calling thread's ambient [`crate::obs::current_site`]).
    #[inline]
    pub fn pwb_at(&self, site: ObsSite) {
        bump!(self.pwbs);
        let c = &self.pwb_site[site.index()];
        let v = c.load(Ordering::Relaxed);
        c.store(v + 1, Ordering::Relaxed);
    }
    #[inline]
    pub fn pfence(&self) {
        bump!(self.pfences);
    }
    #[inline]
    pub fn psync(&self) {
        self.psync_at(ObsSite::Op);
    }
    /// Count a `psync` attributed to `site`.
    #[inline]
    pub fn psync_at(&self, site: ObsSite) {
        bump!(self.psyncs);
        let c = &self.psync_site[site.index()];
        let v = c.load(Ordering::Relaxed);
        c.store(v + 1, Ordering::Relaxed);
    }
    #[inline]
    pub fn conflict(&self, n: u64) {
        let v = self.conflicts.load(Ordering::Relaxed);
        self.conflicts.store(v + n, Ordering::Relaxed);
    }
    #[inline]
    pub fn remote_op(&self) {
        bump!(self.remote_ops);
    }

    fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            loads: self.loads.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            rmws: self.rmws.load(Ordering::Relaxed),
            cas_failures: self.cas_failures.load(Ordering::Relaxed),
            pwbs: self.pwbs.load(Ordering::Relaxed),
            pfences: self.pfences.load(Ordering::Relaxed),
            psyncs: self.psyncs.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
            remote_ops: self.remote_ops.load(Ordering::Relaxed),
            psync_site: std::array::from_fn(|i| self.psync_site[i].load(Ordering::Relaxed)),
            pwb_site: std::array::from_fn(|i| self.pwb_site[i].load(Ordering::Relaxed)),
        }
    }

    fn reset(&self) {
        for c in [
            &self.loads,
            &self.stores,
            &self.rmws,
            &self.cas_failures,
            &self.pwbs,
            &self.pfences,
            &self.psyncs,
            &self.conflicts,
            &self.remote_ops,
        ] {
            c.store(0, Ordering::Relaxed);
        }
        for c in self.psync_site.iter().chain(self.pwb_site.iter()) {
            c.store(0, Ordering::Relaxed);
        }
    }
}

/// A plain-value snapshot of one thread's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub loads: u64,
    pub stores: u64,
    pub rmws: u64,
    pub cas_failures: u64,
    pub pwbs: u64,
    pub pfences: u64,
    pub psyncs: u64,
    pub conflicts: u64,
    pub remote_ops: u64,
    pub psync_site: [u64; SITE_COUNT],
    pub pwb_site: [u64; SITE_COUNT],
}

impl CounterSnapshot {
    pub fn add(&mut self, o: &CounterSnapshot) {
        self.loads += o.loads;
        self.stores += o.stores;
        self.rmws += o.rmws;
        self.cas_failures += o.cas_failures;
        self.pwbs += o.pwbs;
        self.pfences += o.pfences;
        self.psyncs += o.psyncs;
        self.conflicts += o.conflicts;
        self.remote_ops += o.remote_ops;
        for (a, b) in self.psync_site.iter_mut().zip(o.psync_site.iter()) {
            *a += b;
        }
        for (a, b) in self.pwb_site.iter_mut().zip(o.pwb_site.iter()) {
            *a += b;
        }
    }

    /// Total persistence instructions (pwb + pfence + psync).
    pub fn persistence_instructions(&self) -> u64 {
        self.pwbs + self.pfences + self.psyncs
    }

    /// The per-site ledger view of this snapshot.
    pub fn site_ledger(&self) -> SiteLedger {
        SiteLedger { psyncs: self.psync_site, pwbs: self.pwb_site }
    }
}

/// All threads' counters.
pub struct PoolStats {
    per_thread: Vec<CachePadded<OpCounters>>,
}

impl PoolStats {
    pub fn new(max_threads: usize) -> Self {
        Self {
            per_thread: (0..max_threads)
                .map(|_| CachePadded::new(OpCounters::default()))
                .collect(),
        }
    }

    #[inline]
    pub fn of(&self, tid: usize) -> &OpCounters {
        &self.per_thread[tid]
    }

    /// Sum across all threads.
    pub fn total(&self) -> CounterSnapshot {
        let mut t = CounterSnapshot::default();
        for c in &self.per_thread {
            t.add(&c.snapshot());
        }
        t
    }

    /// Per-thread snapshots.
    pub fn snapshots(&self) -> Vec<CounterSnapshot> {
        self.per_thread.iter().map(|c| c.snapshot()).collect()
    }

    /// The per-site persistence ledger, summed across threads.
    pub fn site_ledger(&self) -> SiteLedger {
        self.total().site_ledger()
    }

    /// Zero all counters (between bench phases).
    pub fn reset(&self) {
        for c in &self.per_thread {
            c.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_and_total() {
        let s = PoolStats::new(4);
        s.of(0).pwb();
        s.of(0).pwb();
        s.of(1).psync();
        s.of(3).rmw();
        s.of(3).conflict(5);
        let t = s.total();
        assert_eq!(t.pwbs, 2);
        assert_eq!(t.psyncs, 1);
        assert_eq!(t.rmws, 1);
        assert_eq!(t.conflicts, 5);
        assert_eq!(t.persistence_instructions(), 3);
    }

    #[test]
    fn reset_zeroes() {
        let s = PoolStats::new(2);
        s.of(0).load();
        s.of(1).store();
        s.reset();
        assert_eq!(s.total(), CounterSnapshot::default());
    }

    #[test]
    fn snapshots_per_thread() {
        let s = PoolStats::new(2);
        s.of(1).cas_failure();
        let snaps = s.snapshots();
        assert_eq!(snaps[0].cas_failures, 0);
        assert_eq!(snaps[1].cas_failures, 1);
    }

    #[test]
    fn site_attribution_sums_to_totals() {
        let s = PoolStats::new(2);
        s.of(0).psync_at(ObsSite::BatchFlush);
        s.of(0).psync_at(ObsSite::BatchFlush);
        s.of(1).psync_at(ObsSite::PlanCommit);
        s.of(0).psync(); // untyped → Op
        s.of(1).pwb_at(ObsSite::Recovery);
        s.of(1).pwb(); // untyped → Op
        let t = s.total();
        assert_eq!(t.psyncs, 4);
        assert_eq!(t.pwbs, 2);
        let l = s.site_ledger();
        assert_eq!(l.psyncs_at(ObsSite::BatchFlush), 2);
        assert_eq!(l.psyncs_at(ObsSite::PlanCommit), 1);
        assert_eq!(l.psyncs_at(ObsSite::Op), 1);
        assert_eq!(l.pwbs_at(ObsSite::Recovery), 1);
        assert_eq!(l.pwbs_at(ObsSite::Op), 1);
        assert_eq!(l.total_psyncs(), t.psyncs, "ledger must cover every psync");
        assert_eq!(l.total_pwbs(), t.pwbs, "ledger must cover every pwb");
        s.reset();
        assert_eq!(s.site_ledger(), SiteLedger::default());
    }
}
