//! Multi-pool NVM topology: an ordered set of independent [`PmemPool`]s
//! ("sockets"), each with its own arena, NVM bandwidth chain, stats and
//! crash-time nondeterminism — sharing one set of per-thread virtual
//! clocks and one crash cut.
//!
//! The paper's core claim is that moving persistence instructions onto
//! low-contention variables lets different threads' `pwb`/`psync`
//! latencies overlap. On real multi-DIMM / multi-socket machines that
//! overlap is bounded by *per-socket* NVM bandwidth, and a `pwb` that
//! crosses the socket interconnect pays a hefty premium. A single
//! [`PmemPool`] cannot express either effect; a [`Topology`] can:
//!
//! * each pool owns an independent `nvm_chain` (per-socket DIMM
//!   bandwidth) and its own line stamps/stats;
//! * every thread has a **home socket** (assigned round-robin by
//!   [`crate::util::affinity::place`], the paper's §5 pinning order);
//!   primitives on a pool whose socket differs from the caller's home
//!   charge [`CostModel::remote_pwb_ns`] / [`CostModel::remote_rmw_ns`]
//!   (see [`crate::pmem::latency`]);
//! * the step countdown, crash flag, epoch counter and virtual clocks
//!   are shared, so [`Topology::crash`] snapshots **all** pools at one
//!   machine-wide cut — exactly like a real power failure.
//!
//! [`Topology::single`] is the degenerate one-pool case: socket 0, every
//! thread homed on it, no penalty ever charged — byte- and
//! cost-identical to the pre-topology single-pool substrate, which is
//! the refactor's compatibility bar.
//!
//! [`CostModel::remote_pwb_ns`]: crate::pmem::CostModel::remote_pwb_ns
//! [`CostModel::remote_rmw_ns`]: crate::pmem::CostModel::remote_rmw_ns

use std::sync::Arc;

use super::pool::SharedState;
use super::stats::CounterSnapshot;
use super::{Hotness, PAddr, PmemConfig, PmemPool};
use crate::util::affinity::place;
use crate::util::rng::Xoshiro256;

/// Upper bound on pools per topology (the pool index must fit the
/// [`GAddr`] packing and the sharded queue's pool bitmasks).
pub const MAX_POOLS: usize = 16;

/// A pool-qualified persistent address: `{pool, PAddr}`. The packed
/// `u64` form (`pool` in bits 32.., word index below) is what persistent
/// structures store when a handle may point into any pool — pool 0
/// packs to exactly the bare `PAddr` value, so single-pool images stay
/// readable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GAddr {
    /// Pool (socket) index within the topology.
    pub pool: u32,
    /// Word address within that pool's arena.
    pub addr: PAddr,
}

impl GAddr {
    /// Qualify a bare address with its pool.
    #[inline]
    pub fn new(pool: usize, addr: PAddr) -> GAddr {
        GAddr { pool: pool as u32, addr }
    }

    /// Address `k` words later in the same pool.
    #[inline]
    pub fn add(self, k: usize) -> GAddr {
        GAddr { pool: self.pool, addr: self.addr.add(k) }
    }

    /// Is this the null address (of any pool)?
    #[inline]
    pub fn is_null(self) -> bool {
        self.addr.is_null()
    }

    /// Pack for storage in a persistent word: pool in bits 32..48, word
    /// index in bits 0..32. Far below [`crate::queues::MAX_ITEM`], so a
    /// packed handle is always a valid queue item.
    #[inline]
    pub fn to_u64(self) -> u64 {
        ((self.pool as u64) << 32) | self.addr.to_u64()
    }

    /// Unpack from a persistent word value. The pool field is masked to
    /// the documented 16-bit packing; bits 48.. must be zero (a value
    /// with them set was never produced by [`GAddr::to_u64`] — debug
    /// builds assert, so an encoding bug surfaces at the decode site
    /// instead of as an opaque pool-index panic later).
    #[inline]
    pub fn from_u64(v: u64) -> GAddr {
        debug_assert_eq!(v >> 48, 0, "GAddr::from_u64: bits 48.. set in {v:#x}");
        GAddr { pool: ((v >> 32) & 0xFFFF) as u32, addr: PAddr(v as u32) }
    }
}

/// How a sharded structure maps its shards (and their batch logs) onto a
/// topology's pools. Parsed from `--placement` / `[topology] placement`.
#[derive(Clone, Debug, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Shards stripe round-robin across pools and every thread's
    /// round-robin ticket cycles over **all** shards: traffic interleaves
    /// across sockets (the classic striped layout — maximum bandwidth
    /// spread, constant cross-socket `pwb` traffic).
    #[default]
    Interleave,
    /// Shards stripe round-robin across pools, but each thread's
    /// enqueue ticket cycles only over the shards of its **home** socket
    /// (falling back to all shards when its home pool holds none), and
    /// its dequeue scan probes home shards first. Traffic stays
    /// socket-local; cross-socket `pwb`s happen only when stealing work
    /// from sibling sockets.
    Colocate,
    /// Explicit shard→pool map: shard `s` lives on `pools[s % len]`.
    /// Dispatch behaves like [`PlacementPolicy::Colocate`] (home shards
    /// preferred).
    Pinned(Vec<usize>),
}

impl PlacementPolicy {
    /// Parse `interleave` | `colocate` | `pinned:<p0,p1,...>`.
    pub fn parse(s: &str) -> Result<PlacementPolicy, String> {
        let t = s.trim();
        match t {
            "interleave" => return Ok(PlacementPolicy::Interleave),
            "colocate" => return Ok(PlacementPolicy::Colocate),
            _ => {}
        }
        if let Some(list) = t.strip_prefix("pinned:") {
            let pools: Result<Vec<usize>, _> = list
                .split(',')
                .map(str::trim)
                .filter(|p| !p.is_empty())
                .map(str::parse::<usize>)
                .collect();
            let pools = pools.map_err(|_| format!("bad pinned pool list {list:?}"))?;
            if pools.is_empty() {
                return Err("pinned placement needs at least one pool id".to_string());
            }
            if let Some(&p) = pools.iter().find(|&&p| p >= MAX_POOLS) {
                return Err(format!("pinned pool id {p} exceeds MAX_POOLS ({MAX_POOLS})"));
            }
            return Ok(PlacementPolicy::Pinned(pools));
        }
        Err(format!(
            "unknown placement {t:?} (expected interleave | colocate | pinned:<p0,p1,...>)"
        ))
    }

    /// The pool shard `s` lives on, for a topology of `npools` pools.
    /// Pinned ids are returned verbatim — constructors reject maps that
    /// name a pool outside the topology.
    pub fn pool_of(&self, shard: usize, npools: usize) -> usize {
        match self {
            PlacementPolicy::Interleave | PlacementPolicy::Colocate => {
                shard % npools.max(1)
            }
            PlacementPolicy::Pinned(list) => list[shard % list.len()],
        }
    }

    /// Do threads prefer their home socket's shards?
    pub fn prefers_home(&self) -> bool {
        !matches!(self, PlacementPolicy::Interleave)
    }
}

impl std::str::FromStr for PlacementPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        PlacementPolicy::parse(s)
    }
}

impl std::fmt::Display for PlacementPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlacementPolicy::Interleave => write!(f, "interleave"),
            PlacementPolicy::Colocate => write!(f, "colocate"),
            PlacementPolicy::Pinned(list) => {
                write!(f, "pinned:")?;
                for (i, p) in list.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{p}")?;
                }
                Ok(())
            }
        }
    }
}

/// An ordered set of independent NVM pools sharing one clock/crash
/// domain. Cheap to clone (pools are `Arc`-shared). See module docs.
#[derive(Clone)]
pub struct Topology {
    pools: Vec<Arc<PmemPool>>,
    shared: Arc<SharedState>,
}

impl Topology {
    /// Build `npools` pools, each with `cfg.capacity_words` of its own
    /// arena (per-socket DIMMs, not a split arena), and home every
    /// thread id round-robin across the sockets (the paper's §5 pinning
    /// order via [`crate::util::affinity::place`]).
    ///
    /// Panics if `npools` is 0 or exceeds [`MAX_POOLS`] — topology sizes
    /// come from validated config/CLI paths.
    pub fn new(cfg: PmemConfig, npools: usize) -> Topology {
        assert!(
            npools >= 1 && npools <= MAX_POOLS,
            "pool count must be in 1..={MAX_POOLS}, got {npools}"
        );
        let shared = Arc::new(SharedState::new());
        let pools: Vec<Arc<PmemPool>> = (0..npools)
            .map(|socket| {
                let mut pcfg = cfg.clone();
                // Independent crash nondeterminism per socket.
                pcfg.seed = cfg.seed.wrapping_add(socket as u64);
                Arc::new(PmemPool::with_shared(pcfg, socket, Arc::clone(&shared)))
            })
            .collect();
        for tid in 0..super::MAX_THREADS {
            shared.set_home(tid, place(tid, npools, 1).socket);
        }
        Topology { pools, shared }
    }

    /// The degenerate single-pool topology — cost- and layout-identical
    /// to a bare [`PmemPool`].
    pub fn single(cfg: PmemConfig) -> Topology {
        Topology::new(cfg, 1)
    }

    /// Wrap an existing standalone pool (shares its clock/crash state).
    /// Used by compatibility constructors that still accept a bare pool.
    pub fn from_pool(pool: &Arc<PmemPool>) -> Topology {
        Topology { pools: vec![Arc::clone(pool)], shared: Arc::clone(pool.shared()) }
    }

    /// Number of pools (sockets).
    pub fn len(&self) -> usize {
        self.pools.len()
    }

    /// Is this the degenerate single-pool case?
    pub fn is_empty(&self) -> bool {
        false // a topology always has >= 1 pool; method exists for clippy's len-without-is-empty
    }

    /// All pools, in socket order.
    pub fn pools(&self) -> &[Arc<PmemPool>] {
        &self.pools
    }

    /// Pool `i`.
    pub fn pool(&self, i: usize) -> &Arc<PmemPool> {
        &self.pools[i]
    }

    /// Pool 0 — where single-pool algorithms and topology-wide control
    /// state live.
    pub fn primary(&self) -> &Arc<PmemPool> {
        &self.pools[0]
    }

    /// Thread `tid`'s home socket (raw assignment — compare against
    /// [`PmemPool::socket`] for penalty semantics).
    pub fn home_of(&self, tid: usize) -> usize {
        self.shared.home_of(tid)
    }

    /// Thread `tid`'s home pool *index within this topology* (the raw
    /// home clamped into range — differs from `home_of` only for
    /// [`Topology::from_pool`] wrappers around part of a larger
    /// topology).
    pub fn home_pool(&self, tid: usize) -> usize {
        self.shared.home_of(tid) % self.pools.len()
    }

    // ------------------------------------------------------------------
    // Coordinated control plane
    // ------------------------------------------------------------------

    /// Set the active worker count on every pool (bounds Global-line
    /// contention — see [`PmemPool::set_active_threads`]).
    pub fn set_active_threads(&self, n: usize) {
        for p in &self.pools {
            p.set_active_threads(n);
        }
    }

    /// Zero all clocks, stamps, masks and counters on every pool (bench
    /// phase boundary; quiescent).
    pub fn reset_meter(&self) {
        for p in &self.pools {
            p.reset_meter();
        }
    }

    /// Arm the machine-wide crash countdown (primitives on *any* pool
    /// decrement it).
    pub fn arm_crash_after(&self, steps: u64) {
        self.shared.arm_crash_after(steps);
    }

    /// Raise the crash flag immediately.
    pub fn crash_now(&self) {
        self.shared.crash_now();
    }

    /// Commit a coordinated full-system crash: every pool's pending
    /// flushes race the failure and its volatile state dies, all at one
    /// cut; the shared epoch advances **once**. Call only after all
    /// worker threads have unwound (same contract as
    /// [`PmemPool::crash`]).
    pub fn crash(&self, rng: &mut Xoshiro256) {
        for p in &self.pools {
            p.crash_storage(rng);
        }
        self.shared.finish_crash();
    }

    /// Topology-wide crash epoch.
    pub fn epoch(&self) -> u64 {
        self.shared.epoch()
    }

    /// Thread `tid`'s virtual clock (one timeline across all pools).
    pub fn vtime(&self, tid: usize) -> u64 {
        self.shared.vtime(tid)
    }

    /// Simulated makespan: max virtual clock across threads.
    pub fn max_vtime(&self) -> u64 {
        self.shared.max_vtime()
    }

    /// Operation counters merged across all pools.
    pub fn stats_total(&self) -> CounterSnapshot {
        let mut t = CounterSnapshot::default();
        for p in &self.pools {
            t.add(&p.stats.total());
        }
        t
    }

    /// Per-pool operation counters, in socket order.
    pub fn stats_per_pool(&self) -> Vec<CounterSnapshot> {
        self.pools.iter().map(|p| p.stats.total()).collect()
    }

    /// The per-site persistence ledger, merged across all pools (see
    /// [`crate::obs::site`]).
    pub fn site_ledger(&self) -> crate::obs::SiteLedger {
        let mut l = crate::obs::SiteLedger::default();
        for p in &self.pools {
            l.add(&p.stats.site_ledger());
        }
        l
    }

    /// Prometheus-shaped metric families for the pmem substrate:
    /// per-pool operation counters, the per-site persistence ledger,
    /// and the simulated makespan.
    pub fn metric_families(&self) -> Vec<crate::obs::Family> {
        use crate::obs::{Family, Kind, Sample};
        let per_pool = self.stats_per_pool();
        let scalar = |name: &str, help: &str, get: &dyn Fn(&CounterSnapshot) -> u64| {
            Family::scalar(
                name,
                help,
                Kind::Counter,
                per_pool
                    .iter()
                    .enumerate()
                    .map(|(i, s)| Sample::labelled("pool", i, get(s) as f64))
                    .collect(),
            )
        };
        let mut fams = vec![
            scalar("persiq_pmem_loads_total", "atomic loads", &|s| s.loads),
            scalar("persiq_pmem_stores_total", "atomic stores", &|s| s.stores),
            scalar("persiq_pmem_rmws_total", "atomic RMWs", &|s| s.rmws),
            scalar("persiq_pmem_cas_failures_total", "failed CAS attempts", &|s| {
                s.cas_failures
            }),
            scalar("persiq_pmem_pwbs_total", "pwb instructions", &|s| s.pwbs),
            scalar("persiq_pmem_pfences_total", "pfence instructions", &|s| s.pfences),
            scalar("persiq_pmem_psyncs_total", "psync instructions", &|s| s.psyncs),
            scalar("persiq_pmem_conflicts_total", "line conflicts", &|s| s.conflicts),
            scalar("persiq_pmem_remote_ops_total", "cross-socket pwbs/RMWs", &|s| {
                s.remote_ops
            }),
        ];
        fams.extend(crate::obs::ledger_families(&self.site_ledger()));
        // Allocator tier, per-pool views. Lifecycle totals
        // (`persiq_palloc_{alloc,free,recycled,leaked}_total`) and the
        // process-global high-water gauge live in the obs registry
        // (registered by `pmem::palloc` itself); these families add the
        // per-pool/per-class breakdown under distinct names so a
        // combined exposition never carries duplicate families.
        fams.push(Family::scalar(
            "persiq_palloc_free_segments",
            "free segments on the shared freelist, per pool and size class",
            Kind::Gauge,
            self.pools
                .iter()
                .enumerate()
                .flat_map(|(i, p)| {
                    p.palloc().class_occupancy().into_iter().map(move |(lines, n)| Sample {
                        labels: vec![
                            ("pool".to_string(), i.to_string()),
                            ("lines".to_string(), lines.to_string()),
                        ],
                        value: n as f64,
                    })
                })
                .collect(),
        ));
        fams.push(Family::scalar(
            "persiq_pmem_used_words",
            "bump-arena high-water mark (words carved, never shrinks)",
            Kind::Gauge,
            self.pools
                .iter()
                .enumerate()
                .map(|(i, p)| Sample::labelled("pool", i, p.used_words() as f64))
                .collect(),
        ));
        fams.push(Family::scalar(
            "persiq_pmem_max_vtime_ns",
            "simulated makespan (max thread virtual clock)",
            Kind::Gauge,
            vec![Sample::plain(self.max_vtime() as f64)],
        ));
        fams
    }

    /// Drain the calling thread's pending `pwb`s on **every** pool (one
    /// `psync` per pool that quiesce/recovery paths use when buffered
    /// work may span sockets).
    pub fn psync_all(&self, tid: usize) {
        for p in &self.pools {
            p.psync(tid);
        }
    }

    // ------------------------------------------------------------------
    // Pool-qualified accessors (GAddr)
    // ------------------------------------------------------------------

    /// Bump-allocate `n` words aligned to `align` on pool `pool`.
    pub fn alloc_on(&self, pool: usize, n: usize, align: usize) -> GAddr {
        GAddr::new(pool, self.pools[pool].alloc(n, align))
    }

    /// Allocate whole cache lines on pool `pool`.
    pub fn alloc_lines_on(&self, pool: usize, lines: usize) -> GAddr {
        GAddr::new(pool, self.pools[pool].alloc_lines(lines))
    }

    /// Atomic load through a pool-qualified address.
    #[inline]
    pub fn load(&self, tid: usize, g: GAddr) -> u64 {
        self.pools[g.pool as usize].load(tid, g.addr)
    }

    /// Atomic store through a pool-qualified address.
    #[inline]
    pub fn store(&self, tid: usize, g: GAddr, v: u64) {
        self.pools[g.pool as usize].store(tid, g.addr, v);
    }

    /// CAS through a pool-qualified address.
    #[inline]
    pub fn cas(&self, tid: usize, g: GAddr, old: u64, new: u64) -> bool {
        self.pools[g.pool as usize].cas(tid, g.addr, old, new)
    }

    /// `pwb` through a pool-qualified address (the matching `psync` goes
    /// to the same pool: [`Topology::psync_pool`]).
    #[inline]
    pub fn pwb(&self, tid: usize, g: GAddr) {
        self.pools[g.pool as usize].pwb(tid, g.addr);
    }

    /// `psync` on one pool.
    #[inline]
    pub fn psync_pool(&self, tid: usize, pool: usize) {
        self.pools[pool].psync(tid);
    }

    /// Declare contention of a pool-qualified range.
    pub fn set_hot(&self, g: GAddr, words: usize, h: Hotness) {
        self.pools[g.pool as usize].set_hot(g.addr, words, h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pmem::crash::{install_quiet_crash_hook, run_guarded};
    use crate::pmem::CostModel;

    fn cfg() -> PmemConfig {
        PmemConfig {
            capacity_words: 1 << 12,
            cost: CostModel::default(),
            evict_prob: 0.0,
            pending_flush_prob: 0.0,
            seed: 5,
        }
    }

    #[test]
    fn gaddr_packing_roundtrip_and_pool0_compat() {
        let g = GAddr::new(3, PAddr(12345));
        assert_eq!(GAddr::from_u64(g.to_u64()), g);
        assert_eq!(g.add(7).addr.word(), 12352);
        assert_eq!(g.add(7).pool, 3);
        // Pool 0 packs to the bare PAddr value (single-pool image compat).
        let g0 = GAddr::new(0, PAddr(99));
        assert_eq!(g0.to_u64(), 99);
        assert!(GAddr::new(1, PAddr(0)).is_null());
    }

    #[test]
    fn homes_round_robin_across_sockets() {
        let t = Topology::new(cfg(), 4);
        assert_eq!(t.len(), 4);
        for tid in 0..16 {
            assert_eq!(t.home_of(tid), tid % 4);
            assert_eq!(t.home_pool(tid), tid % 4);
        }
        let s = Topology::single(cfg());
        for tid in 0..16 {
            assert_eq!(s.home_of(tid), 0);
        }
    }

    #[test]
    fn placement_parsing() {
        assert_eq!(PlacementPolicy::parse("interleave"), Ok(PlacementPolicy::Interleave));
        assert_eq!(PlacementPolicy::parse("colocate"), Ok(PlacementPolicy::Colocate));
        assert_eq!(
            PlacementPolicy::parse("pinned:0,1,1"),
            Ok(PlacementPolicy::Pinned(vec![0, 1, 1]))
        );
        assert_eq!(
            PlacementPolicy::parse(" pinned:2 "),
            Ok(PlacementPolicy::Pinned(vec![2]))
        );
        assert!(PlacementPolicy::parse("pinned:").is_err());
        assert!(PlacementPolicy::parse("pinned:a,b").is_err());
        assert!(PlacementPolicy::parse("pinned:9999").is_err());
        assert!(PlacementPolicy::parse("nearest").is_err());
        // FromStr + Display roundtrip.
        let p: PlacementPolicy = "pinned:0,1".parse().unwrap();
        assert_eq!(p.to_string(), "pinned:0,1");
        assert_eq!("colocate".parse::<PlacementPolicy>().unwrap().to_string(), "colocate");
    }

    #[test]
    fn placement_pool_mapping() {
        let i = PlacementPolicy::Interleave;
        let c = PlacementPolicy::Colocate;
        for s in 0..8 {
            assert_eq!(i.pool_of(s, 2), s % 2);
            assert_eq!(c.pool_of(s, 2), s % 2);
        }
        let p = PlacementPolicy::Pinned(vec![1, 0]);
        assert_eq!(p.pool_of(0, 2), 1);
        assert_eq!(p.pool_of(1, 2), 0);
        assert_eq!(p.pool_of(2, 2), 1);
        assert!(!i.prefers_home());
        assert!(c.prefers_home());
        assert!(p.prefers_home());
    }

    #[test]
    fn pools_are_independent_arenas() {
        let t = Topology::new(cfg(), 2);
        let a0 = t.alloc_lines_on(0, 1);
        let a1 = t.alloc_lines_on(1, 1);
        t.store(0, a0, 7);
        t.store(1, a1, 9);
        assert_eq!(t.load(0, a0), 7);
        assert_eq!(t.load(0, a1), 9);
        // Same word index, different pools — no aliasing.
        assert_eq!(a0.addr, a1.addr);
        assert_ne!(a0, a1);
    }

    #[test]
    fn coordinated_crash_is_one_cut() {
        let t = Topology::new(cfg(), 2);
        let a0 = t.alloc_lines_on(0, 1);
        let a1 = t.alloc_lines_on(1, 1);
        // Durable on pool 0; volatile on pool 1.
        t.store(0, a0, 1);
        t.pwb(0, a0);
        t.psync_pool(0, 0);
        t.store(0, a1, 2);
        let mut rng = Xoshiro256::seed_from(3);
        t.crash(&mut rng);
        assert_eq!(t.epoch(), 1, "one crash = one epoch bump, not one per pool");
        assert_eq!(t.load(0, a0), 1, "flushed line survives");
        assert_eq!(t.load(0, a1), 0, "volatile line on the sibling pool dies at the same cut");
    }

    #[test]
    fn countdown_spans_pools_and_unwinds_everywhere() {
        install_quiet_crash_hook();
        let t = Topology::new(cfg(), 2);
        let a0 = t.alloc_lines_on(0, 1);
        let a1 = t.alloc_lines_on(1, 1);
        t.arm_crash_after(10);
        let out = run_guarded(|| {
            for i in 0..100u64 {
                // Alternate pools: the shared countdown must fire even
                // though neither pool sees 10 primitives on its own.
                t.store(0, a0, i);
                t.store(0, a1, i);
            }
        });
        assert!(out.crashed(), "shared countdown must fire across pools");
        let mut rng = Xoshiro256::seed_from(4);
        t.crash(&mut rng);
        t.store(0, a0, 1);
        assert_eq!(t.load(0, a0), 1, "topology usable after the cut");
    }

    #[test]
    fn clocks_are_one_timeline_across_pools() {
        let t = Topology::new(cfg(), 2);
        let a0 = t.alloc_lines_on(0, 1);
        let a1 = t.alloc_lines_on(1, 1);
        t.pool(0).set_hot(a0.addr, 1, Hotness::Private);
        t.pool(1).set_hot(a1.addr, 1, Hotness::Private);
        // Thread 0 (home socket 0): local store then remote store — the
        // clock accumulates across pools instead of running two parallel
        // timelines.
        t.store(0, a0, 1);
        let t_after_local = t.vtime(0);
        assert!(t_after_local > 0);
        t.store(0, a1, 1);
        assert!(t.vtime(0) > t_after_local, "cross-pool work extends the same timeline");
        assert_eq!(t.max_vtime(), t.vtime(0));
        t.reset_meter();
        assert_eq!(t.max_vtime(), 0);
    }

    #[test]
    fn merged_stats_cover_all_pools() {
        let t = Topology::new(cfg(), 3);
        for pool in 0..3 {
            let a = t.alloc_lines_on(pool, 1);
            t.store(0, a, 1);
            t.pwb(0, a);
            t.psync_pool(0, pool);
        }
        let total = t.stats_total();
        assert_eq!(total.stores, 3);
        assert_eq!(total.pwbs, 3);
        assert_eq!(total.psyncs, 3);
        let per = t.stats_per_pool();
        assert_eq!(per.len(), 3);
        assert!(per.iter().all(|s| s.pwbs == 1));
    }

    #[test]
    fn site_ledger_merges_pools_and_renders() {
        use crate::obs::{self, ObsSite};
        let t = Topology::new(cfg(), 2);
        let a0 = t.alloc_lines_on(0, 1);
        let a1 = t.alloc_lines_on(1, 1);
        t.store(0, a0, 1);
        t.pwb(0, a0);
        t.psync_pool(0, 0);
        obs::with_site(ObsSite::BatchFlush, || {
            t.store(0, a1, 2);
            t.pwb(0, a1);
            t.psync_pool(0, 1);
        });
        let l = t.site_ledger();
        assert_eq!(l.psyncs_at(ObsSite::Op), 1);
        assert_eq!(l.psyncs_at(ObsSite::BatchFlush), 1);
        assert_eq!(l.pwbs_at(ObsSite::BatchFlush), 1);
        assert_eq!(l.total_psyncs(), t.stats_total().psyncs);
        let text = obs::render(&t.metric_families());
        assert!(text.contains("persiq_pmem_psyncs_total{pool=\"0\"} 1"));
        assert!(text.contains("persiq_pmem_psyncs_by_site_total{site=\"BatchFlush\"} 1"));
        assert!(text.contains("# TYPE persiq_pmem_max_vtime_ns gauge"));
    }

    #[test]
    fn palloc_families_render_occupancy_and_high_water() {
        use crate::obs;
        let t = Topology::new(cfg(), 2);
        let a = t.primary().palloc_alloc(0, 2).unwrap();
        t.primary().palloc_free(0, a);
        t.primary().psync(0);
        let text = obs::render(&t.metric_families());
        // The freed class-2 segment binds the class on pool 0; the
        // occupancy family must render with both labels (value may be 0
        // while the segment sits in a magazine rather than the shared
        // freelist).
        assert!(text.contains("persiq_palloc_free_segments{pool=\"0\",lines=\"2\"}"));
        assert!(text.contains("# TYPE persiq_pmem_used_words gauge"));
        assert!(text.contains("persiq_pmem_used_words{pool=\"0\"}"));
        // Lifecycle totals live in the process-global registry, not
        // here — a combined dump must not carry duplicate families.
        assert!(!text.contains("persiq_palloc_alloc_total"));
    }

    #[test]
    fn remote_penalty_keyed_on_home_socket() {
        let t = Topology::new(cfg(), 2);
        let c = t.primary().config().cost.clone();
        // Thread 0 homes on socket 0, thread 1 on socket 1.
        let a1 = t.alloc_lines_on(1, 1);
        t.pool(1).set_hot(a1.addr, 1, Hotness::Private);
        t.pwb(0, a1); // cross-socket
        t.pwb(1, a1); // home
        let s = t.stats_total();
        assert_eq!(s.remote_ops, 1, "only the foreign thread's pwb is remote");
        assert!(t.vtime(0) >= c.pwb_cost(1) + c.remote_pwb_ns);
    }

    #[test]
    fn from_pool_shares_clock_domain() {
        let t = Topology::new(cfg(), 2);
        let wrapped = Topology::from_pool(t.primary());
        assert_eq!(wrapped.len(), 1);
        t.arm_crash_after(1);
        // The wrapper sees the same armed cut.
        install_quiet_crash_hook();
        let a = wrapped.alloc_lines_on(0, 1);
        let out = run_guarded(|| {
            wrapped.store(0, a, 1);
            wrapped.store(0, a, 2);
        });
        assert!(out.crashed());
        let mut rng = Xoshiro256::seed_from(9);
        t.crash(&mut rng);
        assert_eq!(wrapped.epoch(), t.epoch());
        // home_pool clamps a raw home into the wrapper's range.
        assert_eq!(wrapped.home_of(1), 1, "raw home survives");
        assert_eq!(wrapped.home_pool(1), 0, "clamped into the single-pool wrapper");
    }
}
