//! The persistence/contention cost model.
//!
//! Costs are in simulated nanoseconds, charged to per-thread virtual clocks
//! (see [`super::pool`]). Defaults are calibrated against published Optane
//! DCPMM / cache-coherence measurements:
//!
//! * `clwb`-class flush: ~40–100 ns depending on line state (we split into
//!   a base cost plus a *hot-line* amplification proportional to the number
//!   of recent distinct accessors — flushing a contended line both costs
//!   more and, crucially, its latency lands **on the critical path of every
//!   contender** via the line-stamp mechanism).
//! * `sfence + drain` (`psync`): ~100 ns plus a per-pending-line drain cost.
//! * Contended atomic RMW: ~8 ns uncontended; each additional recent
//!   accessor adds a coherence-serialization penalty.
//!
//! The defaults reproduce the paper's *shape* (PerLCRQ ≥ 2× PBQueue;
//! PerLCRQ-PHead collapsing below the combining baselines at high thread
//! counts — Figs. 2–3); a sensitivity sweep over these knobs is part of the
//! bench suite.

/// How primitives consume time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeterMode {
    /// Charge virtual clocks only (default; used for scaling curves).
    Virtual,
    /// Additionally busy-wait for the pwb/psync cost in wall-clock time
    /// (used by microbenches for real-time comparisons).
    WallclockSpin,
}

/// Simulated cost model (nanoseconds).
///
/// Contention is charged as a **constant line-transfer penalty** when the
/// target line is "remote" — its stamp is ahead of the caller's clock,
/// i.e. another thread wrote/flushed it since the caller last held it.
/// Serialization among concurrent writers is NOT part of the per-op cost:
/// the Lamport stamp chain models it (each RMW appends its cost to the
/// line's stamp, so a hot line's accessors queue behind one another).
/// Charging k-proportional costs here would double-count — this is what
/// makes single-thread latency ≫ chain step, which in turn is what makes
/// FAI-based queues *scale* (the paper's premise: pwb/psync latencies of
/// different threads overlap; only the FAI handoff serializes).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Local (cache-hit) load.
    pub load_ns: u64,
    /// Extra for loading a line another thread wrote since we last held it.
    pub remote_load_ns: u64,
    /// Local store.
    pub store_ns: u64,
    /// Uncontended atomic RMW (FAI/CAS/SWAP/TAS).
    pub atomic_ns: u64,
    /// Line-transfer penalty for an RMW/store on a remote line
    /// (read-for-ownership).
    pub conflict_ns: u64,
    /// Base cost of `pwb` (clwb-class flush) on a cold/single-writer line.
    pub pwb_ns: u64,
    /// Extra `pwb` cost per additional recent accessor of the flushed line
    /// (flushing a hot line: steal + writeback + invalidate every sharer),
    /// capped at `pwb_hot_cap` accessors.
    pub pwb_hot_ns: u64,
    /// Cap on accessors counted for the hot-flush premium.
    pub pwb_hot_cap: u32,
    /// Global NVM media cost per realized flush (all threads share DIMM
    /// write bandwidth — a system-wide serialization chain).
    pub nvm_flush_ns: u64,
    /// Cost of `pfence` (ordering only).
    pub pfence_ns: u64,
    /// Base cost of `psync` (drain). Charged to the caller only — psyncs of
    /// different threads overlap, which is exactly the effect the paper's
    /// low-contention persistence placement exploits.
    pub psync_ns: u64,
    /// Additional `psync` cost per pending (queued) line being drained.
    pub psync_per_line_ns: u64,
    /// Cross-socket `pwb` penalty: extra cost when the flushing thread's
    /// home socket (see [`crate::pmem::Topology`]) differs from the socket
    /// owning the flushed line's pool. On real multi-DIMM machines a
    /// remote `clwb` crosses the interconnect and lands on the *remote*
    /// socket's NVM controller; published Optane numbers put the penalty
    /// at 1–4× the local flush. Only ever charged by multi-pool
    /// topologies: a single pool homes every thread on socket 0.
    pub remote_pwb_ns: u64,
    /// Cross-socket RMW penalty: extra cost for an atomic on a line whose
    /// pool lives on a different socket than the calling thread's home
    /// (directory indirection + interconnect hop).
    pub remote_rmw_ns: u64,
    /// Metering mode.
    pub meter: MeterMode,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            load_ns: 2,
            remote_load_ns: 60,
            store_ns: 3,
            atomic_ns: 8,
            conflict_ns: 120,
            pwb_ns: 60,
            pwb_hot_ns: 60,
            pwb_hot_cap: 10,
            nvm_flush_ns: 70,
            pfence_ns: 10,
            psync_ns: 250,
            psync_per_line_ns: 20,
            remote_pwb_ns: 120,
            remote_rmw_ns: 60,
            meter: MeterMode::Virtual,
        }
    }
}

impl CostModel {
    /// A zero-cost model (used by unit tests that only check semantics).
    pub fn zero() -> Self {
        Self {
            load_ns: 0,
            remote_load_ns: 0,
            store_ns: 0,
            atomic_ns: 0,
            conflict_ns: 0,
            pwb_ns: 0,
            pwb_hot_ns: 0,
            pwb_hot_cap: 0,
            nvm_flush_ns: 0,
            pfence_ns: 0,
            psync_ns: 0,
            psync_per_line_ns: 0,
            remote_pwb_ns: 0,
            remote_rmw_ns: 0,
            meter: MeterMode::Virtual,
        }
    }

    /// RMW cost; `remote` = the line was written by another thread since
    /// the caller last held it (stamp ahead of caller's clock).
    #[inline]
    pub fn rmw_cost(&self, remote: bool) -> u64 {
        self.atomic_ns + if remote { self.conflict_ns } else { 0 }
    }

    /// Load cost (remote ⇒ coherence miss).
    #[inline]
    pub fn load_cost(&self, remote: bool) -> u64 {
        self.load_ns + if remote { self.remote_load_ns } else { 0 }
    }

    /// Store cost (remote ⇒ read-for-ownership transfer).
    #[inline]
    pub fn store_cost(&self, remote: bool) -> u64 {
        self.store_ns + if remote { self.conflict_ns } else { 0 }
    }

    /// `pwb` cost given `k` distinct recent accessors of the line.
    #[inline]
    pub fn pwb_cost(&self, k: u32) -> u64 {
        self.pwb_ns + k.saturating_sub(1).min(self.pwb_hot_cap) as u64 * self.pwb_hot_ns
    }

    /// `psync` cost given `pending` queued lines.
    #[inline]
    pub fn psync_cost(&self, pending: usize) -> u64 {
        self.psync_ns + pending as u64 * self.psync_per_line_ns
    }

    /// Parse overrides from a `[pmem.cost]` config section.
    pub fn apply_toml(&mut self, doc: &crate::util::toml::Doc, section: &str) {
        self.load_ns = doc.get_u64(section, "load_ns", self.load_ns);
        self.remote_load_ns = doc.get_u64(section, "remote_load_ns", self.remote_load_ns);
        self.store_ns = doc.get_u64(section, "store_ns", self.store_ns);
        self.atomic_ns = doc.get_u64(section, "atomic_ns", self.atomic_ns);
        self.conflict_ns = doc.get_u64(section, "conflict_ns", self.conflict_ns);
        self.pwb_ns = doc.get_u64(section, "pwb_ns", self.pwb_ns);
        self.pwb_hot_ns = doc.get_u64(section, "pwb_hot_ns", self.pwb_hot_ns);
        self.pwb_hot_cap =
            doc.get_u64(section, "pwb_hot_cap", self.pwb_hot_cap as u64) as u32;
        self.nvm_flush_ns = doc.get_u64(section, "nvm_flush_ns", self.nvm_flush_ns);
        self.pfence_ns = doc.get_u64(section, "pfence_ns", self.pfence_ns);
        self.psync_ns = doc.get_u64(section, "psync_ns", self.psync_ns);
        self.psync_per_line_ns =
            doc.get_u64(section, "psync_per_line_ns", self.psync_per_line_ns);
        self.remote_pwb_ns = doc.get_u64(section, "remote_pwb_ns", self.remote_pwb_ns);
        self.remote_rmw_ns = doc.get_u64(section, "remote_rmw_ns", self.remote_rmw_ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmw_remote_penalty() {
        let c = CostModel::default();
        assert_eq!(c.rmw_cost(false), c.atomic_ns);
        assert_eq!(c.rmw_cost(true), c.atomic_ns + c.conflict_ns);
        assert_eq!(c.load_cost(true), c.load_ns + c.remote_load_ns);
        assert_eq!(c.store_cost(false), c.store_ns);
    }

    #[test]
    fn pwb_hot_vs_cold() {
        let c = CostModel::default();
        assert!(c.pwb_cost(8) > c.pwb_cost(1));
        assert_eq!(c.pwb_cost(1), c.pwb_ns);
        assert_eq!(c.pwb_cost(2), c.pwb_ns + c.pwb_hot_ns);
        // Cap respected.
        assert_eq!(
            c.pwb_cost(1000),
            c.pwb_ns + c.pwb_hot_cap as u64 * c.pwb_hot_ns
        );
    }

    #[test]
    fn psync_scales_with_pending() {
        let c = CostModel::default();
        assert_eq!(c.psync_cost(0), c.psync_ns);
        assert_eq!(c.psync_cost(3), c.psync_ns + 3 * c.psync_per_line_ns);
    }

    #[test]
    fn zero_model_is_zero() {
        let c = CostModel::zero();
        assert_eq!(c.rmw_cost(true), 0);
        assert_eq!(c.pwb_cost(10), 0);
        assert_eq!(c.psync_cost(10), 0);
    }

    #[test]
    fn cross_socket_knobs_exist_and_override() {
        let c = CostModel::default();
        assert!(c.remote_pwb_ns >= 2 * c.pwb_ns, "default remote pwb should be >= 2x local");
        assert_eq!(CostModel::zero().remote_pwb_ns, 0);
        assert_eq!(CostModel::zero().remote_rmw_ns, 0);
        let doc =
            crate::util::toml::parse("[pmem.cost]\nremote_pwb_ns = 333\nremote_rmw_ns = 44\n")
                .unwrap();
        let mut c = CostModel::default();
        c.apply_toml(&doc, "pmem.cost");
        assert_eq!(c.remote_pwb_ns, 333);
        assert_eq!(c.remote_rmw_ns, 44);
    }

    #[test]
    fn toml_overrides() {
        let doc = crate::util::toml::parse("[pmem.cost]\npwb_ns = 500\n").unwrap();
        let mut c = CostModel::default();
        c.apply_toml(&doc, "pmem.cost");
        assert_eq!(c.pwb_ns, 500);
        assert_eq!(c.psync_ns, CostModel::default().psync_ns);
    }
}
