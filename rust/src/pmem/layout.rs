//! Arena addressing and cache-line geometry.

/// Words per simulated cache line (64 bytes / 8-byte words).
pub const WORDS_PER_LINE: usize = 8;

/// A persistent-arena address: an index of a 64-bit word. All persistent
/// data structures store **addresses, never Rust pointers**, mirroring
/// PMDK's base-relative offsets — the arena image alone must be enough to
/// recover (see DESIGN.md §1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PAddr(pub u32);

/// Sentinel for "null persistent pointer". Word 0 of every pool is reserved
/// so that address 0 is never a valid allocation.
pub const PNULL: PAddr = PAddr(0);

impl PAddr {
    /// Word index.
    #[inline]
    pub fn word(self) -> usize {
        self.0 as usize
    }

    /// Line index containing this word.
    #[inline]
    pub fn line(self) -> usize {
        self.0 as usize / WORDS_PER_LINE
    }

    /// Offset of this word within its line.
    #[inline]
    pub fn offset_in_line(self) -> usize {
        self.0 as usize % WORDS_PER_LINE
    }

    /// Address `k` words after this one.
    #[inline]
    pub fn add(self, k: usize) -> PAddr {
        PAddr(self.0 + k as u32)
    }

    /// Is this the null address?
    #[inline]
    pub fn is_null(self) -> bool {
        self.0 == 0
    }

    /// Raw u64 for storing a persistent pointer inside a persistent word.
    #[inline]
    pub fn to_u64(self) -> u64 {
        self.0 as u64
    }

    /// Reconstruct from a persistent word value.
    #[inline]
    pub fn from_u64(v: u64) -> PAddr {
        PAddr(v as u32)
    }
}

/// A 64-byte-aligned group of 8 atomic words — the unit of `pwb` and of
/// crash-time eviction. `#[repr(align(64))]` guarantees real cache-line
/// alignment so that simulated-line contention is also real contention.
#[repr(align(64))]
pub struct CacheLine(pub [std::sync::atomic::AtomicU64; WORDS_PER_LINE]);

impl CacheLine {
    pub fn zeroed() -> Self {
        CacheLine(std::array::from_fn(|_| std::sync::atomic::AtomicU64::new(0)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_geometry() {
        let a = PAddr(17);
        assert_eq!(a.word(), 17);
        assert_eq!(a.line(), 2);
        assert_eq!(a.offset_in_line(), 1);
        assert_eq!(a.add(7).word(), 24);
        assert_eq!(a.add(7).line(), 3);
    }

    #[test]
    fn null_sentinel() {
        assert!(PNULL.is_null());
        assert!(!PAddr(1).is_null());
        assert_eq!(PAddr::from_u64(PNULL.to_u64()), PNULL);
    }

    #[test]
    fn cache_line_alignment() {
        assert_eq!(std::mem::size_of::<CacheLine>(), 64);
        assert_eq!(std::mem::align_of::<CacheLine>(), 64);
        let boxed = CacheLine::zeroed();
        assert_eq!(&boxed as *const _ as usize % 64, 0);
    }

    #[test]
    fn roundtrip_u64() {
        let a = PAddr(12345);
        assert_eq!(PAddr::from_u64(a.to_u64()), a);
    }
}
